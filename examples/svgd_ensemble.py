"""SVGD on a ViT classifier: the paper's all-to-all particle algorithm.

Runs Stein Variational Gradient Descent twice over the same particles:
  1. the paper-faithful message-passing implementation (leader particle,
     SVGD_STEP / SVGD_FOLLOW messages, read-only views), and
  2. the beyond-paper compiled path (one XLA program over a stacked
     particle axis),
then verifies they agree and reports the ensemble accuracy.

Run:  PYTHONPATH=src python examples/svgd_ensemble.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.bdl import SteinVGD, fused_svgd_step
from repro.core import ParticleModule, functional
from repro.data.loader import DataLoader
from repro.models import api


def main():
    cfg = configs.get("vit-mnist").smoke().replace(n_units=2, d_model=64,
                                                   n_heads=4, n_kv_heads=4,
                                                   head_dim=16, d_ff=128)
    mod = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)
    train = [jax.tree.map(jnp.asarray, b) for b in
             DataLoader(cfg, batch_size=16, num_batches=4, seed=0)]
    test = [jax.tree.map(jnp.asarray, b) for b in
            DataLoader(cfg, batch_size=64, num_batches=2, seed=9)]
    N, LR, EPOCHS = 4, 2e-3, 6

    # --- paper-faithful message-passing SVGD -------------------------------
    sv = SteinVGD(mod, num_devices=1, seed=0)
    pids, losses = sv.bayes_infer(train, EPOCHS, num_particles=N,
                                  lengthscale=1.0, lr=LR)
    mp_flat = jnp.stack([jax.flatten_util.ravel_pytree(
        sv.push_dist.p_params(p))[0] for p in pids])
    acc = _ensemble_acc(sv.push_dist, test)
    print(f"message-passing SVGD: last losses {losses[-1]:.3f}, "
          f"ensemble acc {acc:.3f}")
    print(f"NEL stats: {sv.push_dist.nel.stats}")
    sv.cleanup()

    # --- compiled fused SVGD (same seeds => identical trajectory) ----------
    rng = jax.random.PRNGKey(0)
    inits = []
    for _ in range(N):
        rng, sub = jax.random.split(rng)
        inits.append(mod.init(sub))
    stacked = functional.stack_pytrees(inits)
    step = jax.jit(fused_svgd_step(mod.loss, lr=LR, lengthscale=1.0))
    for _ in range(EPOCHS):
        for b in train:
            stacked, _ = step(stacked, b)
    fu_flat, _ = functional.flatten_stacked(stacked)
    err = float(jnp.abs(mp_flat - fu_flat).max())
    print(f"fused-vs-message-passing parameter agreement: max |diff| = {err:.2e}")
    assert err < 1e-3


def _ensemble_acc(pd, test):
    accs = []
    for b in test:
        pred = pd.p_predict(b)
        accs.append(float(jnp.mean(jnp.argmax(pred, -1) == b["labels"])))
    return sum(accs) / len(accs)


if __name__ == "__main__":
    main()
