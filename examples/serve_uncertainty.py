"""Uncertainty-aware serving on vit_mnist: entropy + mutual information.

Trains a small deep ensemble (compiled backend), hands the posterior to
the serving layer (`posterior_predictive()`), and compares the served
uncertainty heads on in-distribution digits vs pure-noise images: the
BALD mutual information (epistemic) should be visibly higher off
distribution — the signal a production router would use to escalate or
abstain. Ends with a calibration report (NLL / ECE / Brier) computed
from the same served BMA probabilities.

Run:  PYTHONPATH=src python examples/serve_uncertainty.py
"""
import argparse

import jax
import numpy as np

from repro import configs
from repro.bdl import DeepEnsemble
from repro.core import ParticleModule
from repro.data.loader import DataLoader
from repro.models import api
from repro.optim import adam
from repro.serve import metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--particles", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=3)
    a = ap.parse_args()

    cfg = configs.get("vit-mnist").smoke().replace(
        n_units=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128)
    module = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)

    with DeepEnsemble(module, seed=0, backend="compiled") as de:
        dl = DataLoader(cfg, batch_size=16, num_batches=8, seed=0)
        de.bayes_infer(dl, a.epochs, optimizer=adam(1e-3),
                       num_particles=a.particles)

        # posterior -> serving layer (fused BMA + heads, micro-batched)
        with de.posterior_predictive(kind="classify", max_batch=32,
                                     max_wait_ms=2.0) as svc:
            probe = next(iter(DataLoader(cfg, batch_size=64, num_batches=1,
                                         seed=123)))
            rng = np.random.default_rng(7)
            noise = rng.standard_normal(probe["images"].shape).astype(
                np.float32)

            in_heads = svc.predict_batch({"images": probe["images"]})
            ood_heads = svc.predict_batch({"images": noise})

            def report(name, h):
                conf = float(np.mean(np.max(np.asarray(h["mean"]), -1)))
                print(f"{name:16s} confidence={conf:.3f} "
                      f"entropy={float(np.mean(h['entropy'])):.3f} "
                      f"mutual_info={float(np.mean(h['mutual_info'])):.4f}")

            report("in-distribution", in_heads)
            report("noise (OOD)", ood_heads)

            # single-request path: one digit through the micro-batcher
            pred = svc.predict({"images": probe["images"][0]})
            print(f"one request: argmax={int(np.argmax(pred.mean))} "
                  f"label={int(probe['labels'][0])} "
                  f"entropy={float(pred.entropy):.3f}")

            cal = metrics.calibration_report(in_heads["mean"],
                                             probe["labels"])
            print("calibration:", {k: round(v, 4) for k, v in cal.items()})
            stats = svc.stats()
            print(f"served {stats['requests']} single requests in "
                  f"{stats['batches']} fused calls; "
                  f"p50={stats['latency_p50_ms']:.1f}ms "
                  f"p95={stats['latency_p95_ms']:.1f}ms")


if __name__ == "__main__":
    main()
