"""End-to-end training driver: a qwen-family LM trained for a few hundred
steps with the compiled ensemble path, cosine schedule, gradient clipping
via microbatching, checkpointing and loss logging.

Default size is container-friendly (~5M params); --preset 100m builds a
~100M-parameter model (same code path — use on real hardware).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
      PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import checkpoint, configs
from repro.core import ParticleModule, functional
from repro.data.loader import DataLoader
from repro.models import api
from repro.optim import adam
from repro.optim.schedules import warmup_cosine

PRESETS = {
    # (layers, d_model, heads, d_ff, vocab, batch, seq)
    "tiny": (4, 192, 4, 512, 2048, 8, 64),
    "20m": (8, 384, 8, 1024, 8192, 8, 128),
    "100m": (12, 768, 12, 2048, 32000, 8, 256),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=sorted(PRESETS), default="tiny")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--particles", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=100)
    a = ap.parse_args()

    L, D, H, F, V, B, S = PRESETS[a.preset]
    cfg = configs.get("qwen1.5-0.5b").replace(
        n_units=L, d_model=D, n_heads=H, n_kv_heads=H, head_dim=D // H,
        d_ff=F, vocab_size=V, max_seq_len=4 * S)
    mod = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)

    stacked = functional.init_stacked(mod, a.particles, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(stacked)) // a.particles
    print(f"model: {L}L d={D} vocab={V} -> {n_params/1e6:.1f}M params x "
          f"{a.particles} particles")

    opt = adam(warmup_cosine(3e-3, 20, a.steps))
    opt_state = jax.vmap(opt.init)(stacked)
    step_fn = jax.jit(functional.ensemble_step(mod.loss, opt))

    loader = DataLoader(cfg, batch_size=B, seq_len=S,
                        num_batches=max(a.steps, 1), seed=0)
    t0 = time.perf_counter()
    first = None
    for step, batch in enumerate(loader):
        batch = jax.tree.map(jnp.asarray, batch)
        stacked, opt_state, losses = step_fn(stacked, opt_state, batch)
        if step == 0:
            first = float(losses.mean())
        if step % 20 == 0 or step == a.steps - 1:
            l = float(losses.mean())
            dt = time.perf_counter() - t0
            tok_s = (step + 1) * B * S * a.particles / dt
            print(f"step {step:4d}  loss {l:.4f}  ({tok_s:,.0f} tok/s)")
        if a.ckpt_every and step and step % a.ckpt_every == 0:
            path = checkpoint.save(a.ckpt_dir, step,
                                   {"params": stacked, "opt": opt_state})
            print(f"  checkpoint -> {path}")
        if step + 1 >= a.steps:
            break
    last = float(losses.mean())
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'OK: decreased' if last < first else 'WARNING: did not decrease'})")


if __name__ == "__main__":
    main()
