"""Streaming LM serving through `repro.serve.serve_decode`: continuous
batching over a paged KV cache, all particles fused per decode step.

A qwen-family serve ensemble (P particles standing in for SWAG draws)
lives in a PushDistribution's ParticleStore. `serve_decode` installs a
paged KV pool (`kv_pages`) on the stacked particle axis and starts a
DecodeScheduler: requests of very different lengths are admitted and
retired *per decode step* — a short generation never waits for a long
one to finish, and a finished row is refilled from the queue in the
same step it retires. Every step is ONE fixed-shape fused program
(BMA-averaged greedy head + per-token entropy / mutual information)
and ONE host-to-device transfer, so steady-state decode compiles
nothing regardless of admission order.

Contrast with the pre-PR-6 version of this example, which ran a dense
flush batch: per-request KV sized to max_seq_len and every sequence in
the batch stepping until the LAST one finished.

With ``--speculative`` the scheduler switches to speculative BMA
decoding (DESIGN.md §14): one particle drafts K tokens autoregressively,
then a single fused window program scores all K positions across the
whole ensemble and accepts the longest prefix that matches the BMA
argmax. Greedy output is token-exact either way; only the number of
program dispatches per emitted token changes.

Run:  PYTHONPATH=src python examples/serve_decode.py --requests 12
      PYTHONPATH=src python examples/serve_decode.py --speculative
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core import ParticleModule, PushDistribution
from repro.models import api
from repro.runtime import global_cache
from repro.serve import serve_decode


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-active", type=int, default=4)
    ap.add_argument("--particles", type=int, default=2)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--num-pages", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--decode-kernel", action="store_true",
                    help="route paged attention through the Pallas kernel "
                         "(interpret mode on CPU: slow but exercised)")
    ap.add_argument("--speculative", action="store_true",
                    help="speculative BMA decoding: one particle drafts "
                         "K tokens, one fused program verifies the window "
                         "(token-exact, fewer dispatches per token)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="max drafted tokens per step (adaptive below)")
    a = ap.parse_args()

    cfg = configs.get("qwen1.5-0.5b").replace(
        n_units=a.layers, d_model=a.d_model, n_heads=8, n_kv_heads=4,
        head_dim=a.d_model // 8, d_ff=a.d_model * 3, vocab_size=2048,
        max_seq_len=1024)

    # the serve ensemble is a PushDistribution: particles in the store
    module = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)
    with PushDistribution(module, num_devices=1, seed=0) as pd:
        for _ in range(a.particles):
            pd.p_create()
        n_params = sum(x.size for x in jax.tree.leaves(pd.p_params(0)))
        print(f"model: {a.layers}L d={a.d_model} ({n_params/1e6:.1f}M "
              f"params), serve ensemble P={a.particles}")

        svc = serve_decode(pd, cfg, num_pages=a.num_pages,
                           page_size=a.page_size, max_active=a.max_active,
                           decode_kernel=a.decode_kernel,
                           warmup_buckets=(8, 16, 32),
                           speculative=a.draft_k if a.speculative else None)
        try:
            # mixed-length open-loop load: mostly short continuations plus
            # heavy-tail stragglers — the case flush batching handles worst
            rng = np.random.default_rng(0)
            reqs = []
            for i in range(a.requests):
                prompt = list(rng.integers(1, cfg.vocab_size,
                                           int(rng.integers(8, 25))))
                reqs.append((prompt, 96 if i % 4 == 0 else 12))

            cold0 = global_cache().snapshot_stats()["cold_compiles"]
            t0 = time.perf_counter()
            handles = [svc.generate_async(p, max_new=m) for p, m in reqs]
            gens = [h.result(600.0) for h in handles]
            dt = time.perf_counter() - t0
            toks = sum(len(g.tokens) for g in gens)
            cold = global_cache().snapshot_stats()["cold_compiles"] - cold0

            st = svc.stats()
            print(f"decode: {a.requests} requests ({toks} tokens, lengths "
                  f"{sorted({len(g.tokens) for g in gens})}) in {dt:.2f}s "
                  f"({toks / dt:.1f} tok/s)")
            print(f"steps={st['steps']} prefills={st['prefills']} "
                  f"h2d={st['h2d_transfers']} "
                  f"occupancy={st['row_occupancy']:.2f} "
                  f"peak_pages={st['pool']['peak_used']}/"
                  f"{st['pool']['num_pages']} "
                  f"preempted={st['preempted']} "
                  f"cold_compiles_after_warmup={cold}")
            if st.get("speculative"):
                ss = st["speculative"]
                print(f"speculative: k_max={ss['k_max']} "
                      f"acceptance={ss['acceptance_rate']:.2f} "
                      f"tokens_per_step={ss['tokens_per_step']:.2f} "
                      f"mean_k={ss['mean_k']:.2f} "
                      f"rollback_pages={ss['rollback_pages']}")
            g = gens[0]
            print("request 0 tokens   :", g.tokens[:16])
            print("request 0 entropy  :",
                  [round(float(e), 2) for e in g.entropy[:8]])
            print("request 0 mutualinf:",
                  [round(float(m), 3) for m in g.mutual_info[:8]])
            print(f"request 0 finished : {g.finish_reason} "
                  f"(latency p50 {st['latency_p50_ms']:.0f} ms)")
        finally:
            svc.close()


if __name__ == "__main__":
    main()
