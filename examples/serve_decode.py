"""End-to-end serving driver: batched requests through prefill + decode with
a posterior-predictive serve ensemble (the decode-shape workload of the
dry run, at container scale).

A qwen-family model serves a batch of prompts: prefill builds the KV
caches, then an autoregressive decode loop samples new tokens; with
--particles > 1 the logits are averaged over a small serve ensemble
(multi-SWAG-style BDL serving).

Run:  PYTHONPATH=src python examples/serve_decode.py --steps 16 --batch 4
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.synthetic import lm_batch
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--particles", type=int, default=2)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    a = ap.parse_args()

    cfg = configs.get("qwen1.5-0.5b").replace(
        n_units=a.layers, d_model=a.d_model, n_heads=8, n_kv_heads=8,
        head_dim=32, d_ff=a.d_model * 3, vocab_size=2048, max_seq_len=4096)
    n_params = None

    # serve ensemble: P particles (independent inits stand in for SWAG draws)
    params = [api.init_params(jax.random.PRNGKey(i), cfg)
              for i in range(a.particles)]
    n_params = sum(x.size for x in jax.tree.leaves(params[0]))
    print(f"model: {a.layers}L d={a.d_model} ({n_params/1e6:.1f}M params), "
          f"serve ensemble P={a.particles}")

    prompts = jnp.asarray(lm_batch(np.random.default_rng(0), a.batch,
                                   a.prompt_len, cfg.vocab_size)["tokens"])

    total_len = a.prompt_len + a.steps + 1
    prefill = jax.jit(lambda p, b: api.prefill(p, b, cfg, max_len=total_len))
    decode = jax.jit(lambda p, t, c, pos: api.decode_step(p, t, c, pos, cfg))

    # --- prefill ------------------------------------------------------------
    t0 = time.perf_counter()
    logits, caches = zip(*(prefill(p, {"tokens": prompts}) for p in params))
    logits = jnp.mean(jnp.stack([l.astype(jnp.float32) for l in logits]), 0)
    caches = list(caches)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {a.batch} x {a.prompt_len} tokens in {t_prefill:.2f}s "
          f"({a.batch * a.prompt_len / t_prefill:.0f} tok/s)")

    # --- autoregressive decode with ensemble-averaged logits ----------------
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [tok]
    t0 = time.perf_counter()
    for step in range(a.steps):
        pos = jnp.int32(a.prompt_len + step)
        outs = []
        for i in range(a.particles):
            l, caches[i] = decode(params[i], tok, caches[i], pos)
            outs.append(l.astype(jnp.float32))
        tok = jnp.argmax(jnp.mean(jnp.stack(outs), 0), -1).astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    toks = a.steps * a.batch
    print(f"decode: {a.steps} steps x {a.batch} requests in {t_decode:.2f}s "
          f"({toks / t_decode:.1f} tok/s, {t_decode / a.steps * 1e3:.0f} ms/step)")
    gen = jnp.stack(generated, 1)
    print("generated token ids (request 0):", gen[0].tolist())


if __name__ == "__main__":
    main()
