"""Batched LM serving through `repro.serve`: a posterior-predictive
decode loop where ALL particles run in one fused program per token.

A qwen-family serve ensemble (P particles standing in for SWAG draws)
lives in a PushDistribution's ParticleStore; a stateful PredictiveEngine
compiles one fused step — every particle's decode forward over the
stacked axis, Bayesian-model-averaged logits, predictive entropy and
mutual information — and the per-particle KV caches ride the stacked
axis on device across the whole generation. Cache attention runs through
the Pallas decode kernel (`decode_kernel=True`).

Contrast with the pre-serve version of this example, which hand-rolled a
Python loop over particles with a host sync per (particle, step) pair.

Run:  PYTHONPATH=src python examples/serve_decode.py --steps 16 --batch 4
"""
import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import ParticleModule, PushDistribution
from repro.data.synthetic import lm_batch
from repro.models import api
from repro.serve import PredictiveEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--particles", type=int, default=2)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    a = ap.parse_args()

    cfg = configs.get("qwen1.5-0.5b").replace(
        n_units=a.layers, d_model=a.d_model, n_heads=8, n_kv_heads=8,
        head_dim=32, d_ff=a.d_model * 3, vocab_size=2048, max_seq_len=4096)

    # the serve ensemble is a PushDistribution: particles in the store
    module = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)
    with PushDistribution(module, num_devices=1, seed=0) as pd:
        for _ in range(a.particles):
            pd.p_create()
        n_params = sum(x.size for x in jax.tree.leaves(pd.p_params(0)))
        print(f"model: {a.layers}L d={a.d_model} ({n_params/1e6:.1f}M params), "
              f"serve ensemble P={a.particles}")

        prompts = jnp.asarray(lm_batch(np.random.default_rng(0), a.batch,
                                       a.prompt_len, cfg.vocab_size)["tokens"])
        total_len = a.prompt_len + a.steps + 1

        # stateful engine: fused BMA decode, per-particle KV caches stacked
        decode = functools.partial(api.decode_step, cfg=cfg,
                                   decode_kernel=True)
        engine = PredictiveEngine(
            lambda p, caches, b: decode(p, b[0], caches, b[1]),
            store=pd.store, kind="classify", stateful=True)

        # --- prefill: one vmapped pass yields BOTH the stacked caches and
        # the first BMA logits (prompt FLOPs paid once, one program) ------
        t0 = time.perf_counter()
        first, caches = jax.jit(jax.vmap(
            lambda p: api.prefill(p, {"tokens": prompts}, cfg,
                                  max_len=total_len)))(
            engine.stacked_params())
        logits = jnp.mean(first.astype(jnp.float32), 0)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0
        print(f"prefill: {a.batch} x {a.prompt_len} tokens in "
              f"{t_prefill:.2f}s "
              f"({a.batch * a.prompt_len / t_prefill:.0f} tok/s)")

        # --- fused-BMA decode with uncertainty riding along --------------
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        generated, entropies, mis = [tok], [], []
        t0 = time.perf_counter()
        for step in range(a.steps):
            heads, caches = engine.step(
                caches, (tok, jnp.int32(a.prompt_len + step)))
            tok = jnp.argmax(heads["mean"], -1).astype(jnp.int32)
            generated.append(tok)
            entropies.append(heads["entropy"])
            mis.append(heads["mutual_info"])
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0
        toks = a.steps * a.batch
        print(f"decode: {a.steps} steps x {a.batch} requests in "
              f"{t_decode:.2f}s ({toks / t_decode:.1f} tok/s, "
              f"{t_decode / a.steps * 1e3:.0f} ms/step)")
        gen = jnp.stack(generated, 1)
        ent = jnp.stack(entropies, 1)
        mi = jnp.stack(mis, 1)
        print("request 0 tokens   :", gen[0].tolist())
        print("request 0 entropy  :",
              [round(float(e), 2) for e in ent[0]])
        print("request 0 mutualinf:",
              [round(float(m), 3) for m in mi[0]])
        print("engine:", engine.snapshot_stats())


if __name__ == "__main__":
    main()
