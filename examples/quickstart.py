"""Quickstart: Bayesian deep learning with Push particles in ~40 lines.

Builds a Push distribution over a small MLP, trains a 4-particle deep
ensemble on noisy synthetic regression, and prints the posterior-predictive
mean +/- spread (the epistemic uncertainty the ensemble provides).

Backend selection (DESIGN.md §8): ``backend="nel"`` runs the
paper-faithful actor runtime (one dispatch per message, shown below);
``backend="compiled"`` selects the CompiledRuntime — the same particles,
but training and prediction lower to single fused XLA programs through
the shared runtime layer. ``pd.stats()`` shows both sides: executor
wait-vs-run time and the ProgramCache's hit/miss/cold-compile counters.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import ParticleModule, PushDistribution
from repro.optim import adam


# 1. An ordinary NN as pure init/loss/forward functions.
def init(rng):
    k1, k2 = jax.random.split(rng)
    return {"w1": jax.random.normal(k1, (1, 32)) * 0.5,
            "b1": jnp.zeros((32,)),
            "w2": jax.random.normal(k2, (32, 1)) * 0.5,
            "b2": jnp.zeros((1,))}


def forward(p, batch):
    x = batch[0]
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def loss(p, batch):
    return jnp.mean((forward(p, batch) - batch[1]) ** 2), {}


def main():
    # 2. Data: y = sin(3x) + noise, observed only on part of the domain.
    rng = jax.random.PRNGKey(0)
    x = jax.random.uniform(rng, (128, 1), minval=-1.0, maxval=1.0)
    y = jnp.sin(3 * x) + 0.1 * jax.random.normal(rng, (128, 1))

    # 3. A Push distribution with 4 particles (deep ensemble).
    module = ParticleModule(init, loss, forward)
    with PushDistribution(module, num_devices=1) as pd:
        pids = [pd.p_create(adam(1e-2)) for _ in range(4)]
        for epoch in range(300):
            futs = [pd.particles[p].step((x, y)) for p in pids]
            losses = [float(f.wait()) for f in futs]
        print(f"final per-particle losses: {[f'{l:.4f}' for l in losses]}")

        # 4. Posterior predictive: mean over particles; spread = uncertainty.
        xt = jnp.linspace(-2, 2, 9).reshape(-1, 1)
        preds = jnp.stack([pd.particles[p].forward((xt, None)).wait()
                           for p in pids])
        mu, sd = preds.mean(0)[:, 0], preds.std(0)[:, 0]
        print("\n   x     E[f(x)]  +/- spread   (spread grows off-data: x<-1, x>1)")
        for xi, m, s in zip(xt[:, 0], mu, sd):
            print(f"  {float(xi):+.2f}   {float(m):+.3f}    {float(s):.3f}")

    # 5. Same algorithm, compiled runtime: backend= selects a Runtime
    #    object; the fused train step and the BMA predict compile ONCE
    #    through the process-wide ProgramCache (repro.runtime).
    from repro.bdl import DeepEnsemble

    with DeepEnsemble(module, backend="compiled") as de:
        de.bayes_infer([(x, y)], 300, optimizer=adam(1e-2), num_particles=4)
        mu_fused = de.posterior_pred((jnp.linspace(-2, 2, 9).reshape(-1, 1),
                                      None))
        stats = de.push_dist.stats()
        cache = stats["program_cache"]
        print(f"\ncompiled runtime: E[f(0)] ~ {float(mu_fused[4, 0]):+.3f}; "
              f"programs={cache['programs']} "
              f"cold_compiles={cache['cold_compiles']} "
              f"hit_rate={cache['hit_rate']:.2f}")

        # 6. Particle lifecycle — clone WHILE serving (DESIGN.md §9).
        #    The store is capacity-padded, so p_clone/p_kill between
        #    requests are slot writes: the serving program never
        #    recompiles, it just re-reads the active mask per request.
        pd = de.push_dist
        with de.posterior_predictive(kind="regress") as svc:
            xt = jnp.linspace(-2, 2, 9).reshape(-1, 1)
            before = svc.predict_batch((xt, None))
            cold0 = pd.stats()["program_cache"]["cold_compiles"]
            worst = pd.particle_ids()[0]
            pd.p_kill(worst)                       # retire a member...
            pd.p_clone(pd.particle_ids()[0],       # ...replace it live
                       jitter=0.05)
            after = svc.predict_batch((xt, None))
            lc = pd.stats()["lifecycle"]
            print(f"cloned while serving: live={lc['live']}/"
                  f"{lc['capacity']} slots, clones={lc['clones']}, "
                  f"kills={lc['kills']}, recompiles="
                  f"{pd.stats()['program_cache']['cold_compiles'] - cold0}")
            drift = float(jnp.abs(after['mean'] - before['mean']).max())
            print(f"BMA drift after member swap: {drift:.4f} "
                  "(small: the clone is a jittered survivor)")

        # 7. Mixed precision — one flag (DESIGN.md §13). "mixed" keeps
        #    fp32 masters in the store and traces bf16 compute inside
        #    the SAME fused step; "bf16" halves params+opt memory per
        #    particle. The store reports bytes off actual leaf dtypes.
        from repro.obs.device import store_gauges

        with DeepEnsemble(module, backend="compiled",
                          precision="bf16") as de16:
            de16.bayes_infer([(x, y)], 300, optimizer=adam(1e-2),
                             num_particles=4)
            g16, g32 = (store_gauges(d.store) for d in (de16, de))
            print(f"\nbf16 particles: {g16['per_particle_bytes']['params']}"
                  f" B/particle vs fp32 {g32['per_particle_bytes']['params']}"
                  f" B (master={g16['precision']['master']})")

        # 8. Observability — trace a request and open it in Perfetto
        #    (DESIGN.md §12). Tracing is off by default and costs one
        #    branch per dispatch until enabled.
        from repro.obs import trace
        trace.enable()
        de.posterior_pred((xt, None))
        pd.obs().dump_trace("/tmp/push_trace.json")
        trace.disable()
        print(f"traced {trace.TRACER.counts()['buffered']} spans "
              "-> /tmp/push_trace.json (open in ui.perfetto.dev)")


if __name__ == "__main__":
    main()
