"""Paper Tables 1+3 in miniature: trade depth against particles at a fixed
effective parameter count, and compare multi-SWAG vs standard training.

Run:  PYTHONPATH=src python examples/multiswag_tradeoff.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.bdl import MultiSWAG
from repro.core import ParticleModule
from repro.data.loader import DataLoader
from repro.models import api
from repro.optim import adam


def build(depth):
    cfg = configs.get("vit-mnist").smoke().replace(
        n_units=depth, d_model=48, n_heads=4, n_kv_heads=4, head_dim=12,
        d_ff=96)
    return ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)


def main():
    print(" depth  particles  eff_params   multiSWAG acc")
    for depth, n in [(4, 1), (2, 2), (1, 4)]:
        mod = build(depth)
        n_params = sum(x.size for x in jax.tree.leaves(
            mod.init(jax.random.PRNGKey(0))))
        train = [jax.tree.map(jnp.asarray, b) for b in
                 DataLoader(mod.cfg, batch_size=16, num_batches=6, seed=0)]
        test = [jax.tree.map(jnp.asarray, b) for b in
                DataLoader(mod.cfg, batch_size=64, num_batches=2, seed=9)]
        with MultiSWAG(mod, num_devices=1) as ms:
            ms.bayes_infer(train, epochs=6, optimizer=adam(2e-3),
                           num_particles=n, pretrain_epochs=3, max_rank=4)
            accs = []
            for b in test:
                pred = ms.sample_predict(b, samples_per_particle=3)
                accs.append(float(jnp.mean(jnp.argmax(pred, -1) == b["labels"])))
        print(f"  {depth:3d}   {n:6d}    {n_params * n:9,d}      "
              f"{sum(accs)/len(accs):.3f}")


if __name__ == "__main__":
    main()
