"""Particle abstraction semantics (paper §3.2-§3.3): messages, futures,
views, the NEL particle cache, and error propagation."""
import threading
import time

import jax
import jax.numpy as jnp
import pytest

from repro.core import ParticleModule, PushDistribution
from repro.optim import sgd


def _module():
    def init(rng):
        return {"w": jax.random.normal(rng, (4, 4)), "b": jnp.zeros((4,))}

    def loss(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2), {}

    def fwd(p, batch):
        return batch[0] @ p["w"] + p["b"]

    return ParticleModule(init, loss, fwd)


def _batch():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 4))
    return (x, x @ (2.0 * jnp.eye(4)))


def test_p_create_and_ids():
    with PushDistribution(_module(), num_devices=1) as pd:
        pids = [pd.p_create(sgd(0.1)) for _ in range(3)]
        assert pids == [0, 1, 2]
        assert pd.particle_ids() == [0, 1, 2]
        # particles are distinct random inits
        w0 = pd.p_params(0)["w"]
        w1 = pd.p_params(1)["w"]
        assert float(jnp.abs(w0 - w1).max()) > 1e-3


def test_gather_all_to_all():
    """The paper's Fig. 1 _gather pattern."""
    def _gather(particle):
        others = [p for p in particle.particle_ids() if p != particle.pid]
        futures = {p: particle.get(p) for p in others}
        views = {p: f.wait() for p, f in futures.items()}
        return {p: v.view().parameters()["w"].sum() for p, v in views.items()}

    with PushDistribution(_module(), num_devices=1) as pd:
        pids = [pd.p_create(receive={"GATHER": _gather}) for _ in range(4)]
        res = pd.p_wait([pd.p_launch(pids[0], "GATHER")])[0]
        assert sorted(res) == [1, 2, 3]


def test_views_are_snapshots():
    """A view taken before an update must not see the update (read-only copy)."""
    with PushDistribution(_module(), num_devices=1) as pd:
        a = pd.p_create(sgd(0.5))
        b = pd.p_create(sgd(0.5))
        view = pd.particles[b].get(a).wait()
        before = view.parameters()["w"]
        pd.particles[a].step(_batch()).wait()
        after = pd.p_params(a)["w"]
        assert float(jnp.abs(before - after).max()) > 0  # particle moved
        assert jnp.array_equal(view.parameters()["w"], before)


def test_step_trains():
    with PushDistribution(_module(), num_devices=1) as pd:
        pid = pd.p_create(sgd(0.1))
        batch = _batch()
        l0 = float(pd.particles[pid].step(batch).wait())
        for _ in range(50):
            l = float(pd.particles[pid].step(batch).wait())
        assert l < l0 * 0.5


def test_ensemble_predict_averages():
    with PushDistribution(_module(), num_devices=1) as pd:
        for _ in range(3):
            pd.p_create()
        batch = _batch()
        pred = pd.p_predict(batch)
        outs = [pd.particles[p].forward(batch).wait() for p in pd.particle_ids()]
        manual = sum(outs) / len(outs)
        assert jnp.abs(pred - manual).max() < 1e-5


def test_particle_cache_swaps():
    """More particles than cache_size -> LRU swap traffic is recorded."""
    with PushDistribution(_module(), num_devices=1, cache_size=2) as pd:
        pids = [pd.p_create(sgd(0.1)) for _ in range(4)]
        for _ in range(3):
            pd.p_wait([pd.particles[p].step(_batch()) for p in pids])
        assert pd.nel.stats["swaps_out"] > 0
        assert pd.nel.stats["swaps_in"] >= pd.nel.stats["swaps_out"]


def test_unknown_message_raises():
    with PushDistribution(_module(), num_devices=1) as pd:
        pd.p_create()
        with pytest.raises(KeyError):
            pd.p_launch(0, "NO_SUCH_MSG")


def test_handler_error_propagates_via_future():
    def bad(particle):
        raise ValueError("boom")

    with PushDistribution(_module(), num_devices=1) as pd:
        pid = pd.p_create(receive={"BAD": bad})
        fut = pd.p_launch(pid, "BAD")
        with pytest.raises(ValueError, match="boom"):
            fut.wait()


def test_concurrent_sends_from_handler():
    """Handlers can send+wait on other particles without deadlock."""
    def relay(particle, depth):
        if depth == 0:
            return particle.pid
        nxt = (particle.pid + 1) % len(particle.particle_ids())
        return particle.send(nxt, "RELAY", depth - 1).wait()

    with PushDistribution(_module(), num_devices=1) as pd:
        pids = [pd.p_create(receive={"RELAY": relay}) for _ in range(3)]
        out = pd.p_wait([pd.p_launch(pids[0], "RELAY", 5)])[0]
        assert out == (0 + 5) % 3 == 2
