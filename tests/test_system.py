"""End-to-end behaviour tests: the paper's three BDL algorithms train real
(reduced) models through the particle runtime, and the dry-run launcher
lowers + compiles against the production mesh in a subprocess."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.bdl import DeepEnsemble, MultiSWAG, SteinVGD
from repro.core import ParticleModule
from repro.data.loader import DataLoader
from repro.models import api
from repro.optim import adam, sgd

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _vit_module():
    cfg = configs.get("vit-mnist").smoke().replace(n_units=2)
    return ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0],
        cfg=cfg), cfg


def _loader(cfg, n=3):
    dl = DataLoader(cfg, batch_size=8, num_batches=n, seed=0)
    return [jax.tree.map(jnp.asarray, b) for b in dl]


def test_deep_ensemble_end_to_end():
    mod, cfg = _vit_module()
    data = _loader(cfg)
    with DeepEnsemble(mod, num_devices=1) as de:
        pids, losses = de.bayes_infer(data, epochs=3, optimizer=adam(1e-3),
                                      num_particles=3)
        assert len(losses) == 3
        assert all(np.isfinite(l) for l in losses)
        pred = de.posterior_pred(data[0])
        assert pred.shape == (8, cfg.vocab_size)


def test_multiswag_end_to_end():
    mod, cfg = _vit_module()
    data = _loader(cfg)
    with MultiSWAG(mod, num_devices=1) as ms:
        pids, _ = ms.bayes_infer(data, epochs=3, optimizer=adam(1e-3),
                                 num_particles=2, pretrain_epochs=1, max_rank=4)
        for pid in pids:
            assert int(ms.push_dist.particles[pid].state["swag"]["rank"]) == 2
        pred = ms.sample_predict(data[0], samples_per_particle=2)
        assert pred.shape == (8, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(pred)))


def test_svgd_end_to_end():
    mod, cfg = _vit_module()
    data = _loader(cfg, n=2)
    with SteinVGD(mod, num_devices=1) as sv:
        pids, losses = sv.bayes_infer(data, epochs=5, num_particles=3,
                                      lengthscale=-1.0, lr=2e-3)
        assert len(pids) == 3
        assert all(np.isfinite(l) for l in losses)
        # particles stay distinct (repulsion, distinct inits)
        w = [jax.flatten_util.ravel_pytree(sv.push_dist.p_params(p))[0]
             for p in pids]
        assert float(jnp.abs(w[0] - w[1]).max()) > 1e-4


def test_training_reduces_loss_lm():
    """Compiled-path ensemble training on a tiny LM actually learns."""
    from repro.core import functional
    cfg = configs.get("qwen1.5-0.5b").smoke().replace(n_units=2)
    mod = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)
    data = [jax.tree.map(jnp.asarray, b) for b in
            DataLoader(cfg, batch_size=4, seq_len=32, num_batches=4, seed=1)]
    stacked = functional.init_stacked(mod, 2, jax.random.PRNGKey(0))
    opt = adam(3e-3)
    opt_state = jax.vmap(opt.init)(stacked)
    step = jax.jit(functional.ensemble_step(mod.loss, opt))
    first = None
    for epoch in range(6):
        for b in data:
            stacked, opt_state, losses = step(stacked, opt_state, b)
            if first is None:
                first = float(losses.mean())
    last = float(losses.mean())
    assert last < first * 0.9, (first, last)


@pytest.mark.slow
@pytest.mark.skipif(not hasattr(jax, "set_mesh"),
                    reason="dryrun needs the explicit-sharding API "
                           "(jax.set_mesh) in the subprocess")
def test_dryrun_subprocess_production_mesh():
    """Deliverable (e) check: lower+compile on the 16x16 production mesh in a
    fresh process (512 forced host devices)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen1.5-0.5b",
         "--shape", "decode_32k", "--mesh", "single", "--out",
         "/tmp/test_dryrun"],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
        capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.load(open("/tmp/test_dryrun/qwen1.5-0.5b__decode_32k__single.json"))
    assert rec["status"] == "ok", rec
    assert rec["flops_per_device"] > 0
