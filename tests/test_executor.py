"""Executor semantics (paper §4.2 runtime): per-particle FIFO mailboxes,
fixed thread count, cross-device concurrency, context switching on wait,
bounded queues, graceful shutdown — and the backend seam: ``nel`` and
``compiled`` backends must produce numerically matching posteriors.

The Executor is jax-free by design (device residency is injected by the
NEL), so the scheduling tests below exercise it directly with plain
Python callables — no accelerator state involved.
"""
import threading
import time

import jax
import jax.numpy as jnp
import pytest

from repro.bdl import DeepEnsemble, MultiSWAG, SteinVGD
from repro.core import Executor, ParticleModule, PushDistribution
from repro.optim import sgd


def _executor(n_devices=1, **kw):
    ex = Executor(n_devices, **kw)
    for pid in range(8):
        ex.add_particle(pid, pid % n_devices)
    return ex


# ---------------------------------------------------------------------------
# scheduling semantics
# ---------------------------------------------------------------------------

def test_mailbox_fifo_per_particle():
    """Messages to one particle run in send order; interleaved sends to a
    second particle must not reorder them."""
    ex = _executor()
    log = []
    futs = []
    for i in range(200):
        futs.append(ex.submit(0, lambda i=i: log.append(("p0", i))))
        futs.append(ex.submit(1, lambda i=i: log.append(("p1", i))))
    for f in futs:
        f.wait()
    ex.shutdown()
    for pid in ("p0", "p1"):
        seq = [i for p, i in log if p == pid]
        assert seq == sorted(seq), f"{pid} ran out of FIFO order"
    assert len(log) == 400


def test_no_thread_growth_across_1k_dispatches():
    """Workers are created once at construction: 1k dispatches (device and
    lightweight) must not create a single extra thread."""
    ex = _executor()
    # warm up, then snapshot
    ex.submit(0, lambda: None).wait()
    before = threading.active_count()
    futs = [ex.submit(i % 8, lambda: None, lightweight=(i % 3 == 0))
            for i in range(1000)]
    during = threading.active_count()
    for f in futs:
        f.wait()
    after = threading.active_count()
    ex.shutdown()
    assert during <= before
    assert after <= before
    assert ex.stats()["completed"] >= 1001
    assert ex.stats()["threads"] == ex.num_threads


def test_cross_device_send_concurrency():
    """Two particles on different devices run truly concurrently: each
    handler blocks on a shared barrier that only opens when both arrived."""
    ex = _executor(n_devices=2)
    barrier = threading.Barrier(2, timeout=10)
    futs = [ex.submit(0, barrier.wait), ex.submit(1, barrier.wait)]
    # both resolve only if dev0's and dev1's loops overlap in time
    for f in futs:
        f.wait(timeout=10)
    ex.shutdown()


def test_nested_send_and_wait_context_switch():
    """A handler that waits on work queued behind it on the SAME device must
    not deadlock — the worker context-switches into its queue (paper §4.2)."""
    ex = _executor(n_devices=1)

    def outer():
        inner = ex.submit(1, lambda: "inner-done")
        return inner.wait(timeout=10)

    assert ex.submit(0, outer).wait(timeout=10) == "inner-done"
    ex.shutdown()


def test_bounded_queue_backpressure():
    """External submitters block once a device queue holds max_pending
    messages — memory cannot grow without bound."""
    ex = Executor(1, max_pending=4)
    ex.add_particle(0, 0)
    release = threading.Event()
    first = ex.submit(0, lambda: release.wait(10))
    depths = []

    def flood():
        for _ in range(12):
            ex.submit(0, lambda: None)

    t = threading.Thread(target=flood, daemon=True)
    t.start()
    time.sleep(0.3)
    depths.append(max(ex.queue_depths()))
    assert t.is_alive(), "submitter should be blocked on the full queue"
    assert depths[0] <= 4
    release.set()
    t.join(timeout=10)
    assert not t.is_alive()
    ex.drain(timeout=10)
    ex.shutdown()


def test_clean_shutdown_with_inflight_work():
    """shutdown(drain=True) finishes queued + running messages before the
    loops stop; nothing is dropped, no waiter hangs."""
    ex = _executor()
    done = []
    futs = [ex.submit(0, lambda i=i: (time.sleep(0.02), done.append(i))[1])
            for i in range(10)]
    ex.shutdown()  # drain=True default: all 10 must have run
    assert sorted(done) == list(range(10))
    for f in futs:
        f.wait(timeout=1)
    with pytest.raises(RuntimeError):
        ex.submit(0, lambda: None)


def test_shutdown_rejects_leftovers_without_drain():
    ex = _executor()
    block = threading.Event()
    ex.submit(0, lambda: block.wait(5))
    stuck = [ex.submit(0, lambda: None) for _ in range(3)]
    ex.shutdown(drain=False, timeout=1)
    block.set()
    # queued-behind work is rejected so waiters never hang
    rejected = 0
    for f in stuck:
        try:
            f.wait(timeout=5)
        except RuntimeError:
            rejected += 1
    assert rejected >= 1


def test_dispatch_stats_wait_vs_run():
    ex = _executor()
    futs = [ex.submit(0, lambda: time.sleep(0.01)) for _ in range(5)]
    for f in futs:
        f.wait()
    st = ex.stats()
    ex.shutdown()
    assert st["dispatched"] == 5 and st["completed"] == 5
    assert st["run_time_s"] >= 5 * 0.01 * 0.5
    # later messages queued behind earlier ones -> nonzero wait time
    assert st["wait_time_s"] > 0
    assert st["max_queue_depth"] >= 2


def test_wait_timeout_fires_on_busy_queue():
    """A handler's wait(timeout) must raise even while the device queue keeps
    serving other work — a busy loop cannot starve the deadline."""
    ex = _executor()
    never = threading.Event()  # a future that never resolves
    flood_stop = threading.Event()

    def keep_busy():
        if not flood_stop.is_set():
            ex.submit(2, keep_busy)  # queue never drains
        time.sleep(0.005)

    def outer():
        from repro.core.messages import PFuture
        dangling = PFuture()
        t0 = time.monotonic()
        try:
            dangling.wait(timeout=0.3)
        except TimeoutError:
            return time.monotonic() - t0
        return None

    ex.submit(2, keep_busy)
    elapsed = ex.submit(0, outer).wait(timeout=10)
    flood_stop.set()
    ex.shutdown(drain=False, timeout=2)
    assert elapsed is not None, "wait(timeout) never raised on a busy queue"
    assert elapsed < 5.0


def test_errors_propagate_and_loop_survives():
    """A raising handler rejects its future but must not kill the worker."""
    ex = _executor()

    def boom():
        raise ValueError("boom")

    f1 = ex.submit(0, boom)
    with pytest.raises(ValueError, match="boom"):
        f1.wait(timeout=5)
    assert ex.submit(0, lambda: 42).wait(timeout=5) == 42
    ex.shutdown()


# ---------------------------------------------------------------------------
# NEL integration: no thread growth through the full particle runtime
# ---------------------------------------------------------------------------

def _tiny_module():
    def init(rng):
        return {"w": jax.random.normal(rng, (3, 2)) * 0.5}

    def loss(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] - y) ** 2), {}

    def fwd(p, batch):
        return batch[0] @ p["w"]

    return ParticleModule(init, loss, fwd)


def _tiny_data():
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 3))
    return [(x, x @ jnp.ones((3, 2)))]


def test_nel_dispatch_uses_persistent_loops():
    with PushDistribution(_tiny_module(), num_devices=1) as pd:
        pids = [pd.p_create(sgd(0.1)) for _ in range(4)]
        batch = _tiny_data()[0]
        pd.p_wait([pd.particles[p].step(batch) for p in pids])  # warm up jit
        before = threading.active_count()
        for _ in range(20):
            pd.p_wait([pd.particles[p].step(batch) for p in pids])
        assert threading.active_count() <= before
        st = pd.nel.executor.stats()
        assert st["dispatched"] >= 84
        assert st["threads"] == pd.nel.executor.num_threads
        # the unified stats surface carries the lifecycle section too
        full = pd.stats()
        lc = full["lifecycle"]
        assert lc["capacity"] == 4 and lc["live"] == 4
        assert lc["free_slots"] == 0
        assert lc["clones"] == 0 and lc["kills"] == 0
        assert lc["rebalances"] == 0
        assert lc["mask_invalidations"] >= 4     # one per registration
        # ... and the placement section (the 2D plan + its footprint)
        pl = full["placement"]
        assert pl["mode"] == "tp" and pl["particle_axis"] == "data"
        assert pl["model_axis"] == "model"
        if pd.placement.mesh is None:
            assert pl["mesh_shape"] is None
            assert pl["model_axis_size"] == 1
        else:
            assert pl["mesh_shape"] == {
                a: int(pd.placement.mesh.shape[a])
                for a in pd.placement.mesh.axis_names}
            assert pl["model_axis_size"] >= 1
        assert pl["per_device_param_bytes"] > 0   # params are registered
        assert pl["reshards"] == full["store"]["device_puts"]
        pd.p_kill(pids[-1])
        pd.p_clone(pids[0])
        lc2 = pd.stats()["lifecycle"]
        assert lc2["kills"] == 1 and lc2["clones"] == 1
        assert lc2["live"] == 4 and lc2["capacity"] == 4
        assert lc2["generation"] == lc["generation"]   # churn is free


# ---------------------------------------------------------------------------
# backend seam: nel vs compiled must match numerically
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,kw", [
    (DeepEnsemble, dict(optimizer=sgd(0.05), num_particles=3)),
    (MultiSWAG, dict(optimizer=sgd(0.05), num_particles=3, max_rank=4)),
    (SteinVGD, dict(num_particles=3, lr=0.05, lengthscale=1.0)),
])
def test_backend_compiled_matches_nel(algo, kw):
    data = _tiny_data()
    preds, params = {}, {}
    for backend in ("nel", "compiled"):
        with algo(_tiny_module(), num_devices=1, seed=0, backend=backend) as a:
            pids, _ = a.bayes_infer(data, 3, **kw)
            preds[backend] = a.posterior_pred(data[0])
            params[backend] = [a.push_dist.p_params(p)["w"] for p in pids]
    assert float(jnp.abs(preds["nel"] - preds["compiled"]).max()) < 1e-4
    for pn, pc in zip(params["nel"], params["compiled"]):
        assert float(jnp.abs(pn - pc).max()) < 1e-4


def test_backend_compiled_multiswag_collects_matching_moments():
    data = _tiny_data()
    ranks, means = {}, {}
    for backend in ("nel", "compiled"):
        with MultiSWAG(_tiny_module(), num_devices=1, seed=0,
                       backend=backend) as ms:
            pids, _ = ms.bayes_infer(data, 3, optimizer=sgd(0.05),
                                     num_particles=2, max_rank=4)
            sts = [ms.push_dist.particles[p].state["swag"] for p in pids]
            ranks[backend] = [int(s["rank"]) for s in sts]
            means[backend] = [s["mean"]["w"] for s in sts]
    assert ranks["nel"] == ranks["compiled"] == [3, 3]
    for mn, mc in zip(means["nel"], means["compiled"]):
        assert float(jnp.abs(mn - mc).max()) < 1e-4


def test_backend_compiled_falls_back_without_fused_form():
    """An Infer subclass without _fused_infer runs the NEL path under
    backend="compiled" (transparent fallback)."""
    from repro.bdl.infer import Infer

    class NelOnly(Infer):
        def _nel_infer(self, dataloader, epochs, **kw):
            return "nel-path"

    with NelOnly(_tiny_module(), backend="compiled") as alg:
        assert alg.bayes_infer(None, 1) == "nel-path"


def test_invalid_backend_rejected():
    with pytest.raises(ValueError):
        PushDistribution(_tiny_module(), backend="nonsense")
