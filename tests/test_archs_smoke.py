"""Required per-architecture smoke tests: a REDUCED variant of each assigned
family (<=2 pattern units, d_model<=128, <=4 experts) runs one forward /
train step on CPU with correct output shapes and no NaNs, plus one decode
step for decoder families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.synthetic import make_batch
from repro.models import api
from repro.optim import adam

ARCH_IDS = sorted(configs.ALL)


def _smoke_batch(sc, B=2, S=32):
    rng = np.random.default_rng(0)
    if sc.family in ("vision", "pde"):
        return make_batch(sc, rng, B, S)
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if sc.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, sc.n_frames, sc.d_model)), jnp.float32)
    if sc.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, sc.n_prefix_tokens, sc.d_model)), jnp.float32)
    return jax.tree.map(jnp.asarray, batch)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    sc = configs.get(arch).smoke()
    params = api.init_params(jax.random.PRNGKey(0), sc)
    batch = _smoke_batch(sc)
    opt = adam(1e-3)
    opt_state = opt.init(params)

    loss, metrics = api.loss_fn(params, batch, sc)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    grads = jax.grad(lambda p: api.loss_fn(p, batch, sc)[0])(params)
    gn = jax.tree.reduce(lambda a, b: a + jnp.sum(jnp.square(b)), grads, 0.0)
    assert bool(jnp.isfinite(gn)), f"{arch}: non-finite grads"

    new_params, _ = opt.update(params, grads, opt_state)
    l2, _ = api.loss_fn(new_params, batch, sc)
    assert bool(jnp.isfinite(l2))


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if configs.ALL[a].family not in
                                  ("vision", "pde")])
def test_smoke_decode_step(arch):
    sc = configs.get(arch).smoke()
    params = api.init_params(jax.random.PRNGKey(0), sc)
    B = 2
    cache = api.init_cache(sc, B, 16)
    logits, cache = api.decode_step(params, jnp.ones((B,), jnp.int32), cache,
                                    jnp.int32(0), sc)
    assert logits.shape == (B, sc.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: decode NaN"
    # a second step at the next position must also be finite
    logits, cache = api.decode_step(params, jnp.ones((B,), jnp.int32), cache,
                                    jnp.int32(1), sc)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_prefill_then_decode_consistency_qwen():
    """prefill(tokens) then decode must match full forward next-token logits."""
    sc = configs.get("qwen1.5-0.5b").smoke()
    params = api.init_params(jax.random.PRNGKey(0), sc)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, sc.vocab_size)
    logits_pre, caches = api.prefill(params, {"tokens": toks}, sc, max_len=16)
    # full forward logits at the last position
    out, _ = api.forward(params, {"tokens": toks, "labels": toks}, sc)
    from repro.models.api import _lm_logits
    full_last = _lm_logits(params, out[:, -1:], sc)[:, 0]
    assert jnp.abs(logits_pre - full_last).max() < 1e-3

    # decode one more token: cache from prefill must work
    nxt = jnp.argmax(logits_pre, -1).astype(jnp.int32)
    logits_dec, _ = api.decode_step(params, nxt, caches, jnp.int32(8), sc)
    # reference: full forward over 9 tokens
    toks9 = jnp.concatenate([toks, nxt[:, None]], axis=1)
    out9, _ = api.forward(params, {"tokens": toks9, "labels": toks9}, sc)
    ref = _lm_logits(params, out9[:, -1:], sc)[:, 0]
    assert jnp.abs(logits_dec - ref).max() < 1e-2


def test_gemma_sliding_window_decode_ring_cache():
    """gemma3 smoke: decode beyond the window uses the ring cache correctly."""
    sc = configs.get("gemma3-4b").smoke()
    assert sc.sliding_window == 16
    params = api.init_params(jax.random.PRNGKey(0), sc)
    S = 24  # > window
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, sc.vocab_size)
    # decode step-by-step from scratch
    cache = api.init_cache(sc, 1, S + 1)
    for t in range(S):
        logits, cache = api.decode_step(params, toks[:, t], cache,
                                        jnp.int32(t), sc)
    # reference: full forward
    out, _ = api.forward(params, {"tokens": toks, "labels": toks}, sc)
    from repro.models.api import _lm_logits
    ref = _lm_logits(params, out[:, -1:], sc)[:, 0]
    assert jnp.abs(logits - ref).max() < 1e-2


def test_gemma_prefill_then_decode_ring_roll():
    """prefill with prompt > window, then decode: ring slots must align."""
    sc = configs.get("gemma3-4b").smoke()
    params = api.init_params(jax.random.PRNGKey(0), sc)
    S = 24  # > window (16)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, S), 0, sc.vocab_size)
    logits_pre, caches = api.prefill(params, {"tokens": toks}, sc, max_len=32)
    nxt = jnp.argmax(logits_pre, -1).astype(jnp.int32)
    logits_dec, _ = api.decode_step(params, nxt, caches, jnp.int32(S), sc)
    toks2 = jnp.concatenate([toks, nxt[:, None]], axis=1)
    out2, _ = api.forward(params, {"tokens": toks2, "labels": toks2}, sc)
    from repro.models.api import _lm_logits
    ref = _lm_logits(params, out2[:, -1:], sc)[:, 0]
    assert jnp.abs(logits_dec - ref).max() < 1e-2
