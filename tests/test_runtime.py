"""repro.runtime — the shared plan/compile/execute layer (DESIGN.md §8).

Covers the cache-key anatomy (spec identity, placement, store generation,
bucketed shapes), cross-subsystem program reuse (train -> predict ->
serve over one store), the Runtime protocol seam, the unified
PushDistribution.stats() surface, and the AOT serialization hook.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bdl import DeepEnsemble
from repro.core import ParticleModule, Placement, PushDistribution
from repro.optim import sgd
from repro.runtime import (BACKENDS, CompiledRuntime, NelRuntime,
                           ProgramCache, ProgramSpec, Runtime, bucket_size,
                           global_cache, ident, jit_program, make_runtime,
                           pad_rows, specs)
from repro.serve import PredictiveEngine


def _module():
    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"w": jax.random.normal(k1, (3, 4)) * 0.5,
                "b": jnp.zeros((4,))}

    def loss(p, b):
        return jnp.mean((b[0] @ p["w"] + p["b"] - b[1]) ** 2), {}

    def fwd(p, batch):
        return batch["x"] @ p["w"] + p["b"]

    return ParticleModule(init, loss, fwd)


def _data(m=8):
    x = jax.random.normal(jax.random.PRNGKey(0), (m, 3))
    return [(x, x @ jnp.ones((3, 4)))], x


# ---------------------------------------------------------------------------
# ProgramCache key anatomy
# ---------------------------------------------------------------------------

def _double_spec(tag="double"):
    return ProgramSpec(name=tag, key=(tag,),
                       make=lambda ctx: lambda s, b: (s, b * 2.0),
                       in_kinds=("state", "replicated"))


def test_cache_hit_miss_cold_stats():
    cache = ProgramCache()
    spec = _double_spec()
    st = jnp.ones((2, 3))
    b = jnp.ones((4,))
    cache.run(spec, st, b)
    assert cache.snapshot_stats() == {
        "hits": 0, "misses": 1, "cold_compiles": 1, "evictions": 0,
        "programs": 1, "hit_rate": 0.0}
    cache.run(spec, st, b)
    s = cache.snapshot_stats()
    assert s["hits"] == 1 and s["cold_compiles"] == 1
    assert s["hit_rate"] == 0.5


def test_cache_distinguishes_shapes_and_specs():
    cache = ProgramCache()
    spec = _double_spec()
    st = jnp.ones((2, 3))
    cache.run(spec, st, jnp.ones((4,)))
    cache.run(spec, st, jnp.ones((8,)))          # new shape: miss
    cache.run(_double_spec("other"), st, jnp.ones((4,)))  # new spec: miss
    assert cache.snapshot_stats()["cold_compiles"] == 3


def test_bucketed_batch_shapes_share_programs():
    """Mixed request sizes inside one power-of-two bucket compile once."""
    cache = ProgramCache()
    spec = _double_spec()
    st = jnp.ones((2, 3))
    for m in (3, 4, 2, 4):
        padded = pad_rows(jnp.ones((m, 5)), bucket_size(m))
        cache.run(spec, st, padded)
    s = cache.snapshot_stats()
    # buckets: 4, 4, 2, 4 -> programs for bucket 4 and bucket 2 only
    assert s["cold_compiles"] == 2 and s["hits"] == 2


def test_placement_change_invalidates():
    cache = ProgramCache()
    spec = _double_spec()
    st = jnp.ones((2, 3))
    b = jnp.ones((4,))
    cache.run(spec, st, b, placement=Placement())
    cache.run(spec, st, b, placement=Placement(particle_axis="other"))
    assert cache.snapshot_stats()["cold_compiles"] == 2
    cache.run(spec, st, b, placement=Placement())   # original still cached
    assert cache.snapshot_stats()["hits"] == 1


def test_replacement_same_2d_plan_is_100pct_warm_hit():
    """ISSUE-7 acceptance: the cache invalidates on mesh-shape / mode
    change and NOTHING else — re-placement onto the same 2D plan through
    a separately-built (but identical) mesh is a pure warm hit."""
    from repro.launch.mesh import make_bench_mesh
    n = len(jax.devices())
    m = 2 if n > 1 and n % 2 == 0 else 1
    cache = ProgramCache()
    spec = _double_spec()
    st, b = jnp.ones((2, 3)), jnp.ones((4,))
    cache.run(spec, st, b,
              placement=Placement(mesh=make_bench_mesh(n, model=m)))
    # same plan, rebuilt mesh object: 100% warm, zero recompiles
    for _ in range(3):
        cache.run(spec, st, b,
                  placement=Placement(mesh=make_bench_mesh(n, model=m)))
    s = cache.snapshot_stats()
    assert s["cold_compiles"] == 1 and s["hits"] == 3, s
    # mode change invalidates
    cache.run(spec, st, b, placement=Placement(
        mesh=make_bench_mesh(n, model=m), mode="fsdp_tp"))
    assert cache.snapshot_stats()["cold_compiles"] == 2
    if m == 2:  # mesh-shape change invalidates (needs >= 2 devices)
        cache.run(spec, st, b,
                  placement=Placement(mesh=make_bench_mesh(n, model=1)))
        assert cache.snapshot_stats()["cold_compiles"] == 3


def test_placement_plan_equality_and_hash():
    """Placement equality is by plan value, not mesh object identity."""
    from repro.launch.mesh import make_bench_mesh
    n = len(jax.devices())
    a = Placement(mesh=make_bench_mesh(n))
    b = Placement(mesh=make_bench_mesh(n))
    assert a == b and hash(a) == hash(b)
    assert a != Placement(mesh=make_bench_mesh(n), mode="fsdp_tp")
    assert a != Placement()                 # mesh vs no mesh
    assert Placement() == Placement() and hash(Placement()) == \
        hash(Placement())


def test_state_token_invalidates():
    cache = ProgramCache()
    spec = _double_spec()
    st, b = jnp.ones((2, 3)), jnp.ones((4,))
    cache.run(spec, st, b, state_token=1)
    cache.run(spec, st, b, state_token=1)
    cache.run(spec, st, b, state_token=2)
    s = cache.snapshot_stats()
    assert s["hits"] == 1 and s["cold_compiles"] == 2


def test_lru_eviction_bounds_cache():
    cache = ProgramCache(max_programs=2)
    spec = _double_spec()
    st = jnp.ones((2, 3))
    for m in (1, 2, 3):
        cache.run(spec, st, jnp.ones((m,)))
    s = cache.snapshot_stats()
    assert s["programs"] == 2 and s["evictions"] == 1
    cache.run(spec, st, jnp.ones((1,)))   # evicted: recompiles
    assert cache.snapshot_stats()["cold_compiles"] == 4


def test_donation_plan_is_part_of_key():
    """dataclasses.replace(spec, donate=()) variants must not collide
    with the donating program (compiled_ensemble_step vs epoch loop)."""
    import dataclasses
    cache = ProgramCache()
    spec = ProgramSpec(name="don", key=("don",),
                       make=lambda ctx: lambda s, b: (
                           jax.tree.map(lambda x: x + 1.0, s), b),
                       in_kinds=("state", "replicated"),
                       out_kinds=("in:0", "replicated"), donate=(0,))
    no_don = dataclasses.replace(spec, donate=())
    st = jnp.zeros((2, 3))
    cache.run(spec, st, jnp.ones((4,)))          # donates st
    out, _ = cache.run(no_don, st2 := jnp.zeros((2, 3)), jnp.ones((4,)))
    assert cache.snapshot_stats()["cold_compiles"] == 2
    # the non-donating program left its input alive
    assert not st2.is_deleted()
    np.testing.assert_allclose(np.asarray(out), 1.0)


def test_compile_helpers_share_runtime_programs_via_state_token():
    """functional.compile_* with state_token=store.generation() returns
    the exact program the Runtime's epoch loop lowered (a cache hit)."""
    from repro.core import functional
    mod = _module()
    data, x = _data()
    opt = sgd(0.05)
    with DeepEnsemble(mod, backend="compiled") as de:
        de.bayes_infer(data, 2, optimizer=opt, num_particles=4)
        hits0 = global_cache().snapshot_stats()["hits"]
        tok = de.store.generation()
        mask = de.store.active_mask()
        st = de.store.checkout("params", None)
        ost = de.store.checkout("opt_state", None)
        step = functional.compile_ensemble_step(
            mod.loss, opt, de.placement, st, ost, data[0], mask,
            state_token=tok)
        assert global_cache().snapshot_stats()["hits"] == hits0 + 1
        np_, no_, _ = step(st, ost, data[0], mask)
        de.store.commit("params", np_)
        de.store.commit("opt_state", no_)


def test_ident_is_stable_and_distinct():
    f, g = (lambda x: x), (lambda x: x)
    assert ident(f) == ident(f)
    assert ident(f) != ident(g)


# ---------------------------------------------------------------------------
# spec lowering semantics
# ---------------------------------------------------------------------------

def test_donated_state_round_trips():
    """donate + "in:0" round trip: the epoch-loop pattern — output can be
    fed straight back as the next input with the same cache key."""
    cache = ProgramCache()
    spec = ProgramSpec(name="inc", key=("inc",),
                       make=lambda ctx: lambda s, b: (
                           jax.tree.map(lambda x: x + b.sum(), s), b),
                       in_kinds=("state", "replicated"),
                       out_kinds=("in:0", "replicated"), donate=(0,))
    st = {"w": jnp.zeros((2, 3))}
    b = jnp.ones((4,))
    for _ in range(3):
        st, b = cache.run(spec, st, b)
    assert float(st["w"][0, 0]) == 12.0
    s = cache.snapshot_stats()
    assert s["cold_compiles"] == 1 and s["hits"] == 2


def test_bad_kinds_rejected():
    with pytest.raises(ValueError):
        ProgramSpec(name="x", key=("x",), make=lambda ctx: None,
                    in_kinds=("bogus",))
    with pytest.raises(ValueError):
        ProgramSpec(name="x", key=("x",), make=lambda ctx: None,
                    in_kinds=("state",), out_kinds=("bogus",))


def test_jit_program_shares_across_fresh_closures():
    """jit_program keys on (key, shapes) only — fresh closures per call
    (the baselines' and NEL's pattern) still share one program."""
    cache = global_cache()
    before = cache.snapshot_stats()["cold_compiles"]
    x = jnp.ones((3,))
    for i in range(3):
        out = jit_program("t_jp", ("t_jp", "stable"),
                          lambda a: a * 2.0, (x,))(x)
    assert np.allclose(np.asarray(out), 2.0)
    after = cache.snapshot_stats()["cold_compiles"]
    assert after - before == 1


# ---------------------------------------------------------------------------
# Runtime protocol seam
# ---------------------------------------------------------------------------

def test_make_runtime_selects_objects():
    mod = _module()
    with PushDistribution(mod, backend="nel") as pd:
        assert isinstance(pd.runtime, NelRuntime)
        assert isinstance(pd.runtime, Runtime)
        assert pd.backend == "nel"
    with PushDistribution(mod, backend="compiled") as pd:
        assert isinstance(pd.runtime, CompiledRuntime)
        assert pd.backend == "compiled"
    # a bad backend must raise BEFORE the NodeEventLoop spawns executor
    # threads (nothing would ever shut them down)
    import threading
    n0 = threading.active_count()
    with pytest.raises(ValueError):
        PushDistribution(mod, backend="bogus")
    assert threading.active_count() == n0
    assert BACKENDS == ("nel", "compiled")


def test_pd_stats_merges_executor_and_cache():
    mod = _module()
    data, x = _data()
    with DeepEnsemble(mod, backend="compiled") as de:
        de.bayes_infer(data, 2, optimizer=sgd(0.05), num_particles=2)
        st = de.push_dist.stats()
    assert st["backend"] == "compiled"
    assert "wait_time_s" in st["executor"] and "run_time_s" in st["executor"]
    for k in ("hits", "misses", "cold_compiles", "hit_rate"):
        assert k in st["program_cache"], k
    assert st["store"]["commits"] >= 1


def test_nel_particles_share_one_compiled_step():
    """Two NEL particles stepping the same module compile ONE program:
    the NEL backend's compiles go through the shared layer too."""
    mod = _module()
    data, x = _data()
    before = global_cache().snapshot_stats()["cold_compiles"]
    with DeepEnsemble(mod, backend="nel") as de:
        de.bayes_infer(data, 2, optimizer=sgd(0.05), num_particles=3)
    after = global_cache().snapshot_stats()["cold_compiles"]
    # one value_and_grad program total (optimizer update runs un-jitted
    # inside the dispatch, as before)
    assert after - before == 1


# ---------------------------------------------------------------------------
# cross-subsystem reuse over one store
# ---------------------------------------------------------------------------

def test_train_then_serve_zero_recompiles_when_version_unchanged():
    mod = _module()
    data, x = _data()
    with DeepEnsemble(mod, backend="compiled") as de:
        de.bayes_infer(data, 2, optimizer=sgd(0.05), num_particles=4)
        v = de.store.version("params")
        with de.posterior_predictive(kind="regress") as svc:
            svc.predict_batch({"x": x})          # cold compile here
        cold = global_cache().snapshot_stats()["cold_compiles"]
        # same store, same version, fresh engine: every program is a hit
        assert de.store.version("params") == v
        with de.posterior_predictive(kind="regress") as svc2:
            svc2.predict_batch({"x": x})
            svc2.predict_batch({"x": x[:5]})     # same bucket as 8: pad
        assert global_cache().snapshot_stats()["cold_compiles"] == cold


def test_train_more_epochs_reuses_program_across_calls():
    mod = _module()
    data, x = _data()
    opt = sgd(0.05)
    with DeepEnsemble(mod, backend="compiled") as de:
        de.bayes_infer(data, 2, optimizer=opt, num_particles=4)
        cold = global_cache().snapshot_stats()["cold_compiles"]
        pids = de.push_dist.particle_ids()
        de._fused_epochs(pids, data, 2, optimizer=opt)
        assert global_cache().snapshot_stats()["cold_compiles"] == cold


def test_particle_set_change_invalidates_predict():
    """p_create bumps the store generation: the fused predict program
    recompiles (new n), and stale-program reuse is impossible."""
    mod = _module()
    data, x = _data()
    with DeepEnsemble(mod, backend="compiled") as de:
        de.bayes_infer(data, 1, optimizer=sgd(0.05), num_particles=2)
        de.posterior_pred({"x": x})
        cold = global_cache().snapshot_stats()["cold_compiles"]
        de.push_dist.p_create(sgd(0.05))
        de.posterior_pred({"x": x})
        assert global_cache().snapshot_stats()["cold_compiles"] == cold + 1


def test_engine_private_cache_isolated():
    """cache= lets an engine run on a private ProgramCache (tests /
    multi-tenant isolation) without touching the process-wide one."""
    mod = _module()
    data, x = _data()
    cache = ProgramCache()
    with DeepEnsemble(mod, backend="compiled") as de:
        de.bayes_infer(data, 1, optimizer=sgd(0.05), num_particles=2)
        g_before = global_cache().snapshot_stats()["cold_compiles"]
        eng = PredictiveEngine(mod.forward, store=de.store, kind="regress",
                               cache=cache)
        eng.predict({"x": x})
        eng.predict({"x": x})
        s = cache.snapshot_stats()
        assert s["cold_compiles"] == 1 and s["hits"] == 1
        assert global_cache().snapshot_stats()["cold_compiles"] == g_before


def test_fused_predict_matches_nel_predict():
    """Same store, both runtimes: the fused predict program and the NEL
    per-particle average agree (the seam invariant, now object-based)."""
    mod = _module()
    data, x = _data()
    with DeepEnsemble(mod, backend="compiled") as de:
        de.bayes_infer(data, 2, optimizer=sgd(0.05), num_particles=3)
        pd = de.push_dist
        fused = np.asarray(pd.runtime.predict(pd, {"x": x}))
        nel = np.asarray(NelRuntime(pd).predict(pd, {"x": x}))
        assert np.abs(fused - nel).max() < 1e-5


# ---------------------------------------------------------------------------
# AOT serialization hook
# ---------------------------------------------------------------------------

def test_aot_dump_and_preload(tmp_path):
    jax_export = pytest.importorskip("jax.export")
    cache = ProgramCache()
    spec = _double_spec("aot")
    st, b = jnp.ones((2, 3)), jnp.arange(4.0)
    cache.run(spec, st, b)
    manifest = cache.aot_dump(str(tmp_path))
    assert manifest and all(v == "aot" for v in manifest.values())
    blobs = list(tmp_path.glob("*.jaxprog"))
    assert len(blobs) == len(manifest)

    fresh = ProgramCache()
    fresh.preload(spec, None, (st, b), blobs[0].read_bytes())
    _, out = fresh.run(spec, st, b)
    assert np.allclose(np.asarray(out), np.arange(4.0) * 2.0)
    s = fresh.snapshot_stats()
    # served from the preloaded artifact: a miss but NOT a cold compile
    assert s["misses"] == 1 and s["cold_compiles"] == 0
