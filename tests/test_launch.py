"""Launch-layer unit tests that run on the single CPU device: plans, batch
specs, cache specs, and abstract step building (trace-only via eval_shape
on a 1x1 mesh — the full 512-device compile lives in test_system.py's slow
subprocess test)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs import INPUT_SHAPES
from repro.launch import steps as S
from repro.launch import mesh as mesh_compat
from repro.launch.plans import plan_for

# Building sharded steps needs the explicit-sharding API (AxisType +
# jax.set_mesh); plan/cost tests below run on any jax version.
needs_explicit_sharding = pytest.mark.skipif(
    not (mesh_compat.HAS_AXIS_TYPES and hasattr(jax, "set_mesh")),
    reason="installed jax lacks the explicit-sharding API (AxisType/set_mesh)")


def tiny_mesh():
    return mesh_compat.make_mesh((1, 1), ("data", "model"))


# ---------------------------------------------------------------------------
# mesh factory validation (ISSUE-7 satellite): bad axis sizes raise a
# clear ValueError, not a cryptic reshape/XLA error
# ---------------------------------------------------------------------------

def test_make_bench_mesh_rejects_non_divisible_model():
    n = len(jax.devices())
    with pytest.raises(ValueError, match="does not divide"):
        mesh_compat.make_bench_mesh(n, model=n + 1)
    with pytest.raises(ValueError, match="positive"):
        mesh_compat.make_bench_mesh(n, model=0)


def test_make_mesh_rejects_oversized_shape():
    n = len(jax.devices())
    with pytest.raises(ValueError, match="devices"):
        mesh_compat.make_mesh((n + 1,), ("data",))
    with pytest.raises(ValueError, match="disagree"):
        mesh_compat.make_mesh((1, 1), ("data",))
    # valid submesh shapes still build (the 1x1 trace mesh everywhere)
    assert tiny_mesh() is not None


def test_make_bench_mesh_2d_axes():
    n = len(jax.devices())
    mesh = mesh_compat.make_bench_mesh(n, model=1)
    assert tuple(mesh.axis_names) == ("data", "model")
    assert int(mesh.shape["data"]) == n and int(mesh.shape["model"]) == 1


def test_pick_model_axis_budget():
    # no memory info / no params -> particle-parallel (model=1)
    assert mesh_compat.pick_model_axis(0, 8) == 1
    assert mesh_compat.pick_model_axis(100, 8, device_memory_bytes=None) == 1
    # smallest divisor of n_devices whose shard fits fraction*memory
    assert mesh_compat.pick_model_axis(
        100, 8, device_memory_bytes=1000) == 1
    assert mesh_compat.pick_model_axis(
        1000, 8, device_memory_bytes=1000) == 2
    assert mesh_compat.pick_model_axis(
        2300, 8, device_memory_bytes=1000) == 4
    # never fits: best effort = every device
    assert mesh_compat.pick_model_axis(
        10**9, 8, device_memory_bytes=1000) == 8


@pytest.mark.parametrize("arch", sorted(configs.ARCHS))
@pytest.mark.parametrize("shape", sorted(INPUT_SHAPES))
def test_plans_are_coherent(arch, shape):
    cfg = configs.get(arch)
    shp = INPUT_SHAPES[shape]
    plan = plan_for(cfg, shp)
    assert plan.particles >= 1
    if shp.kind == "train":
        assert shp.global_batch % plan.microbatches == 0
    if plan.particle_axis is not None:
        assert plan.particles % 16 == 0  # must shard over data=16


@needs_explicit_sharding
def test_build_shapes_ensemble_train():
    cfg = configs.get("qwen1.5-0.5b").smoke()
    shp = INPUT_SHAPES["train_4k"]
    plan = plan_for(configs.get("qwen1.5-0.5b"), shp)
    import dataclasses
    plan = dataclasses.replace(plan, particles=2, microbatches=2)
    mesh = tiny_mesh()
    with jax.set_mesh(mesh):
        step, args, sh = S.build(cfg, dataclasses.replace(
            shp, seq_len=32, global_batch=4), plan, mesh)
        out = jax.eval_shape(step, *args)
    # params out matches params in (stacked particle axis preserved)
    assert jax.tree.structure(out[0]) == jax.tree.structure(args[0])
    assert out[2].shape == (2, )  # per-particle losses


@needs_explicit_sharding
def test_build_shapes_svgd_train():
    cfg = configs.get("qwen1.5-0.5b").smoke()
    import dataclasses
    shp = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=32,
                              global_batch=4)
    plan = dataclasses.replace(plan_for(configs.get("qwen1.5-0.5b"),
                                        INPUT_SHAPES["train_4k"]),
                               particles=2, microbatches=2)
    mesh = tiny_mesh()
    with jax.set_mesh(mesh):
        step, args, sh = S.build(cfg, shp, plan, mesh, bdl="svgd")
        out = jax.eval_shape(step, *args)
    assert jax.tree.structure(out[0]) == jax.tree.structure(args[0])


@needs_explicit_sharding
def test_build_decode_cache_roundtrip():
    cfg = configs.get("gemma3-4b").smoke()
    import dataclasses
    shp = dataclasses.replace(INPUT_SHAPES["decode_32k"], seq_len=64,
                              global_batch=2)
    plan = dataclasses.replace(plan_for(configs.get("gemma3-4b"),
                                        INPUT_SHAPES["decode_32k"]),
                               particles=2)
    mesh = tiny_mesh()
    with jax.set_mesh(mesh):
        step, args, sh = S.build(cfg, shp, plan, mesh)
        logits, new_cache = jax.eval_shape(step, *args)
    assert logits.shape == (2, cfg.vocab_size)
    # cache structure is preserved (serve_step is iterable)
    assert jax.tree.structure(new_cache) == jax.tree.structure(args[2])


def test_hlo_cost_trip_counts():
    """hlo_cost multiplies scan-body costs by trip counts."""
    from repro.launch import hlo_cost as hc

    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    x = jnp.ones((8, 8))
    w = jnp.ones((8, 8))
    txt = jax.jit(f).lower(x, w).compile().as_text()
    c = hc.cost(txt)
    expected = 2 * 8 * 8 * 8 * 7  # 7 iterations of an 8x8x8 matmul
    assert c["flops"] == pytest.approx(expected, rel=0.01), c["flops"]
