"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import attention, ref, svgd_rbf, swag_moments


@pytest.mark.parametrize("n,D,bd", [(2, 16, 8), (4, 100, 32), (8, 5000, 2048),
                                    (64, 12345, 4096), (3, 7, 8)])
def test_pairwise_sqdist(n, D, bd):
    t = jax.random.normal(jax.random.PRNGKey(0), (n, D), jnp.float32) * 0.1
    d2 = svgd_rbf.pairwise_sqdist(t, block_d=bd)
    d2r = ref.pairwise_sqdist(t)
    assert jnp.abs(d2 - d2r).max() < 1e-3
    # symmetry + psd-ish basics
    assert jnp.abs(d2 - d2.T).max() < 1e-5
    assert float(d2.min()) >= 0.0


@pytest.mark.parametrize("n,D,bd,ell", [(4, 100, 32, 1.0), (8, 5000, 2048, 1.3),
                                        (16, 50000, 8192, 0.7), (3, 7, 8, 2.0)])
def test_svgd_force(n, D, bd, ell):
    t = jax.random.normal(jax.random.PRNGKey(0), (n, D), jnp.float32) * 0.05
    g = jax.random.normal(jax.random.PRNGKey(1), (n, D), jnp.float32)
    f = svgd_rbf.svgd_force(t, g, ell, block_d=bd)
    fr = ref.svgd_force(t, g, ell)
    rel = jnp.abs(f - fr).max() / (jnp.abs(fr).max() + 1e-9)
    assert rel < 2e-4


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_svgd_force_dtypes(dtype):
    t = (jax.random.normal(jax.random.PRNGKey(0), (4, 257)) * 0.05).astype(dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), (4, 257)).astype(dtype)
    f = svgd_rbf.svgd_force(t.astype(jnp.float32), g.astype(jnp.float32), 1.0,
                            block_d=64)
    assert f.shape == (4, 257)
    assert bool(jnp.all(jnp.isfinite(f)))


def test_swag_moments_streaming():
    """Streaming kernel moments == batch mean/second-moment after n updates."""
    ps = [jax.random.normal(jax.random.PRNGKey(i), (123,)) for i in range(5)]
    mean = jnp.zeros((123,))
    sq = jnp.zeros((123,))
    for i, p in enumerate(ps):
        mean, sq = swag_moments.moments_flat(mean, sq, p, float(i))
    stacked = jnp.stack(ps)
    assert jnp.abs(mean - stacked.mean(0)).max() < 1e-5
    assert jnp.abs(sq - (stacked ** 2).mean(0)).max() < 1e-5


def test_swag_moments_pytree():
    tree = {"a": jnp.ones((7, 3)), "b": jnp.full((11,), 2.0)}
    zeros = jax.tree.map(jnp.zeros_like, tree)
    m, s = swag_moments.update_moments(zeros, zeros, tree, 0.0)
    assert jnp.abs(m["a"] - 1.0).max() < 1e-6
    assert jnp.abs(s["b"] - 4.0).max() < 1e-6


@pytest.mark.parametrize("B,S,H,KVH,hd,causal,qb,kb", [
    (1, 64, 4, 2, 32, True, 16, 16),
    (2, 50, 4, 1, 16, True, 16, 32),
    (1, 128, 8, 8, 64, False, 32, 32),
    (2, 33, 2, 2, 8, True, 16, 16),
])
def test_flash_kernel_vs_ref(B, S, H, KVH, hd, causal, qb, kb):
    ks = jax.random.split(jax.random.PRNGKey(B * S), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KVH, hd))
    v = jax.random.normal(ks[2], (B, S, KVH, hd))
    o = attention.flash_attention(q, k, v, causal=causal, q_block=qb, k_block=kb)
    orf = ref.flash_attention(q, k, v, causal=causal)
    assert jnp.abs(o - orf).max() < 2e-5


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_flash_kernel_dtypes(dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32)).astype(dtype)
    k = jax.random.normal(ks[1], (1, 64, 2, 32)).astype(dtype)
    v = jax.random.normal(ks[2], (1, 64, 2, 32)).astype(dtype)
    o = attention.flash_attention(q, k, v, causal=True, q_block=16, k_block=16)
    orf = ref.flash_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), causal=True)
    assert jnp.abs(o.astype(jnp.float32) - orf).max() < tol


@pytest.mark.parametrize("B,C,H,KVH,hd,cb,holes", [
    (2, 64, 4, 2, 32, 16, False),
    (1, 100, 8, 1, 16, 32, True),   # MQA + ring-cache holes + pad
    (3, 33, 4, 4, 8, 16, True),
])
def test_decode_attention_kernel(B, C, H, KVH, hd, cb, holes):
    from repro.kernels import decode_attention as dk
    ks = jax.random.split(jax.random.PRNGKey(C), 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, C, KVH, hd))
    v = jax.random.normal(ks[2], (B, C, KVH, hd))
    pos = jnp.broadcast_to(jnp.arange(C), (B, C))
    if holes:  # ring-cache style: some slots empty
        mask = jax.random.bernoulli(ks[3], 0.8, (B, C))
        pos = jnp.where(mask, pos, -1)
    out = dk.decode_attention(q, k, v, pos, c_block=cb)
    orf = ref.decode_attention(q, k, v, pos)
    assert jnp.abs(out - orf).max() < 2e-5


@pytest.mark.parametrize("C,cb", [(100, 32), (33, 16), (7, 512), (65, 64)])
def test_decode_attention_ragged_tail(C, cb):
    """C % c_block != 0 is handled by in-kernel masking — no cache pad."""
    from repro.kernels import decode_attention as dk
    ks = jax.random.split(jax.random.PRNGKey(C), 3)
    B, H, KVH, hd = 2, 4, 2, 16
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, C, KVH, hd))
    v = jax.random.normal(ks[2], (B, C, KVH, hd))
    pos = jnp.broadcast_to(jnp.arange(C), (B, C))
    out = dk.decode_attention(q, k, v, pos, c_block=cb)
    orf = ref.decode_attention(q, k, v, pos)
    assert jnp.abs(out - orf).max() < 2e-5
    assert bool(jnp.all(jnp.isfinite(out)))


def test_decode_kernel_matches_model_decode_path():
    """Pallas decode kernel == models.blocks.decode_attention."""
    from repro.kernels import decode_attention as dk
    from repro.models.blocks import decode_attention as model_dec
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, C, H, KVH, hd = 2, 40, 4, 2, 16
    q = jax.random.normal(ks[0], (B, 1, H, hd))
    k = jax.random.normal(ks[1], (B, C, KVH, hd))
    v = jax.random.normal(ks[2], (B, C, KVH, hd))
    pos = jnp.broadcast_to(jnp.arange(C), (B, C))
    a = dk.decode_attention(q, k, v, pos, c_block=16)
    b = model_dec(q, k, v, k_pos=pos, cur_pos=C - 1)
    assert jnp.abs(a - b).max() < 2e-5
