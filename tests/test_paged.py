"""Paged KV serving (DESIGN.md §10): page-pool allocator semantics, the
Pallas paged-decode kernel vs its NumPy oracle AND the dense decode
kernel, model-level paged-vs-dense decode parity, and the continuous-
batching DecodeScheduler end to end — token-exact against a solo dense
decode, zero cold compiles at steady state, deterministic under
preemption, stats surfaced through pd.stats()["decode"]."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import ParticleModule, PushDistribution
from repro.kernels import ops, ref
from repro.models import api
from repro.runtime import global_cache
from repro.serve import PagePool, serve_decode


def _cold():
    return global_cache().snapshot_stats()["cold_compiles"]


# ---------------------------------------------------------------------------
# PagePool: host-side allocator semantics
# ---------------------------------------------------------------------------

def test_page_pool_alloc_release_and_backpressure():
    pool = PagePool(num_pages=4, page_size=8, max_seq_pages=4)
    a = pool.alloc(0, 2)
    b = pool.alloc(1, 2)
    assert sorted(a + b) == [0, 1, 2, 3]
    assert pool.free_pages == 0 and pool.used_pages == 4
    assert pool.alloc(2, 1) is None            # dry pool -> backpressure
    assert pool.alloc(0, 1) is None
    st = pool.snapshot_stats()
    assert st["alloc_failures"] == 2 and st["peak_used"] == 4
    assert pool.release(1) == 2                # retire: pages come back
    assert pool.free_pages == 2
    got = pool.alloc(2, 1)
    assert got == [2]                          # freed page recycled
    assert pool.pages_of(0) == a
    assert pool.release(99) == 0               # unknown seq: no-op


def test_page_pool_max_seq_pages_and_block_row():
    pool = PagePool(num_pages=8, page_size=4, max_seq_pages=2)
    assert pool.alloc(7, 2) == [0, 1]
    assert pool.alloc(7, 1) is None            # per-seq cap, pool not dry
    assert pool.free_pages == 6
    row = np.full((2,), -9, np.int32)
    pool.fill_block_row(7, row)
    assert row.tolist() == [0, 1]
    pool.release(7)
    pool.alloc(8, 1)
    pool.fill_block_row(8, row)
    assert row.tolist()[1] == 0                # unused tail cleared to 0
    with pytest.raises(ValueError):
        PagePool(0, 4, 2)


def test_page_pool_release_tail_invariants():
    """Rejected-draft rollback: tail truncation is page-granular, free
    counts are conserved, block tables stay consistent, and releasing a
    sequence the pool does not own raises."""
    pool = PagePool(num_pages=8, page_size=4, max_seq_pages=8)
    pool.alloc(3, 5)                            # covers 20 token positions
    assert pool.free_pages == 3
    owned_before = pool.pages_of(3)
    # 9 tokens need ceil(9/4) = 3 pages: 2 come back, prefix preserved
    assert pool.release_tail(3, 9) == 2
    assert pool.free_pages == 5
    assert pool.pages_of(3) == owned_before[:3]
    row = np.full((8,), -9, np.int32)
    pool.fill_block_row(3, row)
    assert row[:3].tolist() == owned_before[:3] and row[3:].tolist() == [0] * 5
    # page-granular: a partially-used last page is kept
    assert pool.release_tail(3, 9) == 0
    assert pool.release_tail(3, 12) == 0        # exact page boundary
    # n_tokens = 0 keeps zero pages (sequence stays owned, list empty)
    assert pool.release_tail(3, 0) == 3
    assert pool.free_pages == 8 and pool.pages_of(3) == []
    # conservation: freed pages are allocatable again
    assert pool.alloc(4, 8) is not None
    st = pool.snapshot_stats()
    assert st["allocs"] == 13 and st["releases"] == 5
    with pytest.raises(ValueError):
        pool.release_tail(3, -1)
    pool.release(3)                             # full release pops the seq
    with pytest.raises(KeyError):               # double release raises
        pool.release_tail(3, 1)
    with pytest.raises(KeyError):               # never-owned seq raises
        pool.release_tail(77, 1)


# ---------------------------------------------------------------------------
# paged decode kernel vs oracle and vs the dense decode kernel
# ---------------------------------------------------------------------------

def _paged_case(seed, B, H, KVH, hd, ps, n_pmax, NP, lens):
    """Random pages + block tables with the PagePool conventions: rows
    with len -1 inactive, unused block-table entries 0, tail slots of the
    last page holding stale garbage from 'previous owners'."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((NP, ps, KVH, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((NP, ps, KVH, hd)), jnp.float32)
    bt = np.zeros((B, n_pmax), np.int32)
    free = list(rng.permutation(NP))
    for b, sl in enumerate(lens):
        if sl < 0:
            continue
        for i in range(sl // ps + 1):
            bt[b, i] = free.pop()
    return q, k, v, jnp.asarray(bt), jnp.asarray(lens, jnp.int32)


@pytest.mark.parametrize("B,H,KVH,hd,ps,n_pmax,lens", [
    (2, 4, 2, 32, 16, 4, [47, 63]),        # GQA, partial + full pages
    (3, 8, 1, 16, 8, 6, [0, 33, 21]),      # MQA, single-token row
    (2, 4, 4, 8, 16, 3, [-1, 40]),         # MHA + an inactive row
    (4, 6, 3, 64, 32, 2, [5, -1, 63, 31]), # group=2, mixed ragged
])
def test_paged_kernel_vs_oracle(B, H, KVH, hd, ps, n_pmax, lens):
    NP = B * n_pmax + 2
    q, k, v, bt, sl = _paged_case(B * 7 + ps, B, H, KVH, hd, ps, n_pmax,
                                  NP, lens)
    out = ops.paged_decode_attention(q, k, v, bt, sl)
    want = ref.paged_decode_attention(q, k, v, bt, sl)
    assert float(jnp.abs(out - want).max()) < 1e-4
    for b, L in enumerate(lens):           # inactive rows exactly zero
        if L < 0:
            assert float(jnp.abs(out[b]).max()) == 0.0


def test_paged_kernel_matches_dense_decode_kernel():
    """Gathering a row's pages into a contiguous cache and running the
    dense decode kernel must agree with reading the pages in place."""
    B, H, KVH, hd, ps, n_pmax = 2, 4, 2, 32, 8, 5
    lens = [29, 37]
    NP = B * n_pmax + 1
    q, k, v, bt, sl = _paged_case(3, B, H, KVH, hd, ps, n_pmax, NP, lens)
    paged = ops.paged_decode_attention(q, k, v, bt, sl)
    C = n_pmax * ps
    kd = jnp.take(k, bt, axis=0).reshape(B, C, KVH, hd)
    vd = jnp.take(v, bt, axis=0).reshape(B, C, KVH, hd)
    col = jnp.broadcast_to(jnp.arange(C), (B, C))
    pos = jnp.where(col <= sl[:, None], col, -1)
    dense = ops.decode_attention(q, kd, vd, pos)
    assert float(jnp.abs(paged - dense).max()) < 1e-4


def test_paged_kernel_vmaps_over_particle_axis():
    """serve stacks the kernel over the ParticleStore capacity axis:
    q and pages batched, block table + seq_lens shared."""
    P = 3
    B, H, KVH, hd, ps, n_pmax = 2, 4, 2, 16, 8, 3
    NP = B * n_pmax + 1
    cases = [_paged_case(11 + p, B, H, KVH, hd, ps, n_pmax, NP, [20, 13])
             for p in range(P)]
    qs = jnp.stack([c[0] for c in cases])
    ks = jnp.stack([c[1] for c in cases])
    vs = jnp.stack([c[2] for c in cases])
    bt, sl = cases[0][3], cases[0][4]
    outs = jax.vmap(lambda q, k, v: ops.paged_decode_attention(q, k, v,
                                                               bt, sl))(
        qs, ks, vs)
    for p in range(P):
        want = ref.paged_decode_attention(qs[p], ks[p], vs[p], bt, sl)
        assert float(jnp.abs(outs[p] - want).max()) < 1e-4


# ---------------------------------------------------------------------------
# model level: paged prefill + decode vs the dense cache path
# ---------------------------------------------------------------------------

def _tiny_cfg():
    return configs.get("qwen1.5-0.5b").replace(
        n_units=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=128, max_seq_len=128)


def test_model_paged_decode_matches_dense_path():
    cfg = _tiny_cfg()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    L, steps, ps, n_pmax = 13, 4, 8, 6
    rng = np.random.default_rng(5)
    prompt = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, L)), jnp.int32)

    dense_first, caches = api.prefill(params, {"tokens": prompt}, cfg,
                                      max_len=L + steps + 1)
    pages = api.paged_cache_init(cfg, num_pages=16, page_size=ps)
    bt_row = jnp.asarray(list(range(2, 2 + n_pmax)), jnp.int32)
    bucket = 16                               # L=13 padded to its bucket
    padded = jnp.zeros((1, bucket), jnp.int32).at[:, :L].set(prompt)
    paged_first, pages = api.prefill_paged(params, padded, pages, bt_row,
                                           jnp.int32(L), cfg)
    assert float(jnp.abs(dense_first - paged_first).max()) < 1e-4

    tok = jnp.argmax(dense_first, -1).astype(jnp.int32).reshape(1)
    bt = bt_row[None, :]
    for step in range(steps):
        member, caches = api.decode_step(params, tok, caches,
                                         jnp.int32(L + step), cfg,
                                         decode_kernel=False)
        sl = jnp.asarray([L + step], jnp.int32)
        pmember, pages = api.decode_step_paged(params, tok, pages, bt, sl,
                                               cfg)
        assert float(jnp.abs(member - pmember).max()) < 1e-4, step
        tok = jnp.argmax(member, -1).astype(jnp.int32).reshape(1)


# ---------------------------------------------------------------------------
# DecodeScheduler end to end
# ---------------------------------------------------------------------------

def _lm_pd(cfg, n=2):
    module = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)
    pd = PushDistribution(module, num_devices=1, seed=0)
    for _ in range(n):
        pd.p_create()
    return pd


def _ref_decode(pd, cfg, prompt, max_new):
    """Solo dense greedy BMA decode — the oracle the scheduler's batched,
    paged, preempted output must match token for token."""
    toks = jnp.asarray([prompt], jnp.int32)
    stacked = pd.store.stacked("params")
    first, caches = jax.vmap(
        lambda p: api.prefill(p, {"tokens": toks}, cfg,
                              max_len=len(prompt) + max_new + 1))(stacked)
    probs = jnp.mean(jax.nn.softmax(first.astype(jnp.float32), -1), 0)
    out = [int(jnp.argmax(probs, -1)[0])]
    for step in range(max_new - 1):
        tok = jnp.asarray([out[-1]], jnp.int32)
        member, caches = jax.vmap(
            lambda p, c: api.decode_step(p, tok, c,
                                         jnp.int32(len(prompt) + step),
                                         cfg, decode_kernel=False))(
            stacked, caches)
        probs = jnp.mean(jax.nn.softmax(member.astype(jnp.float32), -1), 0)
        out.append(int(jnp.argmax(probs, -1)[0]))
    return out


def test_scheduler_matches_solo_decode_with_zero_steady_state_compiles():
    cfg = _tiny_cfg()
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size, rng.integers(3, 15)))
               for _ in range(5)]
    with _lm_pd(cfg) as pd:
        refs = [_ref_decode(pd, cfg, p, 6) for p in prompts]
        svc = serve_decode(pd, cfg, num_pages=32, page_size=8,
                           max_active=3, warmup_buckets=(4, 8, 16))
        try:
            cold = _cold()
            handles = [svc.generate_async(p, max_new=6) for p in prompts]
            gens = [h.result(300) for h in handles]
            # more sequences than rows: admission happened mid-decode,
            # and every sequence still matches its solo dense run exactly
            for g, r, p in zip(gens, refs, prompts):
                assert g.tokens == r
                assert g.prompt == [int(t) for t in p]
                assert len(g.logprobs) == len(g.entropy) == 6
                assert g.finish_reason == "length"
            assert _cold() == cold, "steady-state decode cold-compiled"
            st = svc.stats()
            assert st["retired"] == 5 and st["admitted"] >= 5
            assert st["steps"] > 0 and st["prefills"] >= 5
            # one H2D per decode step + one per prefill, by construction
            assert st["h2d_transfers"] == st["steps"] + st["prefills"]
            assert st["pool"]["used_pages"] == 0        # all reclaimed
            assert 0.0 < st["row_occupancy"] <= 1.0
            # the store's runtime stats surface the decode section
            dec = pd.stats()["decode"]
            assert dec["retired"] == 5
            assert dec["pool"]["num_pages"] == 32
        finally:
            svc.close()


def test_scheduler_preemption_is_deterministic():
    """A pool too small for the offered load forces preemptions; greedy
    replay makes the output token-identical to the solo run anyway."""
    cfg = _tiny_cfg()
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(1, cfg.vocab_size, 12)) for _ in range(3)]
    with _lm_pd(cfg) as pd:
        refs = [_ref_decode(pd, cfg, p, 8) for p in prompts]
        # 3 seqs x (12 + 8 = 20 tok -> 5 pages) vs 8 pages: can't all fit
        svc = serve_decode(pd, cfg, num_pages=8, page_size=4,
                           max_active=3, warmup=False)
        try:
            handles = [svc.generate_async(p, max_new=8) for p in prompts]
            gens = [h.result(300) for h in handles]
            for g, r in zip(gens, refs):
                assert g.tokens == r, (g.tokens, r, g.preemptions)
            st = svc.stats()
            assert st["preempted"] > 0, "pool sized to force preemption"
            assert sum(g.preemptions for g in gens) == st["preempted"]
            assert st["pool"]["used_pages"] == 0
        finally:
            svc.close()


def test_scheduler_eos_and_request_validation():
    cfg = _tiny_cfg()
    with _lm_pd(cfg, n=1) as pd:
        svc = serve_decode(pd, cfg, num_pages=16, page_size=8,
                           max_active=2, warmup=False)
        try:
            g = svc.generate([5, 9, 23], max_new=8)
            # greedy + eos on the first generated token: stops right there
            g2 = svc.generate([5, 9, 23], max_new=8, eos_id=g.tokens[0])
            assert g2.tokens == g.tokens[:1]
            assert g2.finish_reason == "eos"
            with pytest.raises(ValueError):
                svc.generate([], max_new=4)
            with pytest.raises(ValueError):
                svc.generate([1], max_new=0)
            with pytest.raises(ValueError):     # exceeds pool capacity
                svc.generate([1] * 100, max_new=100)
        finally:
            svc.close()
