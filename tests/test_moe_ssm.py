"""MoE dispatch + RWKV6 + Mamba2 correctness vs oracles."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.models import mamba as mamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.blocks import norm_apply


def moe_cfg(**kw):
    base = dict(name="t", family="moe", d_model=16, vocab_size=10,
                n_experts=4, top_k=2, moe_d_ff=32, capacity_factor=8.0)
    base.update(kw)
    return ModelConfig(**base)


def test_moe_matches_dense_oracle():
    cfg = moe_cfg(n_shared_experts=1, shared_d_ff=32)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = moe_mod.moe_apply(p, x, cfg)
    yr = moe_mod.moe_ref(p, x, cfg)
    assert jnp.abs(y - yr).max() < 1e-4
    assert float(aux["dropped_frac"]) == 0.0


def test_moe_capacity_drops_are_bounded():
    cfg = moe_cfg(capacity_factor=1.0)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 16))
    y, aux = moe_mod.moe_apply(p, x, cfg)
    assert 0.0 <= float(aux["dropped_frac"]) < 0.5
    assert not jnp.any(jnp.isnan(y))


def test_moe_load_balance_loss_uniform_router():
    """A uniform router must give lb_loss ~= 1 (its minimum)."""
    cfg = moe_cfg()
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    p["router"]["w"] = jnp.zeros_like(p["router"]["w"])
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 64, 16))
    _, aux = moe_mod.moe_apply(p, x, cfg)
    assert abs(float(aux["lb_loss"]) - 1.0) < 0.15


def test_moe_grads_flow_to_experts():
    cfg = moe_cfg()
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 16))
    g = jax.grad(lambda pp: moe_mod.moe_apply(pp, x, cfg)[0].sum())(p)
    assert float(jnp.abs(g["wi"]).sum()) > 0
    assert float(jnp.abs(g["router"]["w"]).sum()) > 0


# ---------------------------------------------------------------------------

RWKV_CFG = ModelConfig(name="t", family="ssm", d_model=64, vocab_size=10,
                       rwkv_head_dim=16, d_ff=128)


def test_rwkv_chunked_matches_stepwise():
    p = rwkv_mod.rwkv_init(jax.random.PRNGKey(0), RWKV_CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 37, 64)) * 0.5
    xn = norm_apply(p["ln1"], x)
    y_c, _ = rwkv_mod.time_mix_chunked(p["time_mix"], xn, RWKV_CFG, chunk=8)
    y_r = rwkv_mod.time_mix_ref(p["time_mix"], xn, RWKV_CFG)
    assert jnp.abs(y_c - y_r).max() < 1e-3


def test_rwkv_decode_consistency():
    p = rwkv_mod.rwkv_init(jax.random.PRNGKey(0), RWKV_CFG)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 12, 64)) * 0.5
    full, _ = rwkv_mod.rwkv_block_full(p, x, RWKV_CFG, chunk=4)
    st = rwkv_mod.rwkv_state_init(RWKV_CFG, 1)
    outs = []
    for t in range(12):
        o, st = rwkv_mod.rwkv_block_decode(p, x[:, t:t + 1], RWKV_CFG, st)
        outs.append(o)
    assert jnp.abs(jnp.concatenate(outs, 1) - full).max() < 1e-3


def test_rwkv_grads_finite():
    p = rwkv_mod.rwkv_init(jax.random.PRNGKey(0), RWKV_CFG)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 64)) * 0.5
    g = jax.grad(lambda pp: rwkv_mod.rwkv_block_full(pp, x, RWKV_CFG)[0].sum())(p)
    leaves = jax.tree.leaves(g)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)


# ---------------------------------------------------------------------------

MAMBA_CFG = ModelConfig(name="t", family="hybrid", d_model=32, vocab_size=10,
                        ssm_state=8, ssm_head_dim=16, ssm_expand=2)


def test_mamba_chunked_matches_stepwise():
    p = mamba_mod.mamba_init(jax.random.PRNGKey(0), MAMBA_CFG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 21, 32)) * 0.5
    y_c, _ = mamba_mod.mamba_block_full(p, x, MAMBA_CFG, chunk=8)
    y_r = mamba_mod.mamba_ref(p, x, MAMBA_CFG)
    assert jnp.abs(y_c - y_r).max() < 1e-3


def test_mamba_decode_and_state_continuation():
    p = mamba_mod.mamba_init(jax.random.PRNGKey(0), MAMBA_CFG)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 10, 32)) * 0.5
    full, _ = mamba_mod.mamba_block_full(p, x, MAMBA_CFG, chunk=4)
    # split with state carry
    y1, s1 = mamba_mod.mamba_block_full(p, x[:, :6], MAMBA_CFG, chunk=4)
    y2, _ = mamba_mod.mamba_block_full(p, x[:, 6:], MAMBA_CFG, chunk=4, st=s1)
    assert jnp.abs(jnp.concatenate([y1, y2], 1) - full).max() < 1e-3
    # stepwise decode
    st = mamba_mod.mamba_state_init(MAMBA_CFG, 1)
    outs = []
    for t in range(10):
        o, st = mamba_mod.mamba_block_decode(p, x[:, t:t + 1], MAMBA_CFG, st)
        outs.append(o)
    assert jnp.abs(jnp.concatenate(outs, 1) - full).max() < 1e-3
