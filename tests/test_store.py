"""ParticleStore semantics: round-trips, laziness, dirty tracking, the
store as single source of truth for both backends, and the sharded
compiled path (subprocess with 4 forced host devices).

Property tests (hypothesis) assert the exact-inverse laws the refactor
relies on: stack_pytrees/unstack_pytree and store view/write-back."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ParticleModule, ParticleStore, Placement,
                        PushDistribution, functional)
from repro.optim import sgd

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(seed, shapes):
    rng = np.random.default_rng(seed)
    return {f"p{i}": jnp.asarray(rng.standard_normal(s, dtype=np.float32))
            for i, s in enumerate(shapes)}


def _eq(a, b) -> bool:
    return all(bool(jnp.array_equal(x, y)) for x, y in
               zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# unit tests (always run, no hypothesis needed)
# ---------------------------------------------------------------------------

def test_store_view_writeback_roundtrip():
    store = ParticleStore()
    trees = [_tree(i, [(3, 2), (4,)]) for i in range(3)]
    for pid, t in enumerate(trees):
        store.register(pid)
        store.write("params", pid, t)
    # canonical form is capacity-padded (3 live -> power-of-two 4); the
    # padding row is gated off by the active mask
    assert store.capacity == 4
    st = store.stacked("params")
    assert jax.tree.leaves(st)[0].shape == (4, 3, 2)
    assert np.allclose(np.asarray(store.active_mask()), [1, 1, 1, 0])
    for pid, t in enumerate(trees):
        assert _eq(store.read("params", pid), t)

    # write-back through a view is visible in the next stacked flush
    new_row = _tree(99, [(3, 2), (4,)])
    store.write("params", 1, new_row)
    st2 = store.stacked("params")
    assert _eq(jax.tree.map(lambda x: x[1], st2), new_row)
    assert _eq(jax.tree.map(lambda x: x[0], st2), trees[0])


def test_store_commit_invalidates_views_lazily():
    store = ParticleStore()
    for pid in range(2):
        store.register(pid)
        store.write("params", pid, _tree(pid, [(2, 2)]))
    store.stacked("params")
    _ = store.read("params", 0)              # populate the view cache
    fresh = functional.stack_pytrees([_tree(7, [(2, 2)]),
                                      _tree(8, [(2, 2)])])
    u0 = store.snapshot_stats()["unstacks"]
    store.commit("params", fresh)
    assert store.snapshot_stats()["unstacks"] == u0   # commit is lazy
    assert _eq(store.read("params", 0), _tree(7, [(2, 2)]))
    assert store.snapshot_stats()["unstacks"] == u0 + 1  # unstack-on-read


def test_store_checkout_transfers_ownership():
    store = ParticleStore()
    store.register(0)
    store.write("params", 0, _tree(0, [(2,)]))
    st = store.checkout("params")
    with pytest.raises(KeyError):
        store.read("params", 0)
    store.commit("params", st)
    assert _eq(store.read("params", 0), _tree(0, [(2,)]))


def test_store_grows_with_new_particles():
    store = ParticleStore()
    for pid in range(2):
        store.register(pid)
        store.write("params", pid, _tree(pid, [(2,)]))
    assert jax.tree.leaves(store.stacked("params"))[0].shape[0] == 2
    gen = store.generation()
    store.register(2)               # capacity 2 -> 4: a shape change
    store.write("params", 2, _tree(2, [(2,)]))
    st = store.stacked("params")
    assert store.generation() > gen
    assert jax.tree.leaves(st)[0].shape[0] == 4
    assert _eq(jax.tree.map(lambda x: x[2], st), _tree(2, [(2,)]))


def test_store_subset_roundtrip():
    """An ordered subset (any order) stacks/checks out/commits without
    disturbing the other particles — what a second bayes_infer on the same
    PD relies on."""
    store = ParticleStore()
    trees = {}
    for pid in range(4):
        store.register(pid)
        trees[pid] = _tree(pid, [(2, 3)])
        store.write("params", pid, trees[pid])
    store.stacked("params")                        # canonical full stack
    sub = store.stacked("params", [3, 1])          # reordered subset read
    assert _eq(jax.tree.map(lambda x: x[0], sub), trees[3])
    st = store.checkout("params", [2, 3])
    new = jax.tree.map(lambda x: x + 1.0, st)
    store.commit("params", new, [2, 3])
    assert _eq(store.read("params", 0), trees[0])  # untouched rows survive
    assert _eq(store.read("params", 2),
               jax.tree.map(lambda x: x + 1.0, trees[2]))
    full = store.stacked("params")
    assert _eq(jax.tree.map(lambda x: x[3], full),
               jax.tree.map(lambda x: x + 1.0, trees[3]))


def test_store_rejects_bad_pids_and_counts():
    store = ParticleStore()
    for pid in (0, 1):
        store.register(pid)
        store.write("params", pid, _tree(pid, [(2,)]))
    with pytest.raises(KeyError):
        store.stacked("params", [0, 7])            # unregistered pid
    with pytest.raises(ValueError):
        store.commit("params", functional.stack_pytrees(
            [_tree(0, [(2,)])]))  # wrong particle count


def _mod_and_data():
    def init(rng):
        return {"w": jax.random.normal(rng, (3, 2))}

    def loss(p, b):
        return jnp.mean((b[0] @ p["w"] - b[1]) ** 2), {}

    mod = ParticleModule(init, loss, lambda p, b: b[0] @ p["w"])
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 3))
    return mod, [(x, x @ jnp.ones((3, 2)))]


def test_repeated_bayes_infer_compiled_backend():
    """A second bayes_infer creates new particles -> the fused path must
    operate on that subset of the store (regression: full-set-only store
    ops made this raise)."""
    from repro.bdl import DeepEnsemble
    mod, data = _mod_and_data()
    preds = {}
    for backend in ("nel", "compiled"):
        with DeepEnsemble(mod, num_devices=1, seed=0, backend=backend) as de:
            de.bayes_infer(data, 2, optimizer=sgd(0.05), num_particles=2)
            pids2, _ = de.bayes_infer(data, 2, optimizer=sgd(0.05),
                                      num_particles=2)
            assert len(de.push_dist.particle_ids()) == 4
            preds[backend] = de.posterior_pred(data[0])
            assert all(bool(jnp.all(jnp.isfinite(
                de.push_dist.p_params(p)["w"]))) for p in pids2)
    assert float(jnp.abs(preds["nel"] - preds["compiled"]).max()) < 1e-4


def test_backend_parity_with_real_dataloader():
    """Fused-path compilation must not consume dataloader iterations:
    NEL and compiled must see identical epoch streams from a stateful
    DataLoader (regression: an eager first-batch peek shifted the rng
    epoch of the fused run)."""
    from repro import configs
    from repro.bdl import DeepEnsemble
    from repro.data.loader import DataLoader
    from repro.models import api

    cfg = configs.get("vit-mnist").smoke().replace(n_units=1, d_model=32,
                                                   n_heads=2, n_kv_heads=2,
                                                   head_dim=16, d_ff=64)
    mod = ParticleModule(init=lambda rng: api.init_params(rng, cfg),
                         loss=lambda p, b: api.loss_fn(p, b, cfg),
                         forward=lambda p, b: api.forward(p, b, cfg)[0],
                         cfg=cfg)
    preds = {}
    for backend in ("nel", "compiled"):
        dl = DataLoader(cfg, batch_size=4, num_batches=2, seed=0)
        probe = DataLoader(cfg, batch_size=4, num_batches=1, seed=123)
        with DeepEnsemble(mod, num_devices=1, seed=0, backend=backend) as de:
            de.bayes_infer(dl, 2, optimizer=sgd(0.01), num_particles=2)
            preds[backend] = de.posterior_pred(next(iter(probe)))
    assert float(jnp.abs(preds["nel"] - preds["compiled"]).max()) < 1e-4


def test_particle_state_is_store_backed():
    """Particle.state is a view of the PD's store — one source of truth."""
    def init(rng):
        return {"w": jax.random.normal(rng, (3, 2))}

    def loss(p, b):
        return jnp.mean((b[0] @ p["w"] - b[1]) ** 2), {}

    mod = ParticleModule(init, loss, lambda p, b: b[0] @ p["w"])
    with PushDistribution(mod, num_devices=1) as pd:
        pids = [pd.p_create(sgd(0.1)) for _ in range(2)]
        p0 = pd.particles[pids[0]]
        assert p0.state.store is pd.store
        assert "params" in p0.state and "grads" in p0.state
        # a write through the particle is visible in the stacked form
        w = {"w": jnp.ones((3, 2))}
        p0.state["params"] = w
        st = pd.store.stacked("params", pids)
        assert _eq(jax.tree.map(lambda x: x[0], st), w)
        # and a committed stacked form is visible through the particle
        new = functional.stack_pytrees([{"w": jnp.full((3, 2), 2.0)},
                                        {"w": jnp.full((3, 2), 3.0)}])
        pd.store.commit("params", new, pids)
        assert float(p0.state["params"]["w"][0, 0]) == 2.0
        assert float(pd.particles[pids[1]].state["params"]["w"][0, 0]) == 3.0


def test_p_stack_unstack_deprecated_compat():
    """The legacy bridge still round-trips but warns: one compat test
    until the delegates are removed (migrate to store.stacked/commit)."""
    import warnings as _w
    mod, _ = _mod_and_data()
    with PushDistribution(mod, num_devices=1) as pd:
        pids = [pd.p_create(sgd(0.1)) for _ in range(2)]
        with pytest.warns(DeprecationWarning):
            st = pd.p_stack(pids)
        new = jax.tree.map(lambda x: x + 1.0, st)
        with pytest.warns(DeprecationWarning):
            pd.p_unstack(pids, new)
        assert _eq(pd.store.stacked("params", pids), new)


def test_p_predict_compiled_is_one_fused_program():
    """Satellite: under backend="compiled", p_predict must not dispatch n
    sequential NEL forwards — and must match the NEL answer."""
    def init(rng):
        return {"w": jax.random.normal(rng, (3, 2))}

    def loss(p, b):
        return jnp.mean((b[0] @ p["w"] - b[1]) ** 2), {}

    def fwd(p, b):
        return b[0] @ p["w"]

    x = jax.random.normal(jax.random.PRNGKey(3), (8, 3))
    batch = (x, x @ jnp.ones((3, 2)))
    preds = {}
    for backend in ("nel", "compiled"):
        mod = ParticleModule(init, loss, fwd)
        with PushDistribution(mod, num_devices=1, seed=0,
                              backend=backend) as pd:
            for _ in range(3):
                pd.p_create(sgd(0.1))
            d0 = pd.nel.stats["dispatches"]
            preds[backend] = pd.p_predict(batch)
            nd = pd.nel.stats["dispatches"] - d0
            assert nd == (3 if backend == "nel" else 0), (backend, nd)
    assert float(jnp.abs(preds["nel"] - preds["compiled"]).max()) < 1e-4


# ---------------------------------------------------------------------------
# hypothesis property tests: exact-inverse laws
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    SET = dict(deadline=None, max_examples=20)
    shapes_st = st.lists(
        st.lists(st.integers(1, 4), min_size=1, max_size=3).map(tuple),
        min_size=1, max_size=3)

    @settings(**SET)
    @given(n=st.integers(1, 5), shapes=shapes_st, seed=st.integers(0, 100))
    def test_stack_unstack_exact_inverse(n, shapes, seed):
        trees = [_tree(seed + i, shapes) for i in range(n)]
        stacked = functional.stack_pytrees(trees)
        back = functional.unstack_pytree(stacked, n)
        assert all(_eq(a, b) for a, b in zip(trees, back))
        # and the other direction: unstack(stacked) restacks to stacked
        assert _eq(functional.stack_pytrees(back), stacked)

    @settings(**SET)
    @given(n=st.integers(1, 5), shapes=shapes_st, seed=st.integers(0, 100),
           writes=st.lists(st.integers(0, 4), max_size=4))
    def test_store_view_writeback_exact_inverse(n, shapes, seed, writes):
        """Any interleaving of view writes and stacked flushes preserves
        every particle's tree exactly (no float drift: pure data motion)."""
        store = ParticleStore()
        expect = {}
        for pid in range(n):
            store.register(pid)
            expect[pid] = _tree(seed + pid, shapes)
            store.write("params", pid, expect[pid])
        store.stacked("params")
        for w in writes:
            pid = w % n
            expect[pid] = _tree(seed + 1000 + w, shapes)
            store.write("params", pid, expect[pid])
            if w % 2 == 0:           # interleave flushes with writes
                store.stacked("params")
        st_ = store.stacked("params")
        for pid in range(n):
            assert _eq(store.read("params", pid), expect[pid])
            assert _eq(jax.tree.map(lambda x: x[pid], st_), expect[pid])

    @settings(**SET)
    @given(n=st.integers(1, 4), shapes=shapes_st, seed=st.integers(0, 100))
    def test_store_commit_read_exact_inverse(n, shapes, seed):
        store = ParticleStore()
        trees = [_tree(seed + i, shapes) for i in range(n)]
        for pid in range(n):
            store.register(pid)
            store.write("params", pid, jax.tree.map(jnp.zeros_like, trees[pid]))
        store.commit("params", functional.stack_pytrees(trees),
                     pids=list(range(n)))
        assert all(_eq(store.read("params", pid), trees[pid])
                   for pid in range(n))
else:  # keep a visible skip so the gap is auditable in CI output
    @pytest.mark.skip(reason="hypothesis not installed (pip install -e .[dev])")
    def test_store_property_laws():
        pass


# ---------------------------------------------------------------------------
# the sharded compiled path (acceptance criterion): subprocess, 4 devices
# ---------------------------------------------------------------------------

def test_sharded_compiled_matches_nel_across_4_devices():
    """DeepEnsemble/MultiSWAG/SteinVGD fused paths with the particle axis
    sharded across 4 forced host devices: parity with NEL < 1e-4, sharding
    inspection, zero per-epoch host transfers, donated buffers."""
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "_sharded_store_check.py")],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
             "XLA_FLAGS": "--xla_force_host_platform_device_count=4"},
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    assert "OK" in out.stdout
