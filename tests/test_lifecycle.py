"""Elastic particle lifecycle (DESIGN.md §9): capacity-padded store,
clone/kill/rebalance, masked fused-path parity against dense references,
zero-recompile churn, and elastic checkpoint restore.

The acceptance bar this file carries: within-capacity p_clone/p_kill
cause ZERO ProgramCache cold compiles (generation and shapes are both
churn-invariant), masked BMA/SVGD match dense per-particle references to
< 1e-5, and capacity growth — the one shape-changing lifecycle event —
bumps the generation exactly once per doubling.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bdl import DeepEnsemble, lifecycle
from repro.bdl.svgd import fused_svgd_step, svgd_force
from repro.core import ParticleModule, ParticleStore, PushDistribution
from repro.optim import sgd
from repro.runtime import global_cache
from repro.serve import PredictiveEngine
from repro.serve.uncertainty import predictive_heads


def _module():
    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"w": jax.random.normal(k1, (3, 4)) * 0.5,
                "b": jax.random.normal(k2, (4,)) * 0.1}

    def loss(p, b):
        return jnp.mean((b[0] @ p["w"] + p["b"] - b[1]) ** 2), {}

    def fwd(p, b):
        return b[0] @ p["w"] + p["b"]

    return ParticleModule(init, loss, fwd)


def _batch(m=8, seed=3):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, 3))
    return (x, x @ jnp.ones((3, 4)))


def _cold():
    return global_cache().snapshot_stats()["cold_compiles"]


# ---------------------------------------------------------------------------
# clone / kill semantics
# ---------------------------------------------------------------------------

def test_clone_identical_without_jitter_and_perturbed_with():
    with PushDistribution(_module(), num_devices=1, capacity=4) as pd:
        src = pd.p_create(sgd(0.1))
        twin = pd.p_clone(src)                     # jitter=0: exact copy
        assert twin != src
        a, b = pd.p_params(src), pd.p_params(twin)
        assert all(bool(jnp.array_equal(u, v)) for u, v in
                   zip(jax.tree.leaves(a), jax.tree.leaves(b)))
        jit = pd.p_clone(src, jitter=0.05)
        c = pd.p_params(jit)
        diff = float(jnp.abs(c["w"] - a["w"]).max())
        assert 0.0 < diff < 1.0                    # perturbed, but nearby
        # handlers and optimizer travel with the clone
        assert pd.particles[twin].optimizer is pd.particles[src].optimizer
        assert pd.lifecycle["clones"] == 2


def test_clone_copies_extra_state_keys():
    with PushDistribution(_module(), num_devices=1, capacity=4) as pd:
        src = pd.p_create(sgd(0.1))
        pd.particles[src].state["swag"] = {"n": jnp.ones(())}
        twin = pd.p_clone(src)
        assert float(pd.particles[twin].state["swag"]["n"]) == 1.0


def test_kill_then_create_reuses_slot_without_generation_bump():
    with PushDistribution(_module(), num_devices=1, capacity=4) as pd:
        pids = [pd.p_create(sgd(0.1)) for _ in range(4)]
        store = pd.store
        gen = store.generation()
        slot = store.slot_of(pids[1])
        pd.p_kill(pids[1])
        assert store.live_count() == 3 and store.free_slots() == 1
        assert np.asarray(store.active_mask())[slot] == 0.0
        fresh = pd.p_create(sgd(0.1))
        assert store.slot_of(fresh) == slot        # freed slot reused
        assert store.generation() == gen           # no shape change
        assert store.capacity == 4
        # dead pid is really dead: state, messaging, NEL
        with pytest.raises(KeyError):
            store.read("params", pids[1])
        with pytest.raises(KeyError):
            pd.nel.dispatch(pids[1], lambda: None)
        assert pids[1] not in pd.nel._particles
        assert pids[1] not in pd.nel._device_of


def test_kill_cleans_nel_active_set():
    with PushDistribution(_module(), num_devices=1, capacity=4) as pd:
        pids = [pd.p_create(sgd(0.1)) for _ in range(2)]
        b = _batch()
        pd.p_wait([pd.particles[p].step(b) for p in pids])   # make resident
        assert pids[0] in pd.nel._active[0]
        pd.p_kill(pids[0])
        assert pids[0] not in pd.nel._active[0]
        assert pd.lifecycle["kills"] == 1


def test_capacity_growth_bumps_generation_once_per_doubling():
    with PushDistribution(_module(), num_devices=1) as pd:
        gens = []
        for _ in range(5):
            pd.p_create(sgd(0.1))
            gens.append(pd.store.generation())
        # capacities after each create: 1, 2, 4, 4, 8 — growth (and the
        # generation bump) happens at creates 1, 2, 3 and 5 only
        assert pd.store.capacity == 8
        assert gens[3] == gens[2]      # 4th create fit capacity 4
        assert gens[4] > gens[3]       # 5th forced the doubling


def test_rebalance_moves_particles_evenly():
    with PushDistribution(_module(), num_devices=1, capacity=8) as pd:
        pids = [pd.p_create(sgd(0.1), device=0) for _ in range(4)]
        moves = pd.p_rebalance()       # single device: nothing to move
        assert moves == {}
        assert pd.lifecycle["rebalances"] == 1
        assert set(pd.particle_ids()) == set(pids)


# ---------------------------------------------------------------------------
# masked fused paths match dense per-particle references
# ---------------------------------------------------------------------------

def test_masked_bma_heads_match_dense_subset():
    P_, B, C = 8, 5, 7
    rng = np.random.default_rng(0)
    outs = jnp.asarray(rng.standard_normal((P_, B, C)), jnp.float32)
    mask = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 0], jnp.float32)
    live = outs[np.asarray(mask) > 0]
    for kind in ("classify", "regress"):
        got = predictive_heads(outs, kind, mask)
        want = predictive_heads(live, kind)
        for k in want:
            err = float(jnp.abs(got[k] - want[k]).max())
            assert err < 1e-5, (kind, k, err)


def test_masked_heads_ignore_nan_in_dead_slots():
    outs = jnp.stack([jnp.ones((2, 3)), jnp.full((2, 3), jnp.nan)])
    mask = jnp.asarray([1.0, 0.0])
    got = predictive_heads(outs, "regress", mask)
    assert bool(jnp.all(jnp.isfinite(got["mean"])))


def test_masked_svgd_force_matches_dense_subset():
    rng = np.random.default_rng(1)
    theta = jnp.asarray(rng.standard_normal((8, 6)), jnp.float32)
    grads = jnp.asarray(rng.standard_normal((8, 6)), jnp.float32)
    mask = jnp.asarray([1, 1, 0, 1, 0, 1, 1, 0], jnp.float32)
    keep = np.asarray(mask) > 0
    for ell in (1.0, -1.0):            # fixed + median heuristic
        got = svgd_force(theta, grads, ell, mask=mask)
        want = svgd_force(theta[keep], grads[keep], ell)
        err = float(jnp.abs(got[keep] - want).max())
        assert err < 1e-5, (ell, err)
        assert float(jnp.abs(got[~keep]).max()) == 0.0


def test_masked_fused_svgd_step_freezes_dead_slots():
    mod = _module()
    step = jax.jit(fused_svgd_step(mod.loss, lr=0.1, lengthscale=1.0))
    stacked = jax.vmap(mod.init)(jax.random.split(jax.random.PRNGKey(0), 4))
    mask = jnp.asarray([1, 1, 0, 1], jnp.float32)
    new, losses = step(stacked, _batch(), mask)
    assert bool(jnp.array_equal(new["w"][2], stacked["w"][2]))  # frozen
    assert float(losses[2]) == 0.0
    assert not bool(jnp.array_equal(new["w"][0], stacked["w"][0]))


def test_p_predict_after_churn_matches_live_reference():
    with PushDistribution(_module(), num_devices=1, seed=0,
                          backend="compiled", capacity=4) as pd:
        pids = [pd.p_create(sgd(0.1)) for _ in range(4)]
        b = _batch()
        pd.p_predict(b)                            # compile at capacity 4
        pd.p_kill(pids[2])
        cold = _cold()
        got = pd.p_predict(b)                      # churned: same program
        assert _cold() == cold
        live = [p for p in pids if p != pids[2]]
        ref = np.mean([np.asarray(b[0] @ pd.p_params(p)["w"]
                                  + pd.p_params(p)["b"]) for p in live], 0)
        assert np.abs(np.asarray(got) - ref).max() < 1e-5


# ---------------------------------------------------------------------------
# zero-recompile churn under a serving engine
# ---------------------------------------------------------------------------

def test_serving_survives_churn_with_zero_cold_compiles():
    with PushDistribution(_module(), num_devices=1, seed=0,
                          backend="compiled", capacity=4) as pd:
        pids = [pd.p_create(sgd(0.05)) for _ in range(4)]
        x = jax.random.normal(jax.random.PRNGKey(9), (8, 3))
        eng = PredictiveEngine(pd.module.forward, store=pd.store,
                               kind="regress")
        eng.predict((x, None))                     # warm compile
        cold = _cold()
        for round_ in range(3):
            victim = pd.particle_ids()[0]
            pd.p_kill(victim)
            src = pd.particle_ids()[0]
            pd.p_clone(src, jitter=0.01)
            heads = eng.predict((x, None))
            live = pd.particle_ids()
            ref = np.mean([np.asarray(x @ pd.p_params(p)["w"]
                                      + pd.p_params(p)["b"])
                           for p in live], 0)
            assert np.abs(np.asarray(heads["mean"]) - ref).max() < 1e-5
        assert _cold() == cold, "churn must not recompile serving programs"
        assert pd.stats()["lifecycle"]["clones"] == 3
        assert pd.stats()["lifecycle"]["kills"] == 3


def test_decode_serving_survives_churn_with_zero_cold_compiles():
    """Continuous-batching paged decode under clone/kill churn: the page
    pool is capacity-padded store state like params, so within-capacity
    churn between generations recompiles nothing — and after the churn
    round-trips (clone then kill the twin), greedy decode reproduces the
    pre-churn tokens exactly. Lifecycle ops hold the scheduler's
    step_lock so they never interleave with a step's pages checkout."""
    from repro import configs
    from repro.models import api
    from repro.serve import serve_decode

    cfg = configs.get("qwen1.5-0.5b").replace(
        n_units=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=128, max_seq_len=64)
    lm = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)
    prompt = [3, 5, 7, 11, 13]
    with PushDistribution(lm, num_devices=1, seed=0, capacity=4) as pd:
        pids = [pd.p_create() for _ in range(2)]
        svc = serve_decode(pd, cfg, num_pages=16, page_size=8,
                           max_active=2, warmup_buckets=(8,))
        try:
            base = svc.generate(prompt, max_new=4)
            cold = _cold()
            gen = pd.store.generation()
            with svc.scheduler.step_lock:          # churn vs decode steps
                twin = pd.p_clone(pids[0], jitter=0.01)
            widened = svc.generate(prompt, max_new=4)
            assert len(widened.tokens) == 4        # BMA over 3 live rows
            with svc.scheduler.step_lock:
                pd.p_kill(twin)
            back = svc.generate(prompt, max_new=4)
            assert back.tokens == base.tokens      # live set restored
            assert _cold() == cold, "decode churn must not recompile"
            assert pd.store.generation() == gen
            dec = pd.stats()["decode"]
            assert dec["retired"] == 3
            assert dec["pool"]["used_pages"] == 0
        finally:
            svc.close()


def test_speculative_decode_survives_churn_with_zero_cold_compiles():
    """Speculative BMA decode under clone/kill churn: the draft program
    slices the draft row by a *traced* slot scalar and the verify
    program runs on the capacity-padded stack, so within-capacity churn
    — including killing the current draft particle, forcing a slot
    re-pick — recompiles nothing, never bumps the generation, and after
    the round-trip reproduces the pre-churn tokens exactly."""
    from repro import configs
    from repro.models import api
    from repro.serve import serve_decode

    cfg = configs.get("qwen1.5-0.5b").replace(
        n_units=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=128, max_seq_len=64)
    lm = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)
    prompt = [3, 5, 7, 11, 13]
    with PushDistribution(lm, num_devices=1, seed=0, capacity=4) as pd:
        pids = [pd.p_create() for _ in range(2)]
        svc = serve_decode(pd, cfg, num_pages=16, page_size=8,
                           max_active=2, warmup_buckets=(8,),
                           speculative=True)
        try:
            base = svc.generate(prompt, max_new=4)
            cold = _cold()
            gen = pd.store.generation()
            with svc.scheduler.step_lock:          # churn vs decode steps
                twin = pd.p_clone(pids[0], jitter=0.01)
            widened = svc.generate(prompt, max_new=4)
            assert len(widened.tokens) == 4        # BMA over 3 live rows
            with svc.scheduler.step_lock:
                pd.p_kill(twin)
            back = svc.generate(prompt, max_new=4)
            assert back.tokens == base.tokens      # live set restored
            with svc.scheduler.step_lock:
                # kill the particle currently drafting: the engine must
                # re-pick a live slot (one scalar upload, no compile)
                pd.p_kill(pids[0])
            solo = svc.generate(prompt, max_new=4)
            assert len(solo.tokens) == 4
            assert _cold() == cold, "spec decode churn must not recompile"
            assert pd.store.generation() == gen
            dec = svc.stats()
            assert dec["retired"] == 4
            assert dec["pool"]["used_pages"] == 0
            assert dec["engine"]["slot_uploads"] >= 2
            assert dec["speculative"]["spec_steps"] > 0
        finally:
            svc.close()


def test_bf16_serving_survives_churn_with_zero_cold_compiles():
    """PR 5's invariant survives the precision ladder: on a store with a
    bf16 serve copy ("mixed"), clone/kill churn compiles NOTHING — the
    serve-cast program and the BMA forward both key on capacity-padded
    shapes plus the precision token, and neither moves during churn."""
    with PushDistribution(_module(), num_devices=1, seed=0,
                          backend="compiled", capacity=4,
                          precision="mixed") as pd:
        pids = [pd.p_create(sgd(0.05)) for _ in range(4)]
        x = jax.random.normal(jax.random.PRNGKey(9), (8, 3))
        eng = PredictiveEngine(pd.module.forward, store=pd.store,
                               kind="regress")
        assert eng.precision.casts_serve
        eng.predict((x, None))                     # warm compile
        cold = _cold()
        for round_ in range(3):
            victim = pd.particle_ids()[0]
            pd.p_kill(victim)
            src = pd.particle_ids()[0]
            pd.p_clone(src, jitter=0.01)
            heads = eng.predict((x, None))
            live = pd.particle_ids()
            ref = np.mean([np.asarray(x @ pd.p_params(p)["w"]
                                      + pd.p_params(p)["b"])
                           for p in live], 0)
            # bf16 serve copy: tolerance is rounding, not exactness
            assert np.abs(np.asarray(heads["mean"]) - ref).max() < 0.05
        assert _cold() == cold, \
            "bf16 churn must not recompile serving or serve-cast programs"


def test_bf16_decode_serving_steady_state_compiles_nothing():
    """PR 6's invariant on a pure-bf16 store: steady-state paged decode
    (and one clone/kill round-trip) cold-compiles nothing, and greedy
    decode stays deterministic across the churn."""
    from repro import configs
    from repro.models import api
    from repro.serve import serve_decode

    cfg = configs.get("qwen1.5-0.5b").replace(
        n_units=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=128, max_seq_len=64)
    lm = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)
    prompt = [3, 5, 7, 11, 13]
    with PushDistribution(lm, num_devices=1, seed=0, capacity=4,
                          precision="bf16") as pd:
        pids = [pd.p_create() for _ in range(2)]
        for p in pids:
            leaves = jax.tree.leaves(pd.p_params(p))
            assert all(l.dtype == jnp.bfloat16 for l in leaves)
        svc = serve_decode(pd, cfg, num_pages=16, page_size=8,
                           max_active=2, warmup_buckets=(8,))
        try:
            base = svc.generate(prompt, max_new=4)
            cold = _cold()
            again = svc.generate(prompt, max_new=4)
            assert again.tokens == base.tokens      # steady-state greedy
            with svc.scheduler.step_lock:
                twin = pd.p_clone(pids[0], jitter=0.01)
            svc.generate(prompt, max_new=4)
            with svc.scheduler.step_lock:
                pd.p_kill(twin)
            back = svc.generate(prompt, max_new=4)
            assert back.tokens == base.tokens
            assert _cold() == cold, \
                "bf16 decode steady state must not recompile"
        finally:
            svc.close()


def test_fused_training_after_churn_reuses_program():
    data = [_batch()]
    with DeepEnsemble(_module(), num_devices=1, seed=0,
                      backend="compiled") as de:
        pd = de.push_dist
        pids, _ = de.bayes_infer(data, 2, optimizer=sgd(0.05),
                                 num_particles=4)
        cold = _cold()
        pd.p_kill(pids[1])
        pd.p_clone(pids[0], jitter=0.01)
        # same capacity, same generation -> the padded-path epoch loop
        # reuses the compiled step exactly
        de._fused_epochs(pd.store.pids, data, 2, optimizer=sgd(0.05))
        assert _cold() == cold + 1  # one new optimizer identity compiles
        de._fused_epochs(pd.store.pids, data, 2, optimizer=sgd(0.05))
        # regression: after churn, pid order != slot order — enumerating
        # via particle_ids() must still take the padded zero-recompile
        # path (set comparison, not list order)
        assert pd.particle_ids() != pd.store.pids
        cold2 = _cold()
        opt = sgd(0.05)
        de._fused_epochs(pd.particle_ids(), data, 1, optimizer=opt)
        de._fused_epochs(pd.store.pids, data, 1, optimizer=opt)
        assert _cold() == cold2 + 1  # ONE compile (new opt), shared by both


def test_members_returns_live_rows_only_after_churn():
    with PushDistribution(_module(), num_devices=1, seed=0,
                          backend="compiled", capacity=4) as pd:
        pids = [pd.p_create(sgd(0.1)) for _ in range(4)]
        x = jax.random.normal(jax.random.PRNGKey(7), (5, 3))
        eng = PredictiveEngine(pd.module.forward, store=pd.store,
                               kind="regress")
        _, outs = eng.predict((x, None), members=True)
        assert outs.shape[0] == 4
        pd.p_kill(pids[3])
        _, outs = eng.predict((x, None), members=True)
        assert outs.shape[0] == 3          # live rows only, slot order
        ref = np.stack([np.asarray(x @ pd.p_params(p)["w"]
                                   + pd.p_params(p)["b"])
                        for p in pd.store.pids])
        assert np.abs(np.asarray(outs) - ref).max() < 1e-5


# ---------------------------------------------------------------------------
# lifecycle policies (bdl/lifecycle.py)
# ---------------------------------------------------------------------------

def test_systematic_counts_preserve_total_and_favor_weight():
    counts = lifecycle.systematic_counts([0.7, 0.1, 0.1, 0.1], 4,
                                         np.random.default_rng(0))
    assert sum(counts) == 4
    assert counts[0] >= 2               # heavy lineage multiplies


def test_resample_preserves_live_count_and_capacity():
    data = [_batch()]
    with DeepEnsemble(_module(), num_devices=1, seed=0,
                      backend="compiled") as de:
        pd = de.push_dist
        de.bayes_infer(data, 2, optimizer=sgd(0.05), num_particles=4)
        cap, gen = pd.store.capacity, pd.store.generation()
        lifecycle.ensemble_weights(de, data[0])  # warm the loss program
        cold = _cold()
        live = lifecycle.resample(de, batch=data[0], jitter=0.01,
                                  rng=np.random.default_rng(1))
        assert len(live) == 4
        assert pd.store.capacity == cap and pd.store.generation() == gen
        assert _cold() == cold          # churn compiled nothing
        stats = pd.stats()["lifecycle"]
        assert stats["kills"] == stats["clones"]


def test_grow_warm_starts_from_best_member():
    data = [_batch()]
    with DeepEnsemble(_module(), num_devices=1, seed=0,
                      backend="compiled") as de:
        pd = de.push_dist
        de.bayes_infer(data, 2, optimizer=sgd(0.05), num_particles=2)
        w = lifecycle.ensemble_weights(de, data[0])
        best = max(w, key=w.get)
        new = lifecycle.grow(de, 2, jitter=0.02, weights=w,
                             optimizer=sgd(0.05))
        assert len(pd.particle_ids()) == 4
        for pid in new:
            d = float(jnp.abs(pd.p_params(pid)["w"]
                              - pd.p_params(best)["w"]).max())
            assert 0.0 < d < 0.5        # warm start near the best member
        # progressive training continues over the widened ensemble
        de._fused_epochs(pd.store.pids, data, 2, optimizer=sgd(0.05))


def test_infer_forwards_capacity_for_recompile_free_growth():
    data = [_batch()]
    with DeepEnsemble(_module(), num_devices=1, seed=0, backend="compiled",
                      capacity=8) as de:
        pd = de.push_dist
        assert pd.store.capacity == 8
        de.bayes_infer(data, 1, optimizer=sgd(0.05), num_particles=4)
        gen = pd.store.generation()
        lifecycle.grow(de, 2, jitter=0.01)     # fits: no doubling
        assert pd.store.generation() == gen
        assert pd.store.capacity == 8


def test_prune_keeps_heaviest_members():
    with PushDistribution(_module(), num_devices=1, capacity=4) as pd:
        pids = [pd.p_create(sgd(0.1)) for _ in range(4)]
        weights = {p: float(i) for i, p in enumerate(pids)}
        live = lifecycle.prune(pd, 2, weights=weights)
        assert sorted(live) == sorted(pids[2:])


def test_slot_activates_only_after_data_lands():
    """The churn window a concurrent serve could hit: between register
    and the first state write the slot must stay masked OFF — otherwise
    the BMA averages the previous occupant's stale row (or zeros)."""
    store = ParticleStore(capacity=4)
    store.register(0)
    assert np.asarray(store.active_mask()).sum() == 0     # no data yet
    store.write("params", 0, {"w": jnp.ones((2,))})
    assert np.allclose(np.asarray(store.active_mask()), [1, 0, 0, 0])
    store.register(1)
    assert np.allclose(np.asarray(store.active_mask()), [1, 0, 0, 0])
    store.write("params", 1, {"w": jnp.zeros((2,))})
    assert np.allclose(np.asarray(store.active_mask()), [1, 1, 0, 0])


def test_mid_run_register_survives_full_commit():
    """A particle created while a fused run holds a full checkout keeps
    its written params across that run's commit (the commit only covers
    the cohort it checked out)."""
    store = ParticleStore(capacity=4)
    for pid in range(2):
        store.register(pid)
        store.write("params", pid, {"w": jnp.full((2,), float(pid))})
    co = store.checkout("params")
    assert jax.tree.leaves(co)[0].shape[0] == 4
    store.register(5)                                     # mid-run create
    store.write("params", 5, {"w": jnp.full((2,), 9.0)})
    new = jax.tree.map(lambda x: x + 1.0, co)
    store.commit("params", new)
    # the committing run's cohort took the +1; the newcomer kept its row
    assert float(store.read("params", 0)["w"][0]) == 1.0
    assert float(store.read("params", 1)["w"][0]) == 2.0
    assert float(store.read("params", 5)["w"][0]) == 9.0
    st = store.stacked("params")
    assert float(jax.tree.leaves(st)[0][store.slot_of(5), 0]) == 9.0
    assert np.asarray(store.active_mask()).sum() == 3


def test_clone_during_checkout_fails_loudly():
    """Cloning a key a fused run has checked out is impossible (the data
    moved out, likely donated) — the error must say so, not claim the
    data is missing."""
    store = ParticleStore(capacity=4)
    store.register(0)
    store.write("params", 0, {"w": jnp.ones((2,))})
    store.register(1)
    co = store.checkout("params")
    with pytest.raises(RuntimeError, match="checked out"):
        store.clone_slot("params", 0, 1)
    store.commit("params", co)
    store.clone_slot("params", 0, 1)            # fine after commit
    assert float(store.read("params", 1)["w"][0]) == 1.0


def test_capacity_growth_during_checkout_does_not_lose_the_run():
    """A p_create that doubles capacity while a fused run holds a full
    checkout must not make the run's commit fail (the committed tree is
    validated against the checkout-era capacity and padded up)."""
    store = ParticleStore()                     # grows on demand
    for pid in range(2):
        store.register(pid)
        store.write("params", pid, {"w": jnp.full((2,), float(pid))})
    co = store.checkout("params")               # capacity 2
    store.register(2)                           # grow: capacity 2 -> 4
    store.write("params", 2, {"w": jnp.full((2,), 7.0)})
    store.commit("params", jax.tree.map(lambda x: x + 1.0, co))
    assert store.capacity == 4
    assert float(store.read("params", 0)["w"][0]) == 1.0
    assert float(store.read("params", 2)["w"][0]) == 7.0
    st = store.stacked("params")
    assert jax.tree.leaves(st)[0].shape[0] == 4


# ---------------------------------------------------------------------------
# elastic checkpoint: restore across capacities
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_across_capacities(tmp_path):
    from repro.checkpoint import restore_store, save_store
    with PushDistribution(_module(), num_devices=1, capacity=4) as pd:
        pids = [pd.p_create(sgd(0.1)) for _ in range(4)]
        pd.p_kill(pids[2])              # a hole in the slot layout
        save_store(str(tmp_path), 1, pd.store)
        live = pd.store.pids
        for cap, want_cap in ((None, 4), (8, 8), (2, 4)):
            # saved capacity / grown / shrink-to-fit (2 < 3 live -> 4)
            step, s2 = restore_store(str(tmp_path), capacity=cap)
            assert step == 1 and s2.pids == live
            assert s2.capacity == want_cap
            assert int(np.asarray(s2.active_mask()).sum()) == 3
            for p in live:
                a = pd.store.read("params", p)
                b = s2.read("params", p)
                for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                    assert np.array_equal(np.asarray(u), np.asarray(v))
            # restored store serves immediately with masked BMA
            eng = PredictiveEngine(pd.module.forward, store=s2,
                                   kind="regress")
            x = jax.random.normal(jax.random.PRNGKey(2), (4, 3))
            heads = eng.predict((x, None))
            ref = np.mean([np.asarray(x @ pd.p_params(p)["w"]
                                      + pd.p_params(p)["b"])
                           for p in live], 0)
            assert np.abs(np.asarray(heads["mean"]) - ref).max() < 1e-5


# ---------------------------------------------------------------------------
# stats surface
# ---------------------------------------------------------------------------

def test_stats_exposes_lifecycle_counters():
    with PushDistribution(_module(), num_devices=1, capacity=4) as pd:
        a = pd.p_create(sgd(0.1))
        b = pd.p_clone(a)
        pd.p_kill(b)
        pd.p_rebalance()
        lc = pd.stats()["lifecycle"]
        assert lc["capacity"] == 4 and lc["live"] == 1
        assert lc["free_slots"] == 3
        assert lc["clones"] == 1 and lc["kills"] == 1
        assert lc["rebalances"] == 1
        assert lc["mask_invalidations"] >= 3
