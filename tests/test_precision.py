"""Mixed-precision policy (DESIGN.md §13): bf16-compute train parity
against fp32, fp32-master update exactness where pure bf16 stalls, int8
BMA serving tolerance, precision-in-the-ProgramCache-key, checkpoint
dtype round-trips in both directions, the named remat-policy menu, and
policy-aware byte estimates / model-axis sizing.

The acceptance bar: "mixed" (fp32 masters, bf16 compute) tracks fp32
training within tolerance while masters stay float32; tiny constant
updates (1e-3 at w=1.0, below the bf16 spacing of 2^-8) accumulate in
fp32 masters but round away in a pure-bf16 store; switching precision is
a cache MISS (cold compile), re-running the same precision is a HIT.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParticleModule, PushDistribution
from repro.core.precision import (PRESETS, Precision, cast_floats,
                                  checkpoint_policy, dequantize, get,
                                  quantize_int8, tree_bytes)
from repro.optim import sgd
from repro.runtime import global_cache, specs
from repro.serve import PredictiveEngine


def _module():
    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"w": jax.random.normal(k1, (3, 4)) * 0.5,
                "b": jax.random.normal(k2, (4,)) * 0.1}

    def loss(p, b):
        return jnp.mean((b[0] @ p["w"] + p["b"] - b[1]) ** 2), {}

    def fwd(p, b):
        return b[0] @ p["w"] + p["b"]

    return ParticleModule(init, loss, fwd)


def _batch(m=8, seed=3):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, 3))
    return (x, x @ jnp.ones((3, 4)))


def _cold():
    return global_cache().snapshot_stats()["cold_compiles"]


def _stacked(mod, n=4, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), n)
    return jax.vmap(mod.init)(ks)


# ---------------------------------------------------------------------------
# policy resolution + presets
# ---------------------------------------------------------------------------

def test_preset_ladder_resolves():
    assert get(None) == PRESETS["fp32"]
    assert get("mixed").casts_compute and not get("fp32").casts_compute
    assert get("bf16").master == jnp.dtype(jnp.bfloat16)
    assert get("mixed_int8").serve_quant == "int8"
    p = get("mixed")
    assert get(p) is p                     # Precision passes through
    with pytest.raises(ValueError):
        get("fp8_dreams")


def test_precision_key_distinguishes_policies():
    keys = {get(name).key() for name in PRESETS}
    assert len(keys) == len(PRESETS)


# ---------------------------------------------------------------------------
# bf16 compute parity: "mixed" tracks fp32 training within tolerance
# ---------------------------------------------------------------------------

def test_bf16_compute_train_loss_tracks_fp32():
    mod, opt = _module(), sgd(0.05)
    batch, mask = _batch(), jnp.ones((4,))
    cache = global_cache()
    finals = {}
    for name in ("fp32", "mixed"):
        params = _stacked(mod)                     # same seed -> same init
        opt_state = jax.vmap(opt.init)(params)
        spec = specs.ensemble_step(mod.loss, opt, precision=name)
        for _ in range(5):
            params, opt_state, losses = cache.run(spec, params, opt_state,
                                                  batch, mask)
        finals[name] = float(jnp.mean(losses))
        # masters never leave fp32 under "mixed"
        assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(params))
        assert losses.dtype == jnp.float32         # losses surface as fp32
    assert abs(finals["mixed"] - finals["fp32"]) < \
        0.1 * abs(finals["fp32"]) + 0.05


def test_nel_and_compiled_backends_agree_under_mixed():
    # both runtimes implement the SAME master/compute split: the actor
    # path's per-particle value_and_grad traces the bf16 cast exactly
    # like core.functional.ensemble_step does on the stacked axis
    from repro.bdl import DeepEnsemble
    from repro.optim import adam

    mod, data = _module(), [_batch()]
    preds = {}
    for be in ("nel", "compiled"):
        with DeepEnsemble(mod, num_devices=1, seed=0, backend=be,
                          precision="mixed") as de:
            de.bayes_infer(data, 5, optimizer=adam(1e-2), num_particles=4)
            preds[be] = np.asarray(de.posterior_pred(data[0]))
    err = np.abs(preds["nel"] - preds["compiled"]).max()
    assert err < 1e-4, f"nel vs compiled under mixed precision: {err}"


# ---------------------------------------------------------------------------
# master-weight exactness: tiny steps survive fp32 accumulation, not bf16
# ---------------------------------------------------------------------------

def test_fp32_masters_accumulate_updates_below_bf16_spacing():
    # constant gradient 1e-3; bf16 spacing at 1.0 is 2^-8 = 0.0039, so a
    # pure-bf16 store rounds every update away and never moves, while
    # fp32 masters (even with bf16 compute) accumulate all 50 steps
    def init(rng):
        return {"w": jnp.ones((4,))}

    def loss(p, b):
        return 1e-3 * jnp.sum(p["w"]), {}

    mod = ParticleModule(init, loss, lambda p, b: p["w"])
    opt = sgd(1.0)
    batch, mask = (jnp.zeros((1,)),), jnp.ones((2,))
    cache = global_cache()
    out = {}
    for name in ("mixed", "bf16"):
        params = _stacked(mod, n=2)
        if get(name).master != jnp.dtype(jnp.float32):
            params = cast_floats(params, get(name).master)
        opt_state = jax.vmap(opt.init)(params)
        spec = specs.ensemble_step(mod.loss, opt, precision=name)
        for _ in range(50):
            params, opt_state, _ = cache.run(spec, params, opt_state,
                                             batch, mask)
        out[name] = np.asarray(params["w"], np.float32)
    assert np.all(out["bf16"] == 1.0), "bf16 masters must stall (spacing)"
    assert np.abs(out["mixed"] - 0.95).max() < 2e-3, \
        "fp32 masters must accumulate ~50 x 1e-3"


# ---------------------------------------------------------------------------
# serving: bf16 + int8 ensembles within tolerance of fp32 masters
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,tol", [("mixed", 0.03), ("mixed_int8", 0.06)])
def test_quantized_serving_heads_match_fp32_reference(policy, tol):
    with PushDistribution(_module(), num_devices=1, capacity=4,
                          precision=policy) as pd:
        pids = [pd.p_create(sgd(0.1)) for _ in range(4)]
        x = jax.random.normal(jax.random.PRNGKey(5), (6, 3))
        eng = PredictiveEngine(pd.module.forward, store=pd.store,
                               kind="regress")
        assert eng.precision.casts_serve          # inherited from the store
        heads = eng.predict((x, None))
        assert heads["mean"].dtype == jnp.float32  # heads surface as fp32
        ref = np.mean([np.asarray(x @ pd.p_params(p)["w"]
                                  + pd.p_params(p)["b"]) for p in pids], 0)
        assert np.abs(np.asarray(heads["mean"]) - ref).max() < tol


def test_int8_quantization_roundtrip_error_bounded():
    w = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 8)) * 0.5
    q = quantize_int8({"w": w})["w"]
    assert q["q"].dtype == jnp.int8 and q["s"].shape == (2, 1, 8)
    back = np.asarray(dequantize({"w": q}, jnp.float32)["w"])
    # per-channel scale bounds the error at scale/2 = amax/254
    amax = np.abs(np.asarray(w)).max(axis=1, keepdims=True)
    assert np.abs(back - np.asarray(w)).max() <= (amax / 254 + 1e-7).max()


# ---------------------------------------------------------------------------
# the ProgramCache keys on precision: switch = cold, re-run = warm
# ---------------------------------------------------------------------------

def test_precision_is_a_cache_key_dimension():
    mod, opt = _module(), sgd(0.1)
    params = _stacked(mod)
    opt_state = jax.vmap(opt.init)(params)
    args = (params, opt_state, _batch(), jnp.ones((4,)))
    s_fp32 = specs.ensemble_step(mod.loss, opt)
    s_mixed = specs.ensemble_step(mod.loss, opt, precision="mixed")
    # fp32 carries NO precision token: cache keys stay byte-compatible
    # with pre-policy entries (and AOT-preloaded blobs)
    assert s_fp32.precision is None
    assert s_mixed.precision == get("mixed").key()
    cache = global_cache()
    c0 = _cold()
    cache.lookup(s_fp32, None, args)
    assert _cold() == c0 + 1
    # same abstract args (the cast is traced INSIDE), still a distinct key
    cache.lookup(s_mixed, None, args)
    assert _cold() == c0 + 2
    cache.lookup(s_mixed, None, args)          # re-run same policy: warm
    assert _cold() == c0 + 2


# ---------------------------------------------------------------------------
# checkpoint: dtypes round-trip, restore re-casts in both directions
# ---------------------------------------------------------------------------

def test_checkpoint_preserves_bf16_and_recasts_up(tmp_path):
    from repro.checkpoint import restore_store, save_store
    with PushDistribution(_module(), num_devices=1, capacity=4,
                          precision="bf16") as pd:
        pids = [pd.p_create(sgd(0.1)) for _ in range(3)]
        want = {p: jax.tree.map(np.asarray, pd.p_params(p)) for p in pids}
        save_store(str(tmp_path), 1, pd.store)
    # default restore revives the saved policy: bfloat16 comes back exact
    # (npz stores a widened fp32 copy; the manifest's per-leaf dtypes
    # drive the re-cast — bf16 -> fp32 -> bf16 is lossless)
    _, s2 = restore_store(str(tmp_path))
    assert s2.precision.master == jnp.dtype(jnp.bfloat16)
    for p in pids:
        got = s2.read("params", p)
        assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(got))
        for u, v in zip(jax.tree.leaves(want[p]), jax.tree.leaves(got)):
            assert np.array_equal(u.astype(np.float32),
                                  np.asarray(v, np.float32))
    # explicit override widens on load: fp32 store from a bf16 ckpt
    _, s3 = restore_store(str(tmp_path), precision="fp32")
    got = s3.read("params", pids[0])
    assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(got))


def test_checkpoint_recasts_fp32_down_to_bf16(tmp_path):
    from repro.checkpoint import restore_store, save_store
    with PushDistribution(_module(), num_devices=1, capacity=4) as pd:
        pids = [pd.p_create(sgd(0.1)) for _ in range(2)]
        want = {p: jax.tree.map(np.asarray, pd.p_params(p)) for p in pids}
        save_store(str(tmp_path), 2, pd.store)
    _, s2 = restore_store(str(tmp_path), precision="bf16")
    assert s2.precision.master == jnp.dtype(jnp.bfloat16)
    for p in pids:
        got = s2.read("params", p)
        for u, v in zip(jax.tree.leaves(want[p]), jax.tree.leaves(got)):
            assert v.dtype == jnp.bfloat16
            assert np.array_equal(u.astype(jnp.bfloat16), np.asarray(v))


# ---------------------------------------------------------------------------
# remat-policy menu
# ---------------------------------------------------------------------------

def test_checkpoint_policy_menu():
    for name in ("dots_saveable", "nothing_saveable",
                 "dots_with_no_batch_dims"):
        assert callable(checkpoint_policy(name))
    with pytest.raises(ValueError):
        checkpoint_policy("everything_is_saveable")


def test_remat_policy_preserves_transformer_loss_and_grads():
    from repro import configs
    from repro.models import api

    cfg = configs.get("qwen1.5-0.5b").replace(
        n_units=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=128, max_seq_len=32)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)
    batch = {"tokens": tok, "labels": tok}
    base, _ = jax.value_and_grad(
        lambda p: api.loss_fn(p, batch, cfg)[0])(params)
    for name in ("dots_saveable", "nothing_saveable"):
        c2 = cfg.replace(remat_policy=name)
        loss, _ = jax.value_and_grad(
            lambda p: api.loss_fn(p, batch, c2)[0])(params)
        assert abs(float(loss) - float(base)) < 1e-4, name


# ---------------------------------------------------------------------------
# policy-aware byte estimates + model-axis sizing
# ---------------------------------------------------------------------------

def test_param_footprint_halves_under_bf16():
    from repro import configs
    from repro.models import api

    cfg = configs.get("qwen1.5-0.5b").replace(
        n_units=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=128, max_seq_len=32)
    f32 = api.param_footprint(cfg)
    bf16 = api.param_footprint(cfg, "bf16")
    assert f32 == 2 * bf16


def test_pick_model_axis_is_precision_aware():
    from repro.launch.mesh import pick_model_axis
    GB = 1 << 30
    # fp32 particle needs a 4-way split; the bf16 version fits on 2
    assert pick_model_axis(2 * GB, 4, device_memory_bytes=GB) == 4
    assert pick_model_axis(1 * GB, 4, device_memory_bytes=GB) == 2
    assert pick_model_axis(0, 4, device_memory_bytes=GB) == 1


def test_store_reports_master_dtype_bytes():
    from repro.obs.device import store_gauges
    per = {}
    for name in ("fp32", "bf16"):
        with PushDistribution(_module(), num_devices=1, capacity=4,
                              precision=name) as pd:
            pd.p_create(sgd(0.1))
            g = store_gauges(pd.store)
            per[name] = g["per_particle_bytes"]["params"]
            assert g["precision"]["master"] == str(get(name).master)
    assert per["fp32"] == 2 * per["bf16"]


def test_tree_bytes_counts_floats_at_master_itemsize():
    tree = {"w": jnp.zeros((8, 4)), "step": jnp.zeros((), jnp.int32)}
    assert tree_bytes(tree) == 8 * 4 * 4 + 4
    assert tree_bytes(tree, "bf16") == 8 * 4 * 2 + 4
