"""Sharded-compiled acceptance check (run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``; see
tests/test_store.py and the CI sharded matrix job).

Asserts, for DeepEnsemble / MultiSWAG / SteinVGD under a 4-device mesh
placement:
  1. the fused path runs with the particle axis sharded across all 4
     devices (sharding inspection of the store's stacked state);
  2. the sharded compiled backend matches the NEL backend to < 1e-4;
  3. a multi-epoch fused run performs zero per-epoch host transfers of
     stacked state — one checkout before the loop, donated buffers inside
     it (the input buffer is consumed by XLA), one commit at the end.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp

from repro.bdl import DeepEnsemble, MultiSWAG, SteinVGD
from repro.core import ParticleModule, Placement
from repro.launch.mesh import make_bench_mesh
from repro.optim import sgd

N_DEV = 4
N_PARTICLES = 4


def tiny_module():
    def init(rng):
        return {"w": jax.random.normal(rng, (3, 2)) * 0.5,
                "b": jnp.zeros((2,))}

    def loss(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2), {}

    def fwd(p, batch):
        return batch[0] @ p["w"] + p["b"]

    return ParticleModule(init, loss, fwd)


def data():
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 3))
    return [(x, x @ jnp.ones((3, 2)))]


def check_particle_axis_sharded(store, key):
    st = store.stacked(key)
    for path, leaf in jax.tree_util.tree_flatten_with_path(st)[0]:
        if leaf.ndim == 0:
            continue
        spec = leaf.sharding.spec
        assert spec and spec[0] == "data", \
            f"{key}{path}: particle axis not sharded, spec={spec}"
        devs = {s.device.id for s in leaf.addressable_shards}
        assert len(devs) == N_DEV, \
            f"{key}{path}: {len(devs)} devices hold shards, want {N_DEV}"


def main():
    assert len(jax.devices()) == N_DEV, \
        f"need {N_DEV} forced host devices, got {len(jax.devices())}"
    mesh = make_bench_mesh(N_DEV)
    placement = Placement(mesh=mesh, particle_axis="data", mode="tp")

    batches = data()
    for algo, kw in [
        (DeepEnsemble, dict(optimizer=sgd(0.05), num_particles=N_PARTICLES)),
        (MultiSWAG, dict(optimizer=sgd(0.05), num_particles=N_PARTICLES,
                         max_rank=4)),
        (SteinVGD, dict(num_particles=N_PARTICLES, lr=0.05, lengthscale=1.0)),
    ]:
        preds, params = {}, {}
        for backend, pl_ in (("nel", None), ("compiled", placement)):
            with algo(tiny_module(), num_devices=1, seed=0, backend=backend,
                      placement=pl_) as a:
                pids, losses = a.bayes_infer(batches, 3, **kw)
                preds[backend] = a.posterior_pred(batches[0])
                params[backend] = [a.push_dist.p_params(p)["w"] for p in pids]
                if backend == "compiled":
                    check_particle_axis_sharded(a.store, "params")
                    before = a.store.snapshot_stats()
                    extra = (dict(optimizer=kw["optimizer"])
                             if "optimizer" in kw else
                             dict(lr=kw["lr"], lengthscale=kw["lengthscale"]))
                    a._fused_epochs(pids, batches, 5, **extra)
                    after = a.store.snapshot_stats()
                    assert after["unstacks"] == before["unstacks"], \
                        "fused epochs unstacked state mid-run"
                    assert after["stacks"] == before["stacks"], \
                        "fused epochs restacked state mid-run"
                    assert after["device_puts"] == before["device_puts"], \
                        "fused epochs re-placed state mid-run"
                    ncommit = after["commits"] - before["commits"]
                    nco = after["checkouts"] - before["checkouts"]
                    assert 1 <= ncommit <= 3 and ncommit == nco, (before, after)
                    # donation: a checked-out buffer is consumed by the
                    # step program, fetched from the shared ProgramCache
                    # through the compile_* delegators with the store's
                    # generation token — a true cache HIT of the exact
                    # program _fused_epochs lowered (asserted below)
                    from repro.bdl.svgd import compile_svgd_step
                    from repro.core import functional
                    from repro.runtime import global_cache
                    hits0 = global_cache().snapshot_stats()["hits"]
                    tok = a.store.generation()
                    mask = a.store.active_mask()
                    st = a.store.checkout("params", pids)
                    if "optimizer" in kw:
                        ost = a.store.checkout("opt_state", pids)
                        step = functional.compile_ensemble_step(
                            a.module.loss, kw["optimizer"], placement,
                            st, ost, batches[0], mask, state_token=tok)
                        np_, no_, _ = step(st, ost, batches[0], mask)
                        assert st["w"].is_deleted(), "params not donated"
                        a.store.commit("opt_state", no_, pids)
                    else:
                        step = compile_svgd_step(
                            a.module.loss, placement, st, batches[0], mask,
                            lr=kw["lr"], lengthscale=kw["lengthscale"],
                            state_token=tok)
                        np_, _ = step(st, batches[0], mask)
                        assert st["w"].is_deleted(), "params not donated"
                    a.store.commit("params", np_, pids)
                    assert global_cache().snapshot_stats()["hits"] \
                        == hits0 + 1, "compile_* did not share the " \
                        "Runtime's cached program"
        err = float(jnp.abs(preds["nel"] - preds["compiled"]).max())
        assert err < 1e-4, f"{algo.__name__}: pred mismatch {err}"
        for pn, pc in zip(params["nel"], params["compiled"]):
            perr = float(jnp.abs(pn - pc).max())
            assert perr < 1e-4, f"{algo.__name__}: param mismatch {perr}"
        print(f"{algo.__name__}: parity {err:.2e}, particle axis sharded "
              f"over {N_DEV} devices, zero mid-run host transfers, "
              "donation verified")
    print("OK")


if __name__ == "__main__":
    sys.exit(main())
