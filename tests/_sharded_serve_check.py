"""Sharded serving acceptance check (run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``; see
tests/test_serve.py and the CI sharded matrix job).

Asserts, for a PredictiveService over a 4-device mesh placement:
  1. fused BMA predict matches a sequential per-particle forward +
     host-side average to < 1e-5;
  2. serving reads the store WITHOUT unsharding it: across many
     requests, zero restacks / unstacks / device_puts / checkouts of
     stacked state (store.stats deltas are all zero) and the stacked
     params stay sharded over all 4 devices;
  3. the served heads are replicated outputs (safe to hand to any host
     thread) and finite;
  4. the runtime's process-wide ProgramCache dedupes across subsystems
     under the mesh too: repeated identical requests and a SECOND
     service over the same store trigger zero cold compiles while
     ``store.version()`` is unchanged;
  5. the precision ladder survives the mesh: a "mixed" store serves its
     bf16 copy with zero stacked-state traffic per request, zero cold
     compiles under clone/kill churn, and fp32 masters stay sharded.

When ``REPRO_TRACE_OUT`` is set, the whole run executes with obs tracing
enabled and dumps a Perfetto-loadable Chrome trace-event JSON to that
path on success (CI uploads it as an artifact from both sharded jobs).
"""
import json
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np

from repro.bdl import DeepEnsemble
from repro.core import ParticleModule, Placement
from repro.launch.mesh import make_bench_mesh
from repro.optim import sgd

N_DEV = 4
N_PARTICLES = 4
FLAT_KEYS = ("stacks", "unstacks", "device_puts", "checkouts", "commits",
             "row_flushes")


def tiny_module():
    def init(rng):
        return {"w": jax.random.normal(rng, (3, 5)) * 0.5,
                "b": jnp.zeros((5,))}

    def loss(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] + p["b"] - y) ** 2), {}

    def fwd(p, batch):
        return batch["x"] @ p["w"] + p["b"]

    return ParticleModule(init, loss, fwd)


def check_sharded(store, key):
    st = store.stacked(key)
    for path, leaf in jax.tree_util.tree_flatten_with_path(st)[0]:
        spec = leaf.sharding.spec
        assert spec and spec[0] == "data", \
            f"{key}{path}: particle axis not sharded, spec={spec}"
        devs = {s.device.id for s in leaf.addressable_shards}
        assert len(devs) == N_DEV, \
            f"{key}{path}: {len(devs)} devices hold shards, want {N_DEV}"


def main():
    assert len(jax.devices()) == N_DEV, \
        f"need {N_DEV} forced host devices, got {len(jax.devices())}"
    trace_out = os.environ.get("REPRO_TRACE_OUT")
    if trace_out:
        from repro.obs import trace
        trace.enable()
    placement = Placement(mesh=make_bench_mesh(N_DEV), particle_axis="data",
                          mode="tp")
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 3))
    batches = [((x, x @ jnp.ones((3, 5))))]
    train = [((b[0], b[1])) for b in batches]

    with DeepEnsemble(tiny_module(), num_devices=1, seed=0,
                      backend="compiled", placement=placement) as de:
        de.bayes_infer(train, 3, optimizer=sgd(0.05),
                       num_particles=N_PARTICLES)
        check_sharded(de.store, "params")

        # host-side reference BMA (reads views: do this BEFORE the
        # serving-era stats snapshot — view reads legitimately unstack)
        pids = de.push_dist.particle_ids()
        probe = {"x": x}
        member = [np.asarray(batches[0][0] @ de.push_dist.p_params(p)["w"]
                             + de.push_dist.p_params(p)["b"])
                  for p in pids]
        ref_mean = np.mean(np.stack(member), 0)

        with de.posterior_predictive(kind="regress", max_batch=8,
                                     max_wait_ms=1.0) as svc:
            heads = svc.predict_batch(probe)        # warmup + compile
            err = float(np.abs(np.asarray(heads["mean"]) - ref_mean).max())
            assert err < 1e-5, f"fused BMA vs per-particle loop: {err}"

            before = de.store.snapshot_stats()
            for i in range(8):
                pred = svc.predict({"x": np.asarray(x[i % 16])})
                assert np.isfinite(float(pred.entropy))
                assert np.all(np.isfinite(np.asarray(pred.mean)))
            svc.predict_batch(probe)
            after = de.store.snapshot_stats()
            delta = {k: after[k] - before[k] for k in FLAT_KEYS}
            assert all(v == 0 for v in delta.values()), \
                f"serving touched stacked state: {delta}"

            # the store is still sharded over all devices after serving
            check_sharded(de.store, "params")

            # heads come back replicated: any host thread may consume them
            spec = heads["mean"].sharding.spec if hasattr(
                heads["mean"], "sharding") else None
            assert not spec or all(s is None for s in spec), \
                f"heads not replicated: {spec}"

            st = svc.stats()
            assert st["requests"] == 8 and st["batches"] >= 1

            # runtime-layer hook: identical repeat requests are pure
            # cache hits — no cold compiles while version is unchanged
            from repro.runtime import global_cache
            v = de.store.version("params")
            cold0 = global_cache().snapshot_stats()["cold_compiles"]
            svc.predict_batch(probe)
            assert de.store.version("params") == v
            assert global_cache().snapshot_stats()["cold_compiles"] == cold0, \
                "repeat request cold-compiled under the mesh"

        # a fresh service over the same store compiles NOTHING: the
        # ProgramCache is process-wide and keyed on (spec, placement,
        # store generation, bucketed shapes) — not the engine instance
        from repro.runtime import global_cache
        cold0 = global_cache().snapshot_stats()["cold_compiles"]
        with de.posterior_predictive(kind="regress", max_batch=8,
                                     max_wait_ms=1.0) as svc2:
            heads2 = svc2.predict_batch(probe)
            err2 = float(np.abs(np.asarray(heads2["mean"]) - ref_mean).max())
            assert err2 < 1e-5, f"second service BMA: {err2}"
        assert global_cache().snapshot_stats()["cold_compiles"] == cold0, \
            "second service over the same store recompiled"

        # stateful serving under the mesh: per-particle serving state is
        # born sharded over the particle axis and stays there across steps
        from repro.serve import PredictiveEngine

        def step_fwd(p, state, batch):
            out = batch["x"] @ p["w"] + p["b"] + state["acc"]
            return out, {"acc": state["acc"] + 1.0}

        eng = PredictiveEngine(step_fwd, store=de.store, kind="regress",
                               stateful=True)
        state = eng.init_state(lambda p: {"acc": jnp.zeros(())})
        for step in range(2):
            heads, state = eng.step(state, probe)
            want = ref_mean + step
            serr = float(np.abs(np.asarray(heads["mean"]) - want).max())
            assert serr < 1e-5, f"stateful BMA step {step}: {serr}"
        spec = state["acc"].sharding.spec
        assert spec and spec[0] == "data", \
            f"serving state not particle-sharded: {spec}"

        # lifecycle churn under the mesh: clone+kill between requests
        # must cold-compile NOTHING (capacity, shapes and generation are
        # churn-invariant) while the served BMA tracks the live set and
        # params stay sharded over all 4 devices
        pd = de.push_dist
        eng2 = PredictiveEngine(pd.module.forward, store=de.store,
                                kind="regress")
        eng2.predict(probe)                       # shared-cache warm hit
        cold0 = global_cache().snapshot_stats()["cold_compiles"]
        gen0 = de.store.generation()
        puts0 = de.store.snapshot_stats()["device_puts"]
        for _ in range(3):
            victim = pd.particle_ids()[0]
            pd.p_kill(victim)
            pd.p_clone(pd.particle_ids()[0], jitter=0.01)
            heads2 = eng2.predict(probe)
            live = pd.particle_ids()
            ref2 = np.mean([np.asarray(x @ pd.p_params(p)["w"]
                                       + pd.p_params(p)["b"])
                            for p in live], 0)
            cerr = float(np.abs(np.asarray(heads2["mean"]) - ref2).max())
            assert cerr < 1e-5, f"churned BMA vs live reference: {cerr}"
        assert de.store.generation() == gen0, "churn bumped the generation"
        assert global_cache().snapshot_stats()["cold_compiles"] == cold0, \
            "clone/kill churn cold-compiled under the mesh"
        assert de.store.snapshot_stats()["device_puts"] == puts0, \
            "churn re-placed the stacked state"
        assert de.store.capacity == N_PARTICLES
        check_sharded(de.store, "params")
        lc = pd.stats()["lifecycle"]
        assert lc["clones"] == 3 and lc["kills"] == 3 and lc["live"] == 4

    # --- precision phase: a "mixed" store under the mesh. The bf16
    # serve copy is a version-memoized transformed view of the sharded
    # masters: serving still reads NO stacked store state per request,
    # clone/kill churn cold-compiles nothing (the serve-cast program
    # keys on padded shapes + the precision token, both churn-invariant),
    # and a second engine over the same store shares every program.
    from repro.core import PushDistribution
    from repro.runtime import global_cache

    with PushDistribution(tiny_module(), num_devices=1, seed=0,
                          backend="compiled", capacity=N_PARTICLES,
                          placement=placement, precision="mixed") as pdm:
        for _ in range(N_PARTICLES):
            pdm.p_create(sgd(0.05))
        probe = {"x": x}
        engb = PredictiveEngine(pdm.module.forward, store=pdm.store,
                                kind="regress")
        assert engb.precision.casts_serve
        engb.predict(probe)                        # warm (serve cast + BMA)
        # steady state: repeat requests reuse the memoized serve copy —
        # no stacked-state traffic at all (churn below legitimately
        # commits cloned rows, so the zero-delta window ends here)
        before = pdm.store.snapshot_stats()
        for _ in range(3):
            engb.predict(probe)
        after = pdm.store.snapshot_stats()
        bdelta = {k: after[k] - before[k] for k in FLAT_KEYS}
        assert all(v == 0 for v in bdelta.values()), \
            f"bf16 serving touched stacked state: {bdelta}"
        cold0 = global_cache().snapshot_stats()["cold_compiles"]
        for _ in range(3):
            victim = pdm.particle_ids()[0]
            pdm.p_kill(victim)
            pdm.p_clone(pdm.particle_ids()[0], jitter=0.01)
            headsb = engb.predict(probe)
            live = pdm.particle_ids()
            refb = np.mean([np.asarray(x @ pdm.p_params(p)["w"]
                                       + pdm.p_params(p)["b"])
                            for p in live], 0)
            berr = float(np.abs(np.asarray(headsb["mean"]) - refb).max())
            assert berr < 0.05, f"bf16 BMA vs fp32 masters: {berr}"
        assert global_cache().snapshot_stats()["cold_compiles"] == cold0, \
            "bf16 churn cold-compiled under the mesh"
        engb2 = PredictiveEngine(pdm.module.forward, store=pdm.store,
                                 kind="regress")
        engb2.predict(probe)
        assert global_cache().snapshot_stats()["cold_compiles"] == cold0, \
            "second bf16 engine over the same store recompiled"
        check_sharded(pdm.store, "params")         # masters stay sharded

    # --- decode phase: continuous-batching paged decode under the mesh.
    # The KV page pool is store state like params: born sharded over the
    # particle axis, still sharded after serving, and steady-state decode
    # steps (admission + retirement churn included) cold-compile NOTHING.
    from repro import configs
    from repro.core import PushDistribution
    from repro.models import api
    from repro.runtime import global_cache
    from repro.serve import serve_decode

    cfg = configs.get("qwen1.5-0.5b").replace(
        n_units=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=128, max_seq_len=64)
    lm = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)
    with PushDistribution(lm, num_devices=1, seed=0,
                          placement=placement) as pd:
        for _ in range(N_PARTICLES):
            pd.p_create()
        svc = serve_decode(pd, cfg, num_pages=16, page_size=8,
                           max_active=2, decode_kernel=False,
                           warmup_buckets=(8,))
        try:
            check_sharded(pd.store, "kv_pages")
            cold0 = global_cache().snapshot_stats()["cold_compiles"]
            handles = [svc.generate_async([3 + i, 5, 7, 11, 13], max_new=4)
                       for i in range(3)]
            gens = [h.result(300) for h in handles]
            assert all(len(g.tokens) == 4 for g in gens)
            assert global_cache().snapshot_stats()["cold_compiles"] == cold0, \
                "steady-state decode cold-compiled under the mesh"
            check_sharded(pd.store, "kv_pages")    # pages still sharded
            check_sharded(pd.store, "params")
            dec = pd.stats()["decode"]
            assert dec["retired"] == 3, dec
            assert dec["pool"]["used_pages"] == 0, dec
        finally:
            svc.close()

    if trace_out:
        from repro.obs import export
        export.dump_chrome_trace(trace_out)
        with open(trace_out) as f:
            doc = json.load(f)
        evs = doc["traceEvents"]
        assert evs, "trace dump produced no events"
        assert any(e["ph"] == "X" for e in evs), "no duration spans in trace"
        cats = {e.get("cat") for e in evs if e["ph"] in ("X", "i")}
        assert {"runtime", "serve", "decode"} <= cats, \
            f"trace missing expected categories: {cats}"
        print(f"perfetto trace: {len(evs)} events -> {trace_out}")

    print(f"parity {err:.2e}, stacked state untouched across requests "
          f"({N_DEV} devices), heads replicated, stateful state sharded, "
          "churn cold-compiled nothing (fp32 AND bf16 serve copies), "
          "decode pages stayed sharded")
    print("OK")


if __name__ == "__main__":
    sys.exit(main())
