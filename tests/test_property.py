"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st

from repro.bdl import pairwise_sqdist, svgd_force
from repro.bdl.swag import swag_collect, swag_state_init
from repro.data.loader import DataLoader
from repro import configs

SET = dict(deadline=None, max_examples=20)


@settings(**SET)
@given(n=st.integers(2, 6), d=st.integers(1, 16), seed=st.integers(0, 100))
def test_sqdist_metric_properties(n, d, seed):
    t = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    d2 = pairwise_sqdist(t)
    assert float(jnp.abs(d2 - d2.T).max()) < 1e-4          # symmetry
    assert float(jnp.diag(d2).max()) < 1e-4                # d(x,x)=0
    assert float(d2.min()) >= -1e-5                        # non-negativity


@settings(**SET)
@given(n=st.integers(2, 5), d=st.integers(2, 8), seed=st.integers(0, 50))
def test_svgd_force_permutation_equivariance(n, d, seed):
    """svgd_force(P theta) == P svgd_force(theta) for particle permutations."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    theta = jax.random.normal(k1, (n, d))
    grads = jax.random.normal(k2, (n, d))
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), n)
    f = svgd_force(theta, grads, 1.0)
    fp = svgd_force(theta[perm], grads[perm], 1.0)
    assert float(jnp.abs(f[perm] - fp).max()) < 1e-4


@settings(**SET)
@given(n=st.integers(2, 5), d=st.integers(2, 8), seed=st.integers(0, 50),
       shift=st.floats(-3, 3))
def test_svgd_force_translation_invariance(n, d, seed, shift):
    """RBF kernel depends only on differences -> force is translation-inv."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    theta = jax.random.normal(k1, (n, d))
    grads = jax.random.normal(k2, (n, d))
    f1 = svgd_force(theta, grads, 1.0)
    f2 = svgd_force(theta + shift, grads, 1.0)
    assert float(jnp.abs(f1 - f2).max()) < 1e-3


@settings(**SET)
@given(seed=st.integers(0, 50), n_steps=st.integers(1, 8))
def test_swag_streaming_mean_matches_batch(seed, n_steps):
    rng = jax.random.PRNGKey(seed)
    thetas = []
    st_ = None
    for i in range(n_steps):
        rng, sub = jax.random.split(rng)
        th = {"w": jax.random.normal(sub, (17,))}
        thetas.append(th)
        if st_ is None:
            st_ = swag_state_init(th, max_rank=4)
        st_ = swag_collect(st_, th, use_kernel=False)
    stacked = jnp.stack([t["w"] for t in thetas])
    assert float(jnp.abs(st_["mean"]["w"] - stacked.mean(0)).max()) < 1e-4
    var_emp = (stacked ** 2).mean(0) - stacked.mean(0) ** 2
    var_swag = st_["sq_mean"]["w"] - st_["mean"]["w"] ** 2
    assert float(jnp.abs(var_emp - var_swag).max()) < 1e-3


@settings(**SET)
@given(seed=st.integers(0, 1000))
def test_dataloader_deterministic(seed):
    cfg = configs.get("qwen1.5-0.5b").smoke()
    d1 = DataLoader(cfg, batch_size=2, seq_len=16, num_batches=2, seed=seed)
    d2 = DataLoader(cfg, batch_size=2, seq_len=16, num_batches=2, seed=seed)
    for b1, b2 in zip(d1, d2):
        assert np.array_equal(b1["tokens"], b2["tokens"])


@settings(**SET)
@given(seed=st.integers(0, 100), vocab=st.integers(8, 64))
def test_lm_batch_labels_are_next_tokens(seed, vocab):
    from repro.data.synthetic import lm_batch
    b = lm_batch(np.random.default_rng(seed), 2, 16, vocab)
    assert b["tokens"].shape == b["labels"].shape == (2, 16)
    assert b["tokens"].max() < vocab
    # labels[t] is the stream's tokens shifted by one
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


@settings(**SET)
@given(seed=st.integers(0, 30))
def test_advection_exact_shift(seed):
    from repro.data.synthetic import advection_batch
    b = advection_batch(np.random.default_rng(seed), 2, L=64, c=1.0, dt=4.0)
    assert np.allclose(np.roll(b["u0"], 4, axis=1), b["u1"])


# ---------------------------------------------------------------------------
# sharding/rules.param_spec laws (ISSUE-7 satellite): pure host-side
# PartitionSpec construction — no mesh objects needed
# ---------------------------------------------------------------------------

_RULE_PATHS = [
    ("mlp/wi/w", ("__none__", "model")),
    ("attn/wq/w", ("__none__", "model")),
    ("attn/wo/w", ("model", "__none__")),
    ("embed", ("model", "__none__")),
    ("lm_head/w", ("__none__", "model")),
    ("units/0/k", ("__none__", "__none__", "model", "__none__")),
]


class _Path:
    """Minimal key-path shim: rules.normalize_path(jax keystr) of
    ['a']['b'] is 'a/b'; build the same string through real jax paths."""
    def __new__(cls, s):
        import jax
        parts = s.split("/")
        tree = leaf = object()
        for p in reversed(parts):
            tree = {p: tree}
        flat = jax.tree_util.tree_flatten_with_path(
            tree, is_leaf=lambda x: x is leaf)[0]
        return flat[0][0]


@settings(**SET)
@given(extra_lead=st.integers(0, 3),
       path_i=st.integers(0, len(_RULE_PATHS) - 1),
       particle=st.booleans())
def test_param_spec_pad_and_particle_prepend(extra_lead, path_i, particle):
    """Tail length vs ndim: leading dims pad with None, the FIRST lead
    dim carries the particle axis (when one exists), the tail stays
    trailing-aligned; a tail longer than ndim degrades to lead-only."""
    from repro.sharding.rules import param_spec, spec_tail
    path_s, tail = _RULE_PATHS[path_i]
    tail = tuple(None if t == "__none__" else t for t in tail)
    assert spec_tail(path_s, "tp") == tail
    ndim = len(tail) + extra_lead
    axis = "data" if particle else None
    spec = tuple(param_spec(_Path(path_s), ndim, "tp", axis))
    assert len(spec) == ndim
    if extra_lead >= 1:
        assert spec[0] == axis
        assert all(s is None for s in spec[1:extra_lead])
        assert spec[extra_lead:] == tail
    else:  # no room for the particle axis: tail occupies every dim
        assert spec == tail
    # tail longer than the array rank: rule drops, lead-only spec
    short = tuple(param_spec(_Path(path_s), max(len(tail) - 1, 1), "tp",
                             axis))
    assert all(s in (axis, None) for s in short) and "model" not in short


@settings(**SET)
@given(vocab=st.sampled_from([51865, 51, 77, 128256]),
       model_size=st.sampled_from([16, 32, 7]),
       d=st.sampled_from([64, 96]))
def test_param_spec_divisibility_drop(vocab, model_size, d):
    """The whisper case: a vocab (or head count) that does not divide
    the model-axis size degrades that dim to None without error, while
    divisible dims keep their axis."""
    from repro.sharding.rules import param_spec
    mesh_shape = {"data": 2, "model": model_size}
    spec = tuple(param_spec(_Path("lm_head/w"), 3, "tp", "data",
                            shape=(2, d, vocab), mesh_shape=mesh_shape))
    want_v = "model" if vocab % model_size == 0 else None
    assert spec == ("data", None, want_v)
    # the particle axis obeys the same law on the leading dim
    spec2 = tuple(param_spec(_Path("lm_head/w"), 3, "tp", "data",
                             shape=(3, d, vocab), mesh_shape=mesh_shape))
    assert spec2[0] is None  # 3 % data=2 != 0


@settings(**SET)
@given(model_axis=st.sampled_from(["model", "tensor", None]))
def test_param_spec_model_axis_remap(model_axis):
    """Rule tails name the model axis literally; param_spec remaps it to
    the placement's axis name (or drops it when the plan has none)."""
    from repro.sharding.rules import param_spec
    spec = tuple(param_spec(_Path("mlp/wi/w"), 3, "tp", "data",
                            model_axis=model_axis))
    assert spec == ("data", None, model_axis)
