"""Speculative BMA decoding (DESIGN.md §14): the multi-query window
kernel vs its oracle, model-level window-vs-sequential parity, and the
SpeculativeDecodeScheduler end to end — token-exact (tokens AND
uncertainty heads) against the plain continuous-batching scheduler,
zero steady-state cold compiles, page-granular rollback accounting,
quantized-draft equivalence, and the speculative stats section."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import ParticleModule, PushDistribution
from repro.kernels import ops, ref
from repro.models import api
from repro.runtime import global_cache
from repro.serve import SpecConfig, serve_decode
from repro.serve.speculative import resolve_spec_config


def _cold():
    return global_cache().snapshot_stats()["cold_compiles"]


# ---------------------------------------------------------------------------
# multi-query (drafted-window) paged attention kernel vs oracle
# ---------------------------------------------------------------------------

def _window_case(seed, B, W, H, KVH, hd, ps, n_pmax, NP, lens):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, W, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((NP, ps, KVH, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((NP, ps, KVH, hd)), jnp.float32)
    bt = np.zeros((B, n_pmax), np.int32)
    free = list(rng.permutation(NP))
    for b, sl in enumerate(lens):
        if sl < 0:
            continue
        for i in range((sl + W - 1) // ps + 1):
            bt[b, i] = free.pop()
    return q, k, v, jnp.asarray(bt), jnp.asarray(lens, jnp.int32)


@pytest.mark.parametrize("B,W,H,KVH,hd,ps,n_pmax,lens", [
    (2, 3, 4, 2, 16, 8, 4, [13, 20]),       # GQA, mixed lengths
    (3, 5, 8, 1, 8, 4, 8, [0, 9, 17]),      # MQA, window > page
    (2, 2, 4, 4, 8, 8, 3, [-1, 11]),        # MHA + inactive row
])
def test_window_kernel_vs_oracle(B, W, H, KVH, hd, ps, n_pmax, lens):
    NP = B * n_pmax + 2
    q, k, v, bt, sl = _window_case(B * 3 + W, B, W, H, KVH, hd, ps,
                                   n_pmax, NP, lens)
    out = ops.paged_decode_window_attention(q, k, v, bt, sl)
    want = ref.paged_decode_window_attention(q, k, v, bt, sl)
    assert float(jnp.abs(out - want).max()) < 1e-4
    for b, L in enumerate(lens):
        if L < 0:
            assert float(jnp.abs(out[b]).max()) == 0.0


def test_window_kernel_w1_matches_single_token_kernel():
    """W=1 degenerates to the plain paged decode kernel exactly."""
    B, H, KVH, hd, ps, n_pmax = 2, 4, 2, 16, 8, 3
    NP = B * n_pmax + 1
    q, k, v, bt, sl = _window_case(7, B, 1, H, KVH, hd, ps, n_pmax, NP,
                                   [12, 19])
    single = ops.paged_decode_attention(q, k, v, bt, sl)
    window = ops.paged_decode_window_attention(q, k, v, bt, sl)
    assert float(jnp.abs(single - window).max()) < 1e-5


def test_window_kernel_causal_within_window():
    """Query w must NOT see columns past sl + w: truncating the window
    reproduces the shorter window's rows (a longer draft never changes
    the attention of an earlier drafted position)."""
    B, W, H, KVH, hd, ps, n_pmax = 2, 4, 4, 2, 8, 4, 4
    NP = B * n_pmax + 1
    q, k, v, bt, sl = _window_case(3, B, W, H, KVH, hd, ps, n_pmax, NP,
                                   [5, 9])
    full = ops.paged_decode_window_attention(q, k, v, bt, sl)
    short = ops.paged_decode_window_attention(q[:, :2], k, v, bt, sl)
    assert float(jnp.abs(full[:, :2] - short).max()) < 1e-5


def test_window_kernel_vmaps_over_particle_axis():
    P, B, W, H, KVH, hd, ps, n_pmax = 2, 2, 3, 4, 2, 8, 8, 3
    NP = B * n_pmax + 1
    cases = [_window_case(20 + p, B, W, H, KVH, hd, ps, n_pmax, NP,
                          [10, 15]) for p in range(P)]
    qs = jnp.stack([c[0] for c in cases])
    ks = jnp.stack([c[1] for c in cases])
    vs = jnp.stack([c[2] for c in cases])
    bt, sl = cases[0][3], cases[0][4]
    outs = jax.vmap(lambda q, k, v: ops.paged_decode_window_attention(
        q, k, v, bt, sl))(qs, ks, vs)
    for p in range(P):
        want = ref.paged_decode_window_attention(qs[p], ks[p], vs[p], bt, sl)
        assert float(jnp.abs(outs[p] - want).max()) < 1e-4


# ---------------------------------------------------------------------------
# model level: one window pass == k sequential single-token steps
# ---------------------------------------------------------------------------

def _tiny_cfg():
    return configs.get("qwen1.5-0.5b").replace(
        n_units=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=128, max_seq_len=128)


def test_model_window_matches_sequential_decode():
    cfg = _tiny_cfg()
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    L, W, ps, n_pmax = 13, 4, 8, 6
    rng = np.random.default_rng(5)
    prompt = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, L)), jnp.int32)
    pages = api.paged_cache_init(cfg, num_pages=16, page_size=ps)
    bt_row = jnp.asarray(list(range(2, 2 + n_pmax)), jnp.int32)
    padded = jnp.zeros((1, 16), jnp.int32).at[:, :L].set(prompt)
    first, pages = api.prefill_paged(params, padded, pages, bt_row,
                                     jnp.int32(L), cfg)
    bt = bt_row[None, :]
    toks = [int(jnp.argmax(first, -1)[0])]
    pages_seq = jax.tree.map(lambda a: a, pages)
    seq_logits = []
    for step in range(W):
        tok = jnp.asarray([toks[-1]], jnp.int32)
        sl = jnp.asarray([L + step], jnp.int32)
        lg, pages_seq = api.decode_step_paged(params, tok, pages_seq, bt,
                                              sl, cfg)
        seq_logits.append(lg)
        toks.append(int(jnp.argmax(lg, -1)[0]))
    win = jnp.asarray([toks[:W]], jnp.int32)
    wlog, pages_win = api.decode_window_paged(
        params, win, pages, bt, jnp.asarray([L], jnp.int32),
        jnp.asarray([W], jnp.int32), cfg)
    for w in range(W):
        assert float(jnp.abs(wlog[:, w] - seq_logits[w]).max()) < 1e-4, w
    # the pool the window leaves == the pool k sequential steps leave
    diffs = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), pages_win, pages_seq))
    assert max(diffs) < 1e-4
    # win_len masks the tail: positions past it are neither scored nor
    # written (logits there are unspecified and ignored by callers)
    pages2 = jax.tree.map(lambda a: a, pages)
    wlog2, _ = api.decode_window_paged(
        params, win, pages2, bt, jnp.asarray([L], jnp.int32),
        jnp.asarray([2], jnp.int32), cfg)
    for w in range(2):
        assert float(jnp.abs(wlog2[:, w] - seq_logits[w]).max()) < 1e-4


# ---------------------------------------------------------------------------
# SpeculativeDecodeScheduler end to end
# ---------------------------------------------------------------------------

def _lm_pd(cfg, n=2, capacity=None):
    module = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)
    pd = PushDistribution(module, num_devices=1, seed=0,
                          **({} if capacity is None
                             else {"capacity": capacity}))
    for _ in range(n):
        pd.p_create()
    return pd


def test_resolve_spec_config():
    assert resolve_spec_config(None) is None
    assert resolve_spec_config(False) is None
    assert resolve_spec_config(True).k_max == 4
    assert resolve_spec_config(7).k_max == 7
    cfg = SpecConfig(k_max=2, adaptive=False)
    assert resolve_spec_config(cfg) is cfg
    with pytest.raises(TypeError):
        resolve_spec_config("yes")
    with pytest.raises(ValueError):
        SpecConfig(k_max=0)


def test_speculative_matches_plain_scheduler_token_exact():
    """Same prompts, same store seed: the speculative scheduler must
    reproduce the plain scheduler's tokens AND uncertainty heads exactly
    (greedy BMA is token-exact by construction), across mixed prompt
    lengths, admission churn (more prompts than rows), and an eos stop
    landing mid-window. The speculative stats section must account for
    every drafted/accepted token."""
    cfg = _tiny_cfg()
    rng = np.random.default_rng(0)
    prompts = [list(map(int, rng.integers(1, cfg.vocab_size,
                                          int(rng.integers(3, 15)))))
               for _ in range(5)]
    with _lm_pd(cfg) as pd:
        svc = serve_decode(pd, cfg, num_pages=32, page_size=8,
                           max_active=3, warmup=False)
        try:
            plain = [svc.generate(p, max_new=6) for p in prompts]
        finally:
            svc.close()
    with _lm_pd(cfg) as pd:
        svc = serve_decode(pd, cfg, num_pages=32, page_size=8,
                           max_active=3, warmup=False, speculative=True)
        try:
            handles = [svc.generate_async(p, max_new=6) for p in prompts]
            spec = [h.result(300) for h in handles]
            # eos equal to the first generated token: stops inside the
            # first accepted window, emitted tokens truncated at eos
            g = svc.generate(prompts[0], max_new=6,
                             eos_id=plain[0].tokens[0])
            assert g.tokens == plain[0].tokens[:1]
            assert g.finish_reason == "eos"
            st = svc.stats()
        finally:
            svc.close()
    for a, b in zip(plain, spec):
        assert a.tokens == b.tokens
        np.testing.assert_allclose(a.logprobs, b.logprobs, atol=1e-4)
        np.testing.assert_allclose(a.entropy, b.entropy, atol=1e-4)
        np.testing.assert_allclose(a.mutual_info, b.mutual_info, atol=1e-4)
    ss = st["speculative"]
    assert set(ss) == {"spec_steps", "draft_calls", "verify_calls",
                       "drafted_tokens", "accepted_tokens",
                       "rollback_pages", "acceptance_rate",
                       "tokens_per_step", "k_max", "adaptive",
                       "quantized", "mean_k"}
    assert ss["verify_calls"] == ss["spec_steps"] == st["steps"]
    assert ss["draft_calls"] <= ss["spec_steps"]
    assert 0.0 <= ss["acceptance_rate"] <= 1.0
    assert ss["accepted_tokens"] <= ss["drafted_tokens"]
    # variable tokens per step: the whole point
    assert st["generated_tokens"] >= st["steps"]
    assert st["pool"]["used_pages"] == 0


def test_speculative_quantized_draft_token_exact_and_rollback():
    """An int8-quantized draft changes the proposal quality, never the
    output (verify decides every emitted token); rejected windows roll
    their tail pages back so the pool drains to zero."""
    cfg = _tiny_cfg()
    rng = np.random.default_rng(1)
    prompts = [list(map(int, rng.integers(1, cfg.vocab_size, 9)))
               for _ in range(3)]
    with _lm_pd(cfg) as pd:
        svc = serve_decode(pd, cfg, num_pages=32, page_size=4,
                           max_active=3, warmup=False)
        try:
            plain = [svc.generate(p, max_new=6) for p in prompts]
        finally:
            svc.close()
    with _lm_pd(cfg) as pd:
        svc = serve_decode(pd, cfg, num_pages=32, page_size=4,
                           max_active=3, warmup=False,
                           speculative=SpecConfig(k_max=3, quantized=True))
        try:
            handles = [svc.generate_async(p, max_new=6) for p in prompts]
            spec = [h.result(300) for h in handles]
            st = svc.stats()
        finally:
            svc.close()
    for a, b in zip(plain, spec):
        assert a.tokens == b.tokens
    assert st["engine"]["draft_packs"] >= 1
    assert st["speculative"]["quantized"] is True
    assert st["pool"]["used_pages"] == 0


def test_speculative_warmup_zero_steady_state_cold_compiles():
    """After warmup (draft + verify + prefill buckets), steady-state
    speculative serving — admission, retirement, rollback — compiles
    NOTHING and never bumps the store generation."""
    cfg = _tiny_cfg()
    rng = np.random.default_rng(2)
    prompts = [list(map(int, rng.integers(1, cfg.vocab_size,
                                          int(rng.integers(3, 15)))))
               for _ in range(4)]
    with _lm_pd(cfg) as pd:
        svc = serve_decode(pd, cfg, num_pages=32, page_size=8,
                           max_active=3, warmup_buckets=(4, 8, 16),
                           speculative=True)
        try:
            cold = _cold()
            gen0 = pd.store.generation()
            handles = [svc.generate_async(p, max_new=6) for p in prompts]
            [h.result(300) for h in handles]
            assert _cold() == cold, "steady-state spec decode cold-compiled"
            assert pd.store.generation() == gen0
            st = svc.stats()
            # dispatch accounting: one H2D per draft call, one per verify
            # call, one per prefill — nothing else moves host->device in
            # the steady loop (the draft-slot scalar uploads ride churn)
            assert st["h2d_transfers"] == (
                st["speculative"]["draft_calls"]
                + st["speculative"]["verify_calls"] + st["prefills"])
        finally:
            svc.close()


def test_speculative_property_token_exact_under_churn():
    """Hypothesis sweep: for random prompts, lengths, generation budgets,
    and clone/kill churn between requests, the speculative scheduler is
    token-exact against the plain scheduler and never cold-compiles past
    warmup. (The deterministic tests above always run; this widens the
    input space when hypothesis is available.)"""
    pytest.importorskip("hypothesis", reason="hypothesis not installed "
                        "(pip install -e .[dev])")
    from hypothesis import given, settings, strategies as st

    cfg = _tiny_cfg()
    with _lm_pd(cfg, capacity=4) as pd_plain, \
            _lm_pd(cfg, capacity=4) as pd_spec:
        twin_pid = [pd_spec.particle_ids()[0]]
        svc_p = serve_decode(pd_plain, cfg, num_pages=32, page_size=8,
                             max_active=2, warmup_buckets=(4, 8, 16))
        svc_s = serve_decode(pd_spec, cfg, num_pages=32, page_size=8,
                             max_active=2, warmup_buckets=(4, 8, 16),
                             speculative=True)
        cold = _cold()

        @settings(deadline=None, max_examples=10)
        @given(seed=st.integers(0, 2**16), plen=st.integers(1, 15),
               max_new=st.integers(1, 8), churn=st.booleans())
        def run(seed, plen, max_new, churn):
            rng = np.random.default_rng(seed)
            prompt = list(map(int, rng.integers(1, cfg.vocab_size, plen)))
            if churn:
                # clone/kill round-trip on the spec side only: the live
                # set is restored, so outputs must still match exactly
                with svc_s.scheduler.step_lock:
                    twin = pd_spec.p_clone(twin_pid[0], jitter=0.01)
                with svc_s.scheduler.step_lock:
                    pd_spec.p_kill(twin)
            a = svc_p.generate(prompt, max_new=max_new)
            b = svc_s.generate(prompt, max_new=max_new)
            assert a.tokens == b.tokens
            np.testing.assert_allclose(a.logprobs, b.logprobs, atol=1e-4)

        try:
            run()
            assert _cold() == cold, "property sweep cold-compiled"
        finally:
            svc_p.close()
            svc_s.close()


def test_adaptive_k_tracks_acceptance():
    """Adaptive K sits at k_max while the draft matches the BMA (a
    1-particle 'ensemble' always accepts everything) and the scheduler
    emits full windows."""
    cfg = _tiny_cfg()
    with _lm_pd(cfg, n=1) as pd:
        svc = serve_decode(pd, cfg, num_pages=32, page_size=8,
                           max_active=2, warmup=False,
                           speculative=SpecConfig(k_max=3))
        try:
            g = svc.generate([5, 9, 23, 41], max_new=7)
            assert len(g.tokens) == 7
            ss = svc.stats()["speculative"]
            assert ss["acceptance_rate"] == 1.0
            assert ss["rollback_pages"] == 0
            # 7 tokens at full acceptance: 1+3+3 -> 3 steps, not 7
            assert ss["spec_steps"] <= 3
        finally:
            svc.close()
