"""repro.serve: micro-batcher semantics (flush triggers, padding,
backpressure), fused-BMA numerical parity, calibration metrics vs their
NumPy references, store-aware checkpoint round trips, the SWAG serving
handoff, and the sharded subprocess check (serving must read the store
without unsharding it)."""
import os
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParticleModule, ParticleStore, PushDistribution
from repro.optim import sgd
from repro.serve import (MicroBatcher, PredictiveEngine, bucket_size,
                         metrics, pad_rows, serve, uncertainty)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _linear_module(out_dim: int = 4):
    def init(rng):
        return {"w": jax.random.normal(rng, (3, out_dim)),
                "b": jnp.zeros((out_dim,))}

    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2), {}

    def fwd(p, b):
        return b["x"] @ p["w"] + p["b"]

    return ParticleModule(init, loss, fwd)


def _pd(n=4, seed=0):
    pd = PushDistribution(_linear_module(), num_devices=1, seed=seed)
    for _ in range(n):
        pd.p_create(sgd(0.1))
    return pd


# ---------------------------------------------------------------------------
# micro-batcher
# ---------------------------------------------------------------------------

class _Recorder:
    """predict_fn double: records every padded batch it was handed."""

    def __init__(self, gate=None, fail=False):
        self.batches = []
        self.gate = gate
        self.fail = fail

    def __call__(self, batch):
        if self.gate is not None:
            assert self.gate.wait(10.0)
        if self.fail:
            raise RuntimeError("model exploded")
        self.batches.append(batch)
        return {"y": batch["x"] * 2.0}


def test_batcher_size_trigger_flushes_full_batch():
    rec = _Recorder()
    with MicroBatcher(rec, max_batch=4, max_wait_ms=60_000) as mb:
        futs = [mb.submit({"x": jnp.full((2,), float(i))}) for i in range(4)]
        outs = [f.wait(10.0) for f in futs]
    for i, o in enumerate(outs):
        assert float(o["y"][0]) == 2.0 * i
    st = mb.snapshot_stats()
    assert st["size_flushes"] == 1 and st["deadline_flushes"] == 0
    assert st["batches"] == 1 and st["requests"] == 4


def test_batcher_deadline_trigger():
    rec = _Recorder()
    with MicroBatcher(rec, max_batch=64, max_wait_ms=50) as mb:
        t0 = time.monotonic()
        f = mb.submit({"x": jnp.ones((2,))})
        out = f.wait(10.0)
        waited = time.monotonic() - t0
    assert float(out["y"][0]) == 2.0
    assert waited >= 0.04, "flushed before the deadline"
    st = mb.snapshot_stats()
    assert st["deadline_flushes"] == 1 and st["size_flushes"] == 0


def test_batcher_pads_to_bucket_and_slices_back():
    rec = _Recorder()
    with MicroBatcher(rec, max_batch=8, max_wait_ms=20) as mb:
        futs = [mb.submit({"x": jnp.full((2,), float(i))}) for i in range(3)]
        outs = [f.wait(10.0) for f in futs]
    # three requests ride one power-of-two padded batch ...
    (batch,) = rec.batches
    assert batch["x"].shape == (4, 2)
    assert float(batch["x"][3, 0]) == 2.0      # pad = repeat of last row
    # ... and each caller gets exactly its own row back
    for i, o in enumerate(outs):
        assert o["y"].shape == (2,) and float(o["y"][0]) == 2.0 * i
    assert mb.snapshot_stats()["padded_rows"] == 1


def test_batcher_backpressure_blocks_submitters():
    gate = threading.Event()
    rec = _Recorder(gate=gate)
    mb = MicroBatcher(rec, max_batch=1, max_wait_ms=0, max_queue=2)
    try:
        futs = [mb.submit({"x": jnp.zeros((1,))}) for _ in range(3)]
        # pump holds one request inside predict_fn; the queue is full
        done = threading.Event()

        def blocked_submit():
            futs.append(mb.submit({"x": jnp.zeros((1,))}))
            done.set()

        t = threading.Thread(target=blocked_submit, daemon=True)
        t.start()
        assert not done.wait(0.3), "submit did not block on a full queue"
        gate.set()                      # unblock the model; queue drains
        assert done.wait(10.0), "backpressured submit never admitted"
        for f in futs:
            f.wait(10.0)
    finally:
        gate.set()
        mb.close()
    assert mb.snapshot_stats()["max_queue_depth"] <= 2


def test_batcher_propagates_model_errors():
    rec = _Recorder(fail=True)
    with MicroBatcher(rec, max_batch=2, max_wait_ms=10) as mb:
        f = mb.submit({"x": jnp.zeros((1,))})
        with pytest.raises(RuntimeError, match="model exploded"):
            f.wait(10.0)
    assert mb.snapshot_stats()["errors"] == 1


def test_batcher_rejects_after_close():
    rec = _Recorder()
    mb = MicroBatcher(rec, max_batch=2, max_wait_ms=10)
    mb.close()
    with pytest.raises(RuntimeError):
        mb.submit({"x": jnp.zeros((1,))})


def test_batcher_staging_buffers_one_h2d_per_flush():
    """Flushes fill preallocated per-bucket staging buffers in place:
    exactly one H2D per flush (h2d_transfers == batches), and a repeated
    bucket reuses its buffer instead of allocating (np.stack) again."""
    rec = _Recorder()
    with MicroBatcher(rec, max_batch=4, max_wait_ms=5) as mb:
        for round_ in range(3):
            futs = [mb.submit({"x": jnp.full((2,), float(i))})
                    for i in range(4)]
            for f in futs:
                f.wait(10.0)
    st = mb.snapshot_stats()
    assert st["h2d_transfers"] == st["batches"] == 3
    assert st["staging_builds"] == 1          # one buffer set per bucket
    assert st["staging_reuses"] == 2
    # buffer reuse across flushes never leaked rows between batches
    for round_, batch in enumerate(rec.batches):
        assert np.allclose(np.asarray(batch["x"])[:, 0], [0, 1, 2, 3])


def test_decode_scheduler_stats_surface():
    """pd.stats() grows a 'decode' section while a DecodeScheduler serves
    the store: active/queue/page-pool occupancy and the admission/retire/
    preempt counters (asserted end-to-end in test_paged.py)."""
    from repro import configs
    from repro.models import api as models_api
    from repro.serve import serve_decode

    cfg = configs.get("qwen1.5-0.5b").replace(
        n_units=1, d_model=16, n_heads=2, n_kv_heads=1, head_dim=8,
        d_ff=32, vocab_size=64, max_seq_len=64)
    lm = ParticleModule(
        init=lambda rng: models_api.init_params(rng, cfg),
        loss=lambda p, b: models_api.loss_fn(p, b, cfg),
        forward=lambda p, b: models_api.forward(p, b, cfg)[0], cfg=cfg)
    with PushDistribution(lm, num_devices=1, seed=0) as pd:
        pd.p_create()
        assert "decode" not in pd.stats()      # no scheduler yet
        svc = serve_decode(pd, cfg, num_pages=8, page_size=8,
                           max_active=2, warmup=False)
        try:
            g = svc.generate([3, 5, 7], max_new=3)
            assert len(g.tokens) == 3
            dec = pd.stats()["decode"]
            for k in ("active_seqs", "queue_depth", "admitted", "retired",
                      "preempted", "steps", "prefills", "row_occupancy",
                      "h2d_transfers"):
                assert k in dec, k
            assert dec["admitted"] == dec["retired"] == 1
            assert dec["h2d_transfers"] == dec["steps"] + dec["prefills"]
            pool = dec["pool"]
            assert pool["free_pages"] == pool["num_pages"] == 8
            assert pool["peak_used"] >= 1
        finally:
            svc.close()
        # scheduler gone -> the section unregisters with it
        import gc
        del svc
        gc.collect()
        assert "decode" not in pd.stats()


def test_bucket_and_pad_helpers():
    assert [bucket_size(m) for m in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
    t = {"a": jnp.arange(6.0).reshape(3, 2)}
    p = pad_rows(t, 8)
    assert p["a"].shape == (8, 2)
    assert jnp.array_equal(p["a"][3:], jnp.broadcast_to(t["a"][-1:], (5, 2)))
    assert pad_rows(t, 3) is t


# ---------------------------------------------------------------------------
# engine: fused BMA parity + bucketed compile cache + store versioning
# ---------------------------------------------------------------------------

def test_fused_bma_matches_per_particle_loop():
    """Acceptance bar: engine BMA == sequential per-particle forward +
    host-side average, < 1e-5, for both head kinds."""
    pd = _pd(4)
    try:
        x = jax.random.normal(jax.random.PRNGKey(1), (7, 3))
        member = [np.asarray(x @ pd.p_params(p)["w"] + pd.p_params(p)["b"])
                  for p in pd.particle_ids()]
        stacked = np.stack(member)

        reg = PredictiveEngine(pd.module.forward, store=pd.store,
                               kind="regress")
        heads = reg.predict({"x": x})
        assert np.abs(np.asarray(heads["mean"]) - stacked.mean(0)).max() < 1e-5
        assert np.abs(np.asarray(heads["variance"])
                      - stacked.var(0)).max() < 1e-5

        cls = PredictiveEngine(pd.module.forward, store=pd.store,
                               kind="classify")
        heads = cls.predict({"x": x})
        def softmax(z):
            e = np.exp(z - z.max(-1, keepdims=True))
            return e / e.sum(-1, keepdims=True)
        probs = np.mean([softmax(m) for m in member], 0)
        assert np.abs(np.asarray(heads["mean"]) - probs).max() < 1e-5
        # uncertainty identities against a literal NumPy transcription
        mem_probs = np.stack([softmax(m) for m in member])
        ent = -(probs * np.log(probs + 1e-12)).sum(-1)
        exp_ent = np.mean(-(mem_probs * np.log(mem_probs + 1e-12)).sum(-1), 0)
        assert np.abs(np.asarray(heads["entropy"]) - ent).max() < 1e-5
        assert np.abs(np.asarray(heads["mutual_info"])
                      - np.maximum(ent - exp_ent, 0)).max() < 1e-5
    finally:
        pd.cleanup()


def test_engine_bucketed_compile_cache():
    pd = _pd(2)
    try:
        eng = PredictiveEngine(pd.module.forward, store=pd.store,
                               kind="regress")
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 3))
        eng.predict({"x": x[:3]})          # bucket 4: compile
        eng.predict({"x": x[:4]})          # bucket 4: hit
        eng.predict({"x": x[:5]})          # bucket 8: compile
        eng.predict({"x": x[:8]})          # bucket 8: hit
        st = eng.snapshot_stats()
        assert st["compiles"] == 2 and st["bucket_hits"] == 2
        assert st["programs"] == 2
    finally:
        pd.cleanup()


def test_engine_sees_store_commits_via_version():
    pd = _pd(2)
    try:
        eng = PredictiveEngine(pd.module.forward, store=pd.store,
                               kind="regress")
        x = jnp.ones((2, 3))
        before = np.asarray(eng.predict({"x": x})["mean"])
        new = jax.tree.map(jnp.zeros_like, pd.store.stacked("params"))
        pd.store.commit("params", new)
        after = np.asarray(eng.predict({"x": x})["mean"])
        assert np.abs(after).max() == 0.0 and np.abs(before).max() > 0.0
        assert eng.snapshot_stats()["param_refreshes"] == 2
    finally:
        pd.cleanup()


def test_engine_stateful_step_matches_per_particle_loop():
    """The LM-decode shape without the LM: per-particle serving state
    rides the stacked axis across steps; heads are BMA over member
    outputs; one compiled program reused across steps."""
    pd = _pd(3)
    try:
        def fwd(p, state, batch):
            out = batch["x"] @ p["w"] + p["b"] + state["acc"]
            return out, {"acc": state["acc"] + 1.0}

        eng = PredictiveEngine(fwd, store=pd.store, kind="regress",
                               stateful=True)
        with pytest.raises(RuntimeError):
            eng.predict({"x": jnp.ones((1, 3))})     # wrong entry point
        state = eng.init_state(lambda p: {"acc": jnp.zeros(())})
        # serving state is born capacity-padded (3 live -> capacity 4);
        # dead rows ride along masked out
        assert jax.tree.leaves(state)[0].shape[0] == pd.store.capacity == 4
        x = jax.random.normal(jax.random.PRNGKey(6), (4, 3))
        member = np.stack(
            [np.asarray(x @ pd.p_params(p)["w"] + pd.p_params(p)["b"])
             for p in pd.particle_ids()])
        for step in range(3):
            heads, state = eng.step(state, {"x": x})
            want = (member + step).mean(0)
            assert np.abs(np.asarray(heads["mean"]) - want).max() < 1e-5
        assert float(state["acc"][0]) == 3.0
        st = eng.snapshot_stats()
        assert st["compiles"] == 1 and st["bucket_hits"] == 2
    finally:
        pd.cleanup()


def test_engine_rejects_bad_construction():
    pd = _pd(1)
    try:
        with pytest.raises(ValueError):
            PredictiveEngine(pd.module.forward)          # no source
        with pytest.raises(ValueError):
            PredictiveEngine(pd.module.forward, store=pd.store,
                             params=pd.store.stacked("params"))
        with pytest.raises(ValueError):
            PredictiveEngine(pd.module.forward, store=pd.store, kind="nope")
    finally:
        pd.cleanup()


# ---------------------------------------------------------------------------
# service front-end
# ---------------------------------------------------------------------------

def test_service_concurrent_requests_end_to_end():
    pd = _pd(3)
    try:
        x = jax.random.normal(jax.random.PRNGKey(2), (16, 3))
        member = [np.asarray(x @ pd.p_params(p)["w"] + pd.p_params(p)["b"])
                  for p in pd.particle_ids()]
        want = np.mean(member, 0)
        with serve(pd, kind="regress", max_batch=8, max_wait_ms=5.0) as svc:
            svc.predict_batch({"x": x})     # warm the bucket-8 program
            results = {}

            def client(i):
                results[i] = svc.predict({"x": x[i]}, timeout=30.0)

            threads = [threading.Thread(target=client, args=(i,))
                       for i in range(16)]
            for t in threads:
                t.start()
            for t in threads:
                t.join(30.0)
            assert len(results) == 16
            for i, pred in results.items():
                assert np.abs(np.asarray(pred.mean) - want[i]).max() < 1e-5
            st = svc.stats()
            assert st["requests"] == 16
            assert st["batches"] < 16, "no coalescing happened"
            assert st["latency_p99_ms"] >= st["latency_p50_ms"] >= 0.0
    finally:
        pd.cleanup()


def test_infer_posterior_predictive_handoff():
    from repro.bdl import DeepEnsemble
    mod = _linear_module()
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 3))
    data = [{"x": x, "y": x @ jnp.ones((3, 4))}]
    with DeepEnsemble(mod, num_devices=1, seed=0, backend="compiled") as de:
        de.bayes_infer(data, 2, optimizer=sgd(0.05), num_particles=2)
        with de.posterior_predictive(kind="regress",
                                     max_wait_ms=1.0) as svc:
            pred = svc.predict({"x": x[0]})
            want = np.mean([np.asarray(x[:1] @ de.push_dist.p_params(p)["w"]
                                       + de.push_dist.p_params(p)["b"])
                            for p in de.push_dist.particle_ids()], 0)[0]
            assert np.abs(np.asarray(pred.mean) - want).max() < 1e-5


# ---------------------------------------------------------------------------
# calibration metrics vs NumPy references
# ---------------------------------------------------------------------------

def test_metrics_match_numpy_references():
    rng = np.random.default_rng(0)
    logits = rng.standard_normal((64, 10)) * 2.0
    probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
    labels = rng.integers(0, 10, 64)
    assert abs(float(metrics.nll(probs, labels))
               - metrics.nll_ref(probs, labels)) < 1e-5
    assert abs(float(metrics.brier(probs, labels))
               - metrics.brier_ref(probs, labels)) < 1e-5
    assert abs(float(metrics.accuracy(probs, labels))
               - metrics.accuracy_ref(probs, labels)) < 1e-6
    for n_bins in (5, 15):
        assert abs(float(metrics.ece(probs, labels, n_bins))
                   - metrics.ece_ref(probs, labels, n_bins)) < 1e-5


def test_metrics_calibrated_model_has_low_ece():
    """A perfectly calibrated synthetic predictor scores ~0 ECE; a
    systematically overconfident one scores high."""
    rng = np.random.default_rng(1)
    n, conf = 4096, 0.7
    probs = np.full((n, 2), 0.0)
    probs[:, 0], probs[:, 1] = conf, 1 - conf
    labels = (rng.random(n) > conf).astype(np.int64)   # P(correct)=conf
    assert float(metrics.ece(probs, labels)) < 0.05
    labels_wrong = (rng.random(n) > 0.2).astype(np.int64)
    assert float(metrics.ece(probs, labels_wrong)) > 0.3


def test_uncertainty_heads_degenerate_cases():
    # identical particles -> zero epistemic uncertainty
    logits = jnp.broadcast_to(jnp.array([2.0, 0.0, -1.0]), (4, 5, 3))
    h = uncertainty.predictive_heads(logits, "classify")
    assert float(jnp.max(h["mutual_info"])) < 1e-6
    assert float(jnp.max(h["variance"])) < 1e-12
    # regress: mean/variance are the particle moments
    outs = jnp.stack([jnp.zeros((5, 2)), jnp.ones((5, 2))])
    h = uncertainty.predictive_heads(outs, "regress")
    assert float(jnp.max(jnp.abs(h["mean"] - 0.5))) == 0.0
    assert float(jnp.max(jnp.abs(h["variance"] - 0.25))) == 0.0


# ---------------------------------------------------------------------------
# store-aware checkpointing
# ---------------------------------------------------------------------------

def test_store_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import restore_store, save_store
    pd = _pd(3, seed=1)
    try:
        x = jax.random.normal(jax.random.PRNGKey(4), (4, 3))
        batch = {"x": x, "y": x @ jnp.ones((3, 4))}
        for p in pd.particles.values():
            p.step(batch).wait()           # materialize opt_state + grads
        pd.drain()
        path = save_store(str(tmp_path), 7, pd.store)
        assert os.path.basename(path) == "store_00000007.npz"
        step, store2 = restore_store(str(tmp_path))
        assert step == 7 and store2.pids == pd.store.pids
        for key in ("params", "opt_state"):
            a, b = pd.store.stacked(key), store2.stacked(key)
            assert jax.tree.structure(a) == jax.tree.structure(b)
            for u, v in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
                assert np.array_equal(np.asarray(u), np.asarray(v))
        # a served model loads without replaying inference
        eng = PredictiveEngine(pd.module.forward, store=store2,
                               kind="regress")
        want = PredictiveEngine(pd.module.forward, store=pd.store,
                                kind="regress").predict(batch)
        got = eng.predict(batch)
        assert np.array_equal(np.asarray(got["mean"]),
                              np.asarray(want["mean"]))
    finally:
        pd.cleanup()


def test_store_checkpoint_explicit_missing_key_raises(tmp_path):
    from repro.checkpoint import save_store
    store = ParticleStore()
    store.register(0)
    store.write("params", 0, {"w": jnp.ones((2,))})
    with pytest.raises(KeyError):
        save_store(str(tmp_path), 0, store, keys=["params", "nope"])


# ---------------------------------------------------------------------------
# SWAG serve-time sampling (platform-gated kernel path)
# ---------------------------------------------------------------------------

def test_swag_sample_kernel_matches_reference():
    from repro.bdl.swag import swag_sample, swag_state_init
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (5, 3)),
              "b": jnp.ones((3,))}
    st = swag_state_init(params, max_rank=4)
    # fake some trajectory moments
    st["mean"] = params
    st["sq_mean"] = jax.tree.map(lambda p: p * p + 0.1, params)
    st["n"] = jnp.asarray(3.0)
    st["rank"] = jnp.asarray(3, jnp.int32)
    k = jax.random.PRNGKey(9)
    ref = swag_sample(st, k, use_kernel=False)
    ker = swag_sample(st, k, use_kernel=True)
    for u, v in zip(jax.tree.leaves(ref), jax.tree.leaves(ker)):
        assert float(jnp.abs(u - v).max()) < 1e-5


def test_multiswag_posterior_predictive_serves_samples():
    from repro.bdl import MultiSWAG
    from repro.bdl.swag import swag_sample_stacked
    mod = _linear_module()
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 3))
    data = [{"x": x, "y": x @ jnp.ones((3, 4))}]
    with MultiSWAG(mod, num_devices=1, seed=0, backend="compiled") as ms:
        ms.bayes_infer(data, 3, optimizer=sgd(0.05), num_particles=2,
                       max_rank=3)
        rng = jax.random.PRNGKey(0)
        with ms.posterior_predictive(samples_per_particle=3, rng=rng,
                                     kind="regress",
                                     max_wait_ms=1.0) as svc:
            heads = svc.predict_batch({"x": x})
            sampled = swag_sample_stacked(ms.store.stacked("swag"), rng, 3,
                                          use_kernel=True)
            outs = np.stack([np.asarray(
                x @ sampled["w"][i] + sampled["b"][i]) for i in range(6)])
            assert np.abs(outs.mean(0)
                          - np.asarray(heads["mean"])).max() < 1e-5
        # S=0 falls back to serving the live particle params
        with ms.posterior_predictive(kind="regress",
                                     max_wait_ms=1.0) as svc:
            assert svc.engine.num_particles == 2


# ---------------------------------------------------------------------------
# the sharded serving path (acceptance criterion): subprocess, 4 devices
# ---------------------------------------------------------------------------

def test_sharded_serving_reads_store_without_unsharding():
    """PredictiveService over a 4-device mesh: fused BMA parity < 1e-5 and
    zero per-request host transfers of stacked state (store stats flat,
    params still sharded over all 4 devices after serving)."""
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "_sharded_serve_check.py")],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
             "XLA_FLAGS": "--xla_force_host_platform_device_count=4"},
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    assert "OK" in out.stdout
