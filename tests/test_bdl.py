"""BDL algorithm correctness: SVGD equivalences, SWAG moments, ensembles."""
import jax
import jax.numpy as jnp
import pytest

from repro.bdl import (MultiSWAG, SteinVGD, baselines, fused_svgd_step,
                       pairwise_sqdist, svgd_force, swag_collect, swag_sample,
                       swag_state_init)
from repro.core import ParticleModule, functional
from repro.optim import sgd


def _module():
    def init(rng):
        return {"w": jax.random.normal(rng, (3, 2)) * 0.5}

    def loss(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] - y) ** 2), {}

    def fwd(p, batch):
        return batch[0] @ p["w"]

    return ParticleModule(init, loss, fwd)


def _data():
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 3))
    return [(x, x @ jnp.ones((3, 2)))]


def test_svgd_message_passing_equals_fused():
    """Paper-faithful NEL SVGD == compiled stacked-axis SVGD, bitwise-ish."""
    mod = _module()
    data = _data()
    N, LR, ELL, EPOCHS = 4, 0.05, 1.0, 3
    sv = SteinVGD(mod, num_devices=1, seed=0)
    pids, _ = sv.bayes_infer(data, EPOCHS, num_particles=N, lengthscale=ELL, lr=LR)
    mp = jnp.stack([jax.flatten_util.ravel_pytree(sv.push_dist.p_params(p))[0]
                    for p in pids])
    sv.cleanup()

    rng = jax.random.PRNGKey(0)
    inits = []
    for _ in range(N):
        rng, sub = jax.random.split(rng)
        inits.append(mod.init(sub))
    stacked = functional.stack_pytrees(inits)
    step = jax.jit(fused_svgd_step(mod.loss, lr=LR, lengthscale=ELL))
    for _ in range(EPOCHS):
        for b in data:
            stacked, _ = step(stacked, b)
    fused, _ = functional.flatten_stacked(stacked)
    assert jnp.abs(mp - fused).max() < 1e-4


def test_svgd_single_particle_is_plain_gd():
    """n=1: kernel k_11=1, no repulsion -> SVGD == gradient descent."""
    mod = _module()
    p0 = mod.init(jax.random.PRNGKey(0))
    batch = _data()[0]
    stacked = functional.stack_pytrees([p0])
    step = jax.jit(fused_svgd_step(mod.loss, lr=0.1, lengthscale=1.0))
    s1, _ = step(stacked, batch)
    g = jax.grad(lambda p: mod.loss(p, batch)[0])(p0)
    manual = jax.tree.map(lambda p, gg: p - 0.1 * gg, p0, g)
    assert jnp.abs(s1["w"][0] - manual["w"]).max() < 1e-5


def test_svgd_repulsion_separates_identical_particles():
    """Two identical particles with zero grads must not collapse further —
    repulsion pushes them apart once perturbed."""
    theta = jnp.array([[0.0, 0.0], [0.1, 0.1]], jnp.float32)
    grads = jnp.zeros_like(theta)
    phi = svgd_force(theta, grads, 1.0)
    # descent direction -phi must push particle 0 away from particle 1
    move0 = -phi[0]
    assert move0 @ jnp.array([1.0, 1.0]) < 0  # moves away from (0.1, 0.1)


def test_pairwise_sqdist_basic():
    t = jnp.array([[0.0, 0.0], [3.0, 4.0]])
    d2 = pairwise_sqdist(t)
    assert abs(float(d2[0, 1]) - 25.0) < 1e-5


def test_swag_moments_match_trajectory_stats():
    thetas = [{"w": jnp.full((4,), float(i))} for i in range(1, 6)]
    st = swag_state_init(thetas[0], max_rank=3)
    for th in thetas:
        st = swag_collect(st, th, use_kernel=False)
    assert jnp.abs(st["mean"]["w"] - 3.0).max() < 1e-5          # mean(1..5)
    assert jnp.abs(st["sq_mean"]["w"] - 11.0).max() < 1e-5      # mean(i^2)
    assert int(st["rank"]) == 5


def test_swag_sample_spread():
    """SWAG samples are centred on the mean with nonzero spread."""
    thetas = [{"w": jax.random.normal(jax.random.PRNGKey(i), (32,))}
              for i in range(10)]
    st = swag_state_init(thetas[0], max_rank=5)
    for th in thetas:
        st = swag_collect(st, th, use_kernel=False)
    samples = jnp.stack([swag_sample(st, jax.random.PRNGKey(100 + i))["w"]
                         for i in range(64)])
    emp_mean = samples.mean(0)
    assert jnp.abs(emp_mean - st["mean"]["w"]).max() < 1.0
    assert float(samples.std(0).mean()) > 0.05


def test_multiswag_particles_collect_independently():
    mod = _module()
    with MultiSWAG(mod, num_devices=1, seed=0) as ms:
        pids, losses = ms.bayes_infer(_data(), epochs=3, optimizer=sgd(0.05),
                                      num_particles=3, max_rank=4)
        for pid in pids:
            st = ms.push_dist.particles[pid].state["swag"]
            assert int(st["rank"]) == 3
        pred = ms.sample_predict(_data()[0], samples_per_particle=2)
        assert pred.shape == (16, 2)


def test_ensemble_baseline_equals_compiled_path():
    """Handwritten sequential ensemble == vmapped compiled ensemble."""
    mod = _module()
    data = _data()
    opt = sgd(0.05)
    params_b, _ = baselines.ensemble_baseline(mod, opt, 3, data, epochs=4, seed=7)

    rngs = jax.random.split(jax.random.PRNGKey(7), 3)
    stacked = functional.stack_pytrees([mod.init(r) for r in rngs])
    opt_state = jax.vmap(opt.init)(stacked)
    step = jax.jit(functional.ensemble_step(mod.loss, opt))
    for _ in range(4):
        for b in data:
            stacked, opt_state, _ = step(stacked, opt_state, b)
    for i in range(3):
        assert jnp.abs(stacked["w"][i] - params_b[i]["w"]).max() < 1e-5
