"""2D (particle x model) placement: the ISSUE-7 acceptance subprocess.

The heavy check lives in tests/_sharded_2d_check.py and runs under 4
forced host devices arranged as a ``particle=2 x model=2`` mesh: fused
ensemble/SVGD parity vs single-device, serving + paged decode parity,
kv-page heads on the model axis, zero mid-run host transfers, second
service cold==0, and the ~4x per-device footprint drop on a model-only
placement of a llama3-8b stand-in. This wrapper keeps it in tier-1 and
in the ``sharded-2x2`` CI matrix job.
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_2d_placement_across_4_devices():
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "_sharded_2d_check.py")],
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
             "XLA_FLAGS": "--xla_force_host_platform_device_count=4"},
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    assert "OK" in out.stdout
