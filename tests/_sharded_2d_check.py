"""2D-placement acceptance check (run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``; see
tests/test_placement2d.py and the CI ``sharded-2x2`` matrix job).

Asserts, over a ``particle=2 x model=2`` mesh (the tentpole of ISSUE 7):
  1. fused DeepEnsemble / SteinVGD training matches the single-device
     compiled path to < 1e-4, with the particle axis on ``data`` AND the
     tensor-parallel trailing dims (``mlp/wi/w`` etc.) on ``model``;
  2. multi-epoch fused runs perform zero mid-run host transfers of
     stacked state (store stats deltas are zero inside the loop);
  3. serving matches single-device BMA, reads the store without
     unsharding it, and a SECOND service over the same store
     cold-compiles nothing;
  4. continuous-batching paged decode produces the same tokens as the
     single-device path, with ``kv_pages`` heads sharded over ``model``
     and zero steady-state cold compiles;
  5. a model-only ``1 x 4`` placement of a llama3-8b stand-in (same
     rule coverage: GQA attention + swiglu MLP + tied vocab ends) drops
     per-device parameter bytes ~4x vs replicated, reported through
     ``pd.stats()["placement"]``.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np

from repro.bdl import DeepEnsemble, SteinVGD
from repro.core import ParticleModule, Placement, PushDistribution
from repro.launch.mesh import make_bench_mesh
from repro.optim import sgd

N_DEV = 4
N_PARTICLES = 4
FLAT_KEYS = ("stacks", "unstacks", "device_puts", "checkouts", "commits",
             "row_flushes")


def tiny_module():
    """Rule-matching paths (mlp/wi/w, mlp/wo/w) so the model axis
    actually engages — the store-check's flat {"w","b"} params match no
    tensor-parallel rule and would leave the model axis idle."""
    def init(rng):
        k1, k2 = jax.random.split(rng)
        return {"mlp": {"wi": {"w": jax.random.normal(k1, (3, 16)) * 0.5},
                        "wo": {"w": jax.random.normal(k2, (16, 2)) * 0.5}}}

    def apply(p, x):
        return jax.nn.gelu(x @ p["mlp"]["wi"]["w"]) @ p["mlp"]["wo"]["w"]

    def loss(p, batch):
        x, y = batch
        return jnp.mean((apply(p, x) - y) ** 2), {}

    def fwd(p, batch):
        x = batch["x"] if isinstance(batch, dict) else batch[0]
        return apply(p, x)

    return ParticleModule(init, loss, fwd)


def data():
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 3))
    return [(x, x @ jnp.ones((3, 2)))]


def check_2d_sharded(store, key, model_dims):
    """Every leaf: particle axis on `data`; the leaves named in
    ``model_dims`` ({path-substring: dim}) carry `model` at that dim."""
    st = store.stacked(key)
    seen = set()
    for path, leaf in jax.tree_util.tree_flatten_with_path(st)[0]:
        if leaf.ndim == 0:
            continue
        spec = leaf.sharding.spec
        assert spec and spec[0] == "data", \
            f"{key}{path}: particle axis not sharded, spec={spec}"
        from repro.sharding.rules import normalize_path
        pstr = normalize_path(path)
        for frag, dim in model_dims.items():
            if frag in pstr:
                seen.add(frag)
                got = spec[dim] if dim < len(spec) else None
                assert got == "model", \
                    f"{key}{pstr}: want model at dim {dim}, spec={spec}"
        devs = {s.device.id for s in leaf.addressable_shards}
        assert len(devs) == N_DEV, \
            f"{key}{pstr}: {len(devs)} devices hold shards, want {N_DEV}"
    assert seen == set(model_dims), \
        f"{key}: model-sharded leaves missing: {set(model_dims) - seen}"


def train_parity(placement):
    """Fused train on the 2x2 placement vs single-device compiled."""
    batches = data()
    for algo, kw in [
        (DeepEnsemble, dict(optimizer=sgd(0.05), num_particles=N_PARTICLES)),
        (SteinVGD, dict(num_particles=N_PARTICLES, lr=0.05, lengthscale=1.0)),
    ]:
        preds, params = {}, {}
        for tag, pl_ in (("single", None), ("2d", placement)):
            with algo(tiny_module(), num_devices=1, seed=0,
                      backend="compiled", placement=pl_) as a:
                pids, _ = a.bayes_infer(batches, 3, **kw)
                if tag == "2d":
                    check_2d_sharded(a.store, "params",
                                     {"mlp/wi/w": 2, "mlp/wo/w": 1})
                    before = a.store.snapshot_stats()
                    extra = (dict(optimizer=kw["optimizer"])
                             if "optimizer" in kw else
                             dict(lr=kw["lr"], lengthscale=kw["lengthscale"]))
                    a._fused_epochs(pids, batches, 5, **extra)
                    after = a.store.snapshot_stats()
                    for k in ("unstacks", "stacks", "device_puts"):
                        assert after[k] == before[k], \
                            f"fused epochs did host transfers: {k}"
                    # drive parity through the same total step count
                    with algo(tiny_module(), num_devices=1, seed=0,
                              backend="compiled") as ref:
                        rpids, _ = ref.bayes_infer(batches, 3, **kw)
                        ref._fused_epochs(rpids, batches, 5, **extra)
                        preds["single"] = ref.posterior_pred(batches[0])
                        params["single"] = [
                            ref.push_dist.p_params(p)["mlp"]["wi"]["w"]
                            for p in rpids]
                    preds["2d"] = a.posterior_pred(batches[0])
                    params["2d"] = [
                        a.push_dist.p_params(p)["mlp"]["wi"]["w"]
                        for p in pids]
        err = float(jnp.abs(preds["single"] - preds["2d"]).max())
        assert err < 1e-4, f"{algo.__name__}: pred mismatch {err}"
        for ps, p2 in zip(params["single"], params["2d"]):
            perr = float(jnp.abs(ps - p2).max())
            assert perr < 1e-4, f"{algo.__name__}: param mismatch {perr}"
        print(f"{algo.__name__}: 2x2 vs single-device parity {err:.2e}, "
              "model axis engaged, zero mid-run host transfers")


def serve_parity(placement):
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 3))
    train = data()
    probe = {"x": x}
    with DeepEnsemble(tiny_module(), num_devices=1, seed=0,
                      backend="compiled", placement=placement) as de:
        de.bayes_infer(train, 3, optimizer=sgd(0.05),
                       num_particles=N_PARTICLES)
        pids = de.push_dist.particle_ids()
        member = []
        for p in pids:
            pp = de.push_dist.p_params(p)
            member.append(np.asarray(
                jax.nn.gelu(x @ pp["mlp"]["wi"]["w"]) @ pp["mlp"]["wo"]["w"]))
        ref_mean = np.mean(np.stack(member), 0)

        from repro.runtime import global_cache
        with de.posterior_predictive(kind="regress", max_batch=8,
                                     max_wait_ms=1.0) as svc:
            heads = svc.predict_batch(probe)
            err = float(np.abs(np.asarray(heads["mean"]) - ref_mean).max())
            assert err < 1e-4, f"2x2 BMA vs per-particle reference: {err}"
            before = de.store.snapshot_stats()
            for i in range(4):
                svc.predict({"x": np.asarray(x[i % 16])})
            svc.predict_batch(probe)
            after = de.store.snapshot_stats()
            delta = {k: after[k] - before[k] for k in FLAT_KEYS}
            assert all(v == 0 for v in delta.values()), \
                f"serving touched stacked state: {delta}"
            check_2d_sharded(de.store, "params",
                             {"mlp/wi/w": 2, "mlp/wo/w": 1})
        cold0 = global_cache().snapshot_stats()["cold_compiles"]
        with de.posterior_predictive(kind="regress", max_batch=8,
                                     max_wait_ms=1.0) as svc2:
            heads2 = svc2.predict_batch(probe)
            err2 = float(np.abs(np.asarray(heads2["mean"]) - ref_mean).max())
            assert err2 < 1e-4, f"second service BMA: {err2}"
        assert global_cache().snapshot_stats()["cold_compiles"] == cold0, \
            "second service over the same store cold-compiled under 2x2"

        # the stats surface reports the plan every layer derived from
        pstats = de.push_dist.stats()["placement"]
        assert pstats["mesh_shape"] == {"data": 2, "model": 2}, pstats
        assert pstats["model_axis_size"] == 2 and pstats["mode"] == "tp"
        assert pstats["per_device_param_bytes"] > 0
        print(f"serve: 2x2 BMA parity {err:.2e}, store untouched, "
              "second service cold==0, placement stats reported")


def decode_parity(placement):
    """Paged decode over 2x2 must produce the single-device tokens, with
    kv page heads on the model axis and zero steady-state compiles."""
    from repro import configs
    from repro.models import api
    from repro.runtime import global_cache
    from repro.serve import serve_decode

    cfg = configs.get("qwen1.5-0.5b").replace(
        n_units=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
        d_ff=64, vocab_size=128, max_seq_len=64)
    lm = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)
    prompts = [[3 + i, 5, 7, 11, 13] for i in range(3)]
    tokens = {}
    for tag, pl_ in (("single", None), ("2d", placement)):
        with PushDistribution(lm, num_devices=1, seed=0,
                              placement=pl_) as pd:
            for _ in range(N_PARTICLES):
                pd.p_create()
            svc = serve_decode(pd, cfg, num_pages=16, page_size=8,
                               max_active=2, decode_kernel=False,
                               warmup_buckets=(8,))
            try:
                cold0 = global_cache().snapshot_stats()["cold_compiles"]
                handles = [svc.generate_async(p, max_new=4) for p in prompts]
                tokens[tag] = [h.result(300).tokens for h in handles]
                if tag == "2d":
                    assert global_cache().snapshot_stats()["cold_compiles"] \
                        == cold0, "steady-state decode cold-compiled"
                    # kv page leaves: (cap, n_units, pages, page, KVH, hd)
                    # with KVH on the model axis (the /k, /v rule)
                    st = pd.store.stacked("kv_pages")
                    flat = jax.tree_util.tree_flatten_with_path(st)[0]
                    kv = [(jax.tree_util.keystr(pa), leaf)
                          for pa, leaf in flat
                          if jax.tree_util.keystr(pa).endswith(("'k']",
                                                                "'v']"))]
                    assert kv, "no k/v leaves in kv_pages"
                    for pstr, leaf in kv:
                        spec = leaf.sharding.spec
                        assert spec[0] == "data" and "model" in spec, \
                            f"kv_pages{pstr}: spec={spec}"
                        assert spec[leaf.ndim - 2] == "model", \
                            f"kv_pages{pstr}: heads not on model: {spec}"
                    dec = pd.stats()["decode"]
                    assert dec["retired"] == 3, dec
            finally:
                svc.close()
    assert tokens["2d"] == tokens["single"], \
        f"decode tokens diverged: {tokens}"
    print(f"decode: 2x2 tokens == single-device {tokens['2d']}, "
          "kv heads on model axis, steady state cold==0")


def model_only_footprint():
    """1 x model=4 placement of a llama3-8b stand-in: per-device param
    bytes drop ~4x vs replicated (the ensemble-of-models-that-don't-fit
    headline), visible through pd.stats()['placement']."""
    from repro import configs
    from repro.models import api

    cfg = configs.get("llama3-8b").replace(
        n_units=2, d_model=64, n_heads=8, n_kv_heads=4, head_dim=8,
        d_ff=128, vocab_size=256, max_seq_len=64)
    lm = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)
    byts = {}
    for tag, model in (("replicated", 1), ("model4", 4)):
        pl = Placement(mesh=make_bench_mesh(N_DEV, model=model))
        with PushDistribution(lm, num_devices=1, seed=0, placement=pl) as pd:
            pd.p_create()
            pd.store.stacked("params")          # place on the mesh
            st = pd.stats()["placement"]
            assert st["mesh_shape"] == {"data": N_DEV // model,
                                        "model": model}, st
            byts[tag] = st["per_device_param_bytes"]
    ratio = byts["replicated"] / max(byts["model4"], 1)
    assert ratio > 3.0, f"model-only placement footprint ratio {ratio:.2f} " \
        f"(replicated {byts['replicated']}, model4 {byts['model4']})"
    print(f"llama3-8b stand-in: per-device param bytes {byts['replicated']}"
          f" -> {byts['model4']} ({ratio:.2f}x drop on model=4)")


def main():
    assert len(jax.devices()) == N_DEV, \
        f"need {N_DEV} forced host devices, got {len(jax.devices())}"
    placement = Placement(mesh=make_bench_mesh(N_DEV, model=2),
                          particle_axis="data", mode="tp")
    assert placement.model_axis_size() == 2
    assert placement.particle_axis_size() == 2
    train_parity(placement)
    serve_parity(placement)
    decode_parity(placement)
    model_only_footprint()
    print("OK")


if __name__ == "__main__":
    sys.exit(main())
