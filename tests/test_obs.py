"""repro.obs: golden schema for every pd.stats() section (the keys are
the repo's observability contract — renaming one breaks dashboards),
tracer semantics (nesting, ring bound, disabled no-op), the typed metric
registry, the Chrome/Perfetto + Prometheus exporters, per-Program cost
attribution, and the latency-percentile dedup regression (service stats
keys byte-identical after the Histogram collapse)."""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParticleModule, PushDistribution
from repro.obs import Obs, clock, export, metrics, summary, trace
from repro.optim import sgd
from repro.runtime import ProgramCache, specs
from repro.serve import serve


@pytest.fixture(autouse=True)
def _tracer_reset():
    """Tracing must never leak across tests (other suites assert
    counter deltas that instrumentation noise would perturb)."""
    yield
    trace.disable()
    trace.clear()


def _linear_module(out_dim: int = 4):
    def init(rng):
        return {"w": jax.random.normal(rng, (3, out_dim)),
                "b": jnp.zeros((out_dim,))}

    def loss(p, b):
        return jnp.mean((b["x"] @ p["w"] + p["b"] - b["y"]) ** 2), {}

    def fwd(p, b):
        return b["x"] @ p["w"] + p["b"]

    return ParticleModule(init, loss, fwd)


def _pd(n=3, backend="compiled"):
    pd = PushDistribution(_linear_module(), num_devices=1, backend=backend)
    for _ in range(n):
        pd.p_create(sgd(0.1))
    return pd


# ---------------------------------------------------------------------------
# golden schema: pd.stats() keys are the observability contract
# ---------------------------------------------------------------------------

GOLDEN = {
    "executor": {"dispatched", "completed", "pool_dispatched",
                 "queue_depths", "pool_depth", "max_queue_depth",
                 "wait_time_s", "run_time_s", "threads"},
    "dispatch": {"dispatches", "swaps_in", "swaps_out", "xdev_transfers"},
    "store": {"stacks", "unstacks", "row_flushes", "commits",
              "device_puts", "checkouts", "mask_invalidations",
              "capacity_growths", "slot_clones"},
    "program_cache": {"hits", "misses", "cold_compiles", "evictions",
                      "programs", "hit_rate"},
    "lifecycle": {"capacity", "live", "free_slots", "generation",
                  "mask_invalidations", "capacity_growths", "clones",
                  "kills", "rebalances"},
    "placement": {"mesh_shape", "mode", "particle_axis", "model_axis",
                  "model_axis_size", "per_device_param_bytes", "reshards"},
    "obs": {"tracing_enabled", "spans_recorded", "spans_buffered",
            "spans_dropped", "ring", "clock", "metrics"},
}


def test_stats_golden_schema():
    pd = _pd()
    try:
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 3))
        pd.p_predict({"x": x})
        st = pd.stats()
        assert st["backend"] == "compiled"
        for section, keys in GOLDEN.items():
            assert set(st[section]) == keys, \
                f"stats()[{section!r}] keys drifted"
        obs = st["obs"]
        assert obs["clock"] == "perf_counter"
        assert isinstance(obs["tracing_enabled"], bool)
        assert obs["ring"] >= 1
    finally:
        pd.cleanup()


def test_serve_stats_latency_keys_regression():
    """The three duplicated latency implementations collapsed onto one
    obs.metrics Histogram — every historical service stats key must
    survive, and the percentiles must equal np.percentile over the same
    ring the batcher reports via latencies_s()."""
    pd = _pd()
    try:
        with serve(pd, kind="regress", max_batch=4, max_wait_ms=1.0) as svc:
            xs = jax.random.normal(jax.random.PRNGKey(1), (9, 3))
            for i in range(9):
                svc.predict({"x": xs[i]})
            st = svc.stats()
            for k in ("latency_p50_ms", "latency_p95_ms", "latency_p99_ms",
                      "requests_per_s", "requests", "batches", "rows",
                      "padded_rows", "size_flushes", "deadline_flushes",
                      "close_flushes", "max_queue_depth", "errors",
                      "h2d_transfers", "queue_depth", "staging_builds",
                      "staging_reuses", "occupancy", "engine"):
                assert k in st, f"service stats lost {k!r}"
            lat = svc.batcher.latencies_s()
            assert len(lat) == 9
            for q, key in ((50, "latency_p50_ms"), (95, "latency_p95_ms"),
                           (99, "latency_p99_ms")):
                want = float(np.percentile(np.asarray(lat), q)) * 1e3
                assert st[key] == pytest.approx(want)
            assert st["latency_p99_ms"] >= st["latency_p50_ms"] > 0.0
    finally:
        pd.cleanup()


def test_decode_stats_golden_schema():
    """pd.stats() grows the decode section while a DecodeScheduler
    serves the store; its keys are part of the contract too."""
    from repro import configs
    from repro.models import api
    from repro.serve import serve_decode

    cfg = configs.get("qwen1.5-0.5b").replace(
        n_units=1, d_model=16, n_heads=2, n_kv_heads=1, head_dim=8,
        d_ff=32, vocab_size=64, max_seq_len=64)
    module = ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)
    pd = PushDistribution(module, num_devices=1)
    pd.p_create()
    try:
        svc = serve_decode(pd, cfg, num_pages=16, page_size=8,
                           max_active=2, warmup=False, decode_kernel=False)
        try:
            g = svc.generate([3, 7, 11], max_new=3)
            assert len(g.tokens) == 3
            dec = pd.stats()["decode"]
            assert set(dec) == {
                "submitted", "admitted", "retired", "preempted", "steps",
                "prefills", "generated_tokens", "active_row_steps",
                "admission_blocked", "h2d_transfers", "errors",
                "max_queue_depth", "queue_depth", "active_seqs",
                "max_active", "row_occupancy", "pool", "kv_pages",
                "speculative"}
            assert set(dec["kv_pages"]) == {"key", "dtypes",
                                            "per_device_bytes"}
            assert dec["kv_pages"]["key"] == "kv_pages"
            assert dec["speculative"] is None   # plain scheduler
            st = svc.stats()
            assert st["latency_p99_ms"] >= st["latency_p50_ms"] > 0.0
            assert st["tokens_per_s"] > 0.0
            lat = svc.scheduler.latencies_s()
            assert st["latency_p50_ms"] == pytest.approx(
                metrics.percentile(lat, 50) * 1e3)
        finally:
            svc.close()
    finally:
        pd.cleanup()


# ---------------------------------------------------------------------------
# tracer semantics
# ---------------------------------------------------------------------------

def test_span_nesting_records_on_exit():
    trace.clear()
    trace.enable()
    with trace.span("outer", "t"):
        with trace.span("inner", "t", k=1):
            pass
    spans = trace.snapshot()
    names = [s["name"] for s in spans]
    # inner exits first, and its interval nests inside outer's
    assert names == ["inner", "outer"]
    inner, outer = spans
    assert outer["t0"] <= inner["t0"] and inner["t1"] <= outer["t1"]
    assert inner["args"] == {"k": 1}
    assert inner["tid"] == outer["tid"] == threading.get_ident()


def test_ring_bound_and_drop_accounting():
    trace.clear()
    trace.enable(ring=8)
    try:
        for i in range(20):
            trace.instant(f"e{i}", "t")
        c = trace.TRACER.counts()
        assert c == {"recorded": 20, "buffered": 8, "dropped": 12}
        # the ring keeps the NEWEST spans
        assert [s["name"] for s in trace.snapshot()] == \
            [f"e{i}" for i in range(12, 20)]
    finally:
        trace.enable(ring=trace._DEFAULT_RING)
        trace.disable()
        trace.clear()


def test_disabled_path_is_noop():
    trace.disable()
    trace.clear()
    s = trace.span("x", "t")
    assert s is trace.span("y", "t")     # shared no-op singleton
    with s:
        pass
    trace.instant("z", "t")
    assert trace.snapshot() == []
    assert trace.TRACER.counts()["recorded"] == 0


def test_traced_decorator_and_track_names():
    trace.clear()
    trace.enable()

    @trace.traced(cat="fn")
    def work(a, b):
        return a + b

    assert work(2, 3) == 5
    spans = trace.snapshot()
    assert len(spans) == 1 and spans[0]["name"].endswith("work")
    trace.TRACER.name_track("my-track")
    assert trace.TRACER.track_names()[threading.get_ident()] == "my-track"


def test_runtime_spans_emitted():
    """A traced fused predict leaves runtime spans in the ring with the
    documented names (cold compile, then a hit, then the dispatch)."""
    trace.clear()
    trace.enable()
    pd = _pd()
    try:
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 3))
        pd.p_predict({"x": x})
        pd.p_predict({"x": x})       # second call: a cache.hit instant
        names = {s["name"] for s in trace.snapshot()}
        cats = {s["cat"] for s in trace.snapshot()}
        assert "store.generation_bump" in names     # the p_creates
        assert "runtime.lower" in names and "cache.hit" in names \
            and "cache.miss" in names
        assert "program.ensemble_predict" in names
        assert {"store", "runtime"} <= cats
    finally:
        pd.cleanup()


def test_executor_spans_emitted():
    """NEL dispatch: every work item gets an executor.run span carrying
    its queue + mailbox wait, on a named worker track."""
    trace.clear()
    trace.enable()
    pd = _pd(backend="nel")
    try:
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 3))
        pd.p_predict({"x": x})
        pd.drain(10.0)
        runs = [s for s in trace.snapshot() if s["name"] == "executor.run"]
        assert len(runs) >= 3                       # one forward/particle
        assert all(s["cat"] == "executor" for s in runs)
        assert all(s["args"]["wait_ms"] >= 0 for s in runs)
        tracks = trace.TRACER.track_names()
        assert any(n.startswith("push-dev") for n in
                   (tracks.get(s["tid"], "") for s in runs))
    finally:
        pd.cleanup()


def test_bdl_epoch_spans():
    from repro.bdl import DeepEnsemble
    trace.clear()
    trace.enable()
    mod = _linear_module()
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 3))
    data = [{"x": x, "y": x @ jnp.ones((3, 4))}]
    infer = DeepEnsemble(mod, num_devices=1, backend="compiled")
    try:
        infer.bayes_infer(data, epochs=3, optimizer=sgd(0.1),
                          num_particles=2)
        spans = trace.snapshot()
        epochs = [s for s in spans if s["name"] == "bdl.epoch"]
        assert len(epochs) == 3
        assert [s["args"]["epoch"] for s in epochs] == [0, 1, 2]
        assert all(s["args"]["algo"] == "ensemble" for s in epochs)
        # fused training goes through the store's checkout/commit window
        names = {s["name"] for s in spans}
        assert "store.checkout" in names and "store.commit" in names
    finally:
        infer.cleanup()


# ---------------------------------------------------------------------------
# metric registry
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram():
    reg = metrics.Registry()
    c = reg.counter("requests", route="predict")
    c.inc()
    c.inc(4)
    assert c.value == 5
    assert reg.counter("requests", route="predict") is c   # get-or-create
    assert reg.counter("requests", route="decode") is not c

    g = reg.gauge("depth")
    g.set(7.5)
    assert g.value == 7.5
    g.set_fn(lambda: 42)
    assert g.value == 42

    h = reg.histogram("lat", ring=4)
    for v in (1.0, 2.0, 3.0, 4.0, 5.0):
        h.observe(v)
    assert h.count == 5 and h.sum == 15.0
    assert h.values() == [2.0, 3.0, 4.0, 5.0]       # ring dropped 1.0
    snap = h.snapshot()
    assert snap["count"] == 5 and snap["p50"] == 3.5
    with pytest.raises(TypeError):
        reg.gauge("lat")                             # kind clash
    assert reg.size() == 4


def test_percentile_matches_numpy_and_empty():
    xs = [0.3, 0.1, 0.9, 0.5, 0.7]
    for q in (0, 50, 95, 99, 100):
        assert metrics.percentile(xs, q) == pytest.approx(
            float(np.percentile(np.asarray(xs), q)))
    assert metrics.percentile([], 99) == 0.0


def test_registry_collectors():
    reg = metrics.Registry()
    reg.register_collector("store", lambda: {"live": 3, "nested": {"a": 1}})
    reg.register_collector("dead", lambda: 1 / 0)    # must not kill export
    vals = reg.collector_values()
    assert vals == {"store": {"live": 3, "nested": {"a": 1}}}


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_chrome_trace_structure_and_roundtrip(tmp_path):
    trace.clear()
    trace.enable()
    trace.TRACER.name_track("main-test-track")
    with trace.span("work", "store", key="params"):
        pass
    trace.instant("mark", "decode", sid=7)
    doc = export.chrome_trace()
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert any(e["args"]["name"] == "main-test-track" for e in meta)
    complete = [e for e in evs if e["ph"] == "X"]
    assert len(complete) == 1
    ev = complete[0]
    assert ev["name"] == "work" and ev["cat"] == "store"
    assert ev["dur"] >= 0 and ev["ts"] >= 0        # µs since clock.EPOCH
    assert ev["args"] == {"key": "params"}
    inst = [e for e in evs if e["ph"] == "i"]
    assert len(inst) == 1 and inst[0]["s"] == "t"
    assert inst[0]["args"] == {"sid": 7}

    path = export.dump_chrome_trace(str(tmp_path / "t.json"))
    with open(path) as f:
        loaded = json.load(f)                       # valid JSON on disk
    assert loaded["traceEvents"]


def test_clock_to_us():
    t = clock.now()
    assert clock.to_us(t) >= 0.0
    assert clock.to_us(clock.EPOCH + 1.0) - clock.to_us(clock.EPOCH) \
        == pytest.approx(1e6)


def test_prometheus_text():
    reg = metrics.Registry()
    reg.counter("reqs", route="a").inc(3)
    reg.gauge("depth").set(2)
    h = reg.histogram("lat_s")
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    reg.register_collector("store", lambda: {"live": 4})
    text = export.prometheus_text(
        reg, extra={"serve": {"p99 (ms)": 1.5, "name": "drop-me"}})
    assert "# TYPE repro_reqs counter" in text
    assert 'repro_reqs{route="a"} 3.0' in text
    assert "# TYPE repro_depth gauge" in text
    assert "# TYPE repro_lat_s summary" in text
    assert 'repro_lat_s{quantile="0.5"} 0.2' in text
    assert "repro_lat_s_count 3" in text
    assert "repro_store_live 4.0" in text
    assert "repro_serve_p99__ms_ 1.5" in text       # sanitized name
    assert "drop-me" not in text                    # strings are dropped


# ---------------------------------------------------------------------------
# per-Program cost attribution
# ---------------------------------------------------------------------------

def test_program_cost_attribution():
    """Every ProgramCache entry exposes FLOPs / bytes accessed /
    per-device param bytes (the ISSUE's acceptance bar). A private cache
    keeps this test independent of whatever the global cache holds."""
    cache = ProgramCache()
    mod = _linear_module()
    stacked = jax.vmap(mod.init)(
        jax.random.split(jax.random.PRNGKey(0), 3))
    batch = {"x": jnp.ones((4, 3))}
    mask = jnp.ones((3,), jnp.float32)
    spec = specs.ensemble_predict(mod.forward)
    prog = cache.program(spec, None, (stacked, batch, mask))

    entries = cache.program_costs()                 # lazy: nothing computed
    assert len(entries) == 1
    e = entries[0]
    assert e["name"] == spec.name and e["cost"] is None
    assert e["num_particles"] == 3
    assert len(e["fingerprint"]) == 16
    # params: 3 particles x (3x4 w + 4 b) x f32, one device
    assert e["param_bytes_per_device"] == 3 * (12 + 4) * 4

    cost = prog.cost()                              # one analysis compile
    assert cost["flops"] > 0 and cost["bytes_accessed"] > 0
    assert cost["param_bytes_per_device"] == e["param_bytes_per_device"]
    assert cost["memory"]["argument_bytes"] > 0
    assert cost["loop_aware"]["flops"] > 0
    assert prog.cost() is cost                      # memoized
    assert cache.program_costs()[0]["cost"] is cost
    assert cache.program_costs(compute=True)[0]["cost"] is cost


# ---------------------------------------------------------------------------
# the pd.obs() front-end
# ---------------------------------------------------------------------------

def test_obs_front_end(tmp_path):
    pd = _pd()
    try:
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 3))
        trace.enable()
        pd.p_predict({"x": x})
        obs = pd.obs()
        assert isinstance(obs, Obs)
        snap = obs.snapshot()
        assert set(snap) == {"stats", "devices", "store", "programs",
                             "trace"}
        assert snap["devices"] and "platform" in snap["devices"][0]
        sg = snap["store"]
        assert sg["live"] == 3 and sum(sg["live_mask"]) == 3
        assert sg["per_device_bytes"]["params"] > 0
        assert snap["trace"]["recorded"] > 0
        path = obs.dump_trace(str(tmp_path / "pd.json"))
        with open(path) as f:
            doc = json.load(f)
        assert any(e.get("ph") == "X" for e in doc["traceEvents"])
        text = obs.prometheus()
        assert "repro_program_cache_hits" in text
        s = summary()
        assert s["tracing_enabled"] and s["spans_recorded"] > 0
    finally:
        pd.cleanup()
