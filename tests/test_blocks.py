"""Attention / norm / rope unit tests (values AND grads vs naive oracle)."""
import math

import jax
import jax.numpy as jnp
import pytest

from repro.models.blocks import (decode_attention, flash_attention,
                                 norm_apply, norm_init, rope)


def naive_attention(q, k, v, kind="causal", window=0, prefix_len=0):
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    qq = q.reshape(B, Sq, KVH, H // KVH, hd)
    s = jnp.einsum("bqngh,bknh->bngqk", qq, k) / math.sqrt(hd)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    masks = {
        "causal": kp <= qp,
        "sliding": (kp <= qp) & (kp > qp - window),
        "prefix": (kp <= qp) | (kp < prefix_len),
        "bidir": jnp.ones((Sq, Sk), bool),
    }
    s = jnp.where(masks[kind][None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngqk,bknh->bngqh", p, v)
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd)


CASES = [
    (2, 37, 4, 2, 8, "causal", 0, 0),
    (1, 64, 4, 1, 16, "sliding", 7, 0),
    (2, 50, 2, 2, 8, "prefix", 0, 11),
    (1, 33, 4, 4, 8, "bidir", 0, 0),
]


@pytest.mark.parametrize("B,S,H,KVH,hd,kind,w,plen", CASES)
def test_flash_matches_naive(B, S, H, KVH, hd, kind, w, plen):
    ks = jax.random.split(jax.random.PRNGKey(S), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KVH, hd))
    v = jax.random.normal(ks[2], (B, S, KVH, hd))
    out = flash_attention(q, k, v, kind=kind, window=w, prefix_len=plen,
                          q_chunk=16, k_chunk=8)
    ref = naive_attention(q, k, v, kind, w, plen)
    assert jnp.abs(out - ref).max() < 1e-4


@pytest.mark.parametrize("B,S,H,KVH,hd,kind,w,plen", CASES)
def test_flash_grads_match_naive(B, S, H, KVH, hd, kind, w, plen):
    ks = jax.random.split(jax.random.PRNGKey(S + 1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KVH, hd))
    v = jax.random.normal(ks[2], (B, S, KVH, hd))

    def f(q, k, v):
        return flash_attention(q, k, v, kind=kind, window=w, prefix_len=plen,
                               q_chunk=16, k_chunk=8).sum()

    def g(q, k, v):
        return naive_attention(q, k, v, kind, w, plen).sum()

    gf = jax.grad(f, (0, 1, 2))(q, k, v)
    gn = jax.grad(g, (0, 1, 2))(q, k, v)
    for a, b in zip(gf, gn):
        assert jnp.abs(a - b).max() < 1e-3


def test_cross_attention_padded_keys():
    """bidir with Sk not a chunk multiple (whisper cross-attn 1500 frames)."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 20, 4, 8))
    k = jax.random.normal(key, (1, 37, 4, 8))
    v = jax.random.normal(key, (1, 37, 4, 8))
    out = flash_attention(q, k, v, kind="bidir", q_chunk=16, k_chunk=16)
    ref = naive_attention(q, k, v, "bidir")
    assert jnp.abs(out - ref).max() < 1e-4


def test_decode_matches_fullseq_last_token():
    """decode_attention over a cache == last row of full causal attention."""
    key = jax.random.PRNGKey(1)
    B, S, H, KVH, hd = 2, 9, 4, 2, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KVH, hd))
    v = jax.random.normal(ks[2], (B, S, KVH, hd))
    full = naive_attention(q, k, v, "causal")
    kpos = jnp.broadcast_to(jnp.arange(S), (B, S))
    dec = decode_attention(q[:, -1:], k, v, k_pos=kpos, cur_pos=S - 1)
    assert jnp.abs(dec[:, 0] - full[:, -1]).max() < 1e-4


def test_rope_relative_property():
    """RoPE: <rope(q,m), rope(k,n)> depends only on (m - n)."""
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, 16))

    def dot_at(m, n):
        qm = rope(q, jnp.array([m]), 10_000.0)
        kn = rope(k, jnp.array([n]), 10_000.0)
        return float(jnp.sum(qm * kn))

    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
    assert abs(dot_at(0, 0) - dot_at(7, 7)) < 1e-4


def test_norms():
    p = norm_init("rms", 8)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8)) * 5
    y = norm_apply(p, x)
    ms = jnp.mean(jnp.square(y), axis=-1)
    assert jnp.abs(ms - 1.0).max() < 1e-3
    p = norm_init("layer", 8)
    y = norm_apply(p, x)
    assert jnp.abs(jnp.mean(y, -1)).max() < 1e-4
    assert jnp.abs(jnp.std(y, -1) - 1.0).max() < 1e-2
