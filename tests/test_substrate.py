"""Optimizers, checkpointing, schedules, sharding rules."""
import os

import jax
import jax.numpy as jnp
import pytest

from repro import checkpoint
from repro.optim import adafactor, adam, sgd
from repro.optim.schedules import cosine, warmup_cosine
from repro.sharding import rules
from jax.sharding import PartitionSpec as P


@pytest.mark.parametrize("opt", [sgd(0.1, momentum=0.9), adam(0.05),
                                 adafactor(0.1)])
def test_optimizers_minimize_quadratic(opt):
    params = {"w": jnp.array([3.0, -2.0]), "m": jnp.ones((2, 2))}

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum((p["m"] - 1.0) ** 2)

    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state = opt.update(params, g, state)
    assert float(loss(params)) < 0.05 * l0


def test_schedules():
    s = cosine(1.0, 100)
    assert float(s(jnp.int32(0))) == pytest.approx(1.0)
    assert float(s(jnp.int32(100))) == pytest.approx(0.1, abs=1e-5)
    w = warmup_cosine(1.0, 10, 100)
    assert float(w(jnp.int32(5))) == pytest.approx(0.5)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": jnp.array(7)}
    d = str(tmp_path / "ck")
    checkpoint.save(d, 3, tree)
    checkpoint.save(d, 7, jax.tree.map(lambda x: x + 1, tree))
    assert checkpoint.latest_step(d) == 7
    step, restored = checkpoint.restore(d, like=tree)
    assert step == 7
    assert jnp.array_equal(restored["a"]["b"], tree["a"]["b"] + 1)
    step3, r3 = checkpoint.restore(d, step=3, like=tree)
    assert jnp.array_equal(r3["c"], tree["c"])


def test_param_spec_rules():
    # TP mode: attention qkv shards heads over model only
    spec = rules.param_spec(
        (jax.tree_util.DictKey("units"), jax.tree_util.SequenceKey(0),
         jax.tree_util.DictKey("attn"), jax.tree_util.DictKey("wq"),
         jax.tree_util.DictKey("w")), 3, "tp", particle_axis=None)
    assert spec == P(None, None, "model")
    # FSDP mode adds data sharding
    spec = rules.param_spec(
        (jax.tree_util.DictKey("units"), jax.tree_util.SequenceKey(0),
         jax.tree_util.DictKey("attn"), jax.tree_util.DictKey("wq"),
         jax.tree_util.DictKey("w")), 3, "fsdp_tp", particle_axis=None)
    assert spec == P(None, "data", "model")
    # particle axis prepends to the leading dim
    spec = rules.param_spec(
        (jax.tree_util.DictKey("attn"), jax.tree_util.DictKey("wq"),
         jax.tree_util.DictKey("w")), 3, "tp", particle_axis="data")
    assert spec == P("data", None, "model")
    # unknown leaves are replicated
    spec = rules.param_spec((jax.tree_util.DictKey("w0"),), 1, "tp", None)
    assert spec == P(None)


def test_tree_param_specs_cover_model():
    from repro import configs
    from repro.models import api
    cfg = configs.get("qwen1.5-0.5b").smoke()
    params = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0), cfg))
    specs = rules.tree_param_specs(params, "tp")
    n_sharded = sum(1 for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P)) if any(a for a in s))
    assert n_sharded > 10  # the big matrices are all covered by rules
