"""obs.export — Chrome/Perfetto trace-event JSON + Prometheus text.

``chrome_trace()`` renders the tracer's ring as the Trace Event Format
(the JSON Perfetto's legacy importer and chrome://tracing both load):
complete events (``ph: "X"``, ``ts``/``dur`` in microseconds since
``clock.EPOCH``) per span, instant events (``ph: "i"``) for
zero-duration marks, and ``thread_name`` metadata events so executor
workers show up as labelled tracks.

``prometheus_text()`` renders the metric registry — typed metrics as
counter/gauge/summary lines, pull collectors (the ``pd.stats()``
sections) flattened to gauges — in the text exposition format a
Prometheus scrape endpoint would serve.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, List, Optional

from . import clock, metrics, trace

# ---------------------------------------------------------------------------
# Chrome / Perfetto trace-event JSON
# ---------------------------------------------------------------------------


def chrome_trace(spans: Optional[List[Dict[str, Any]]] = None,
                 track_names: Optional[Dict[int, str]] = None
                 ) -> Dict[str, Any]:
    """The current tracer ring (or an explicit span list) as a
    trace-event JSON object."""
    if spans is None:
        spans = trace.TRACER.snapshot()
    if track_names is None:
        track_names = trace.TRACER.track_names()
    pid = os.getpid()
    events: List[Dict[str, Any]] = []
    tids = {s["tid"] for s in spans}
    for tid in sorted(tids):
        name = track_names.get(tid)
        if name:
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid, "args": {"name": name}})
    for s in spans:
        ev: Dict[str, Any] = {"name": s["name"], "cat": s["cat"],
                              "pid": pid, "tid": s["tid"],
                              "ts": round(clock.to_us(s["t0"]), 3)}
        if s["t1"] > s["t0"]:
            ev["ph"] = "X"
            ev["dur"] = round((s["t1"] - s["t0"]) * 1e6, 3)
        else:
            ev["ph"] = "i"
            ev["s"] = "t"       # thread-scoped instant
        if s["args"]:
            ev["args"] = s["args"]
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def dump_chrome_trace(path: str,
                      spans: Optional[List[Dict[str, Any]]] = None) -> str:
    """Write ``chrome_trace()`` to ``path``; open it at ui.perfetto.dev."""
    with open(path, "w") as f:
        json.dump(chrome_trace(spans), f)
    return path


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")


def _san(name: str) -> str:
    name = _NAME_OK.sub("_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return name


def _labels(lab) -> str:
    if not lab:
        return ""
    inner = ",".join(f'{_san(k)}="{v}"' for k, v in lab)
    return "{" + inner + "}"


def _flatten(prefix: str, obj, out: Dict[str, float]):
    """Numeric leaves of a nested stats dict -> flat metric names."""
    if isinstance(obj, bool):
        out[prefix] = float(obj)
    elif isinstance(obj, (int, float)):
        out[prefix] = float(obj)
    elif isinstance(obj, dict):
        for k, v in obj.items():
            _flatten(f"{prefix}_{_san(str(k))}", v, out)
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            _flatten(f"{prefix}_{i}", v, out)
    # strings / None / objects are dropped: exposition is numeric


def prometheus_text(registry: Optional[metrics.Registry] = None,
                    extra: Optional[Dict[str, Any]] = None,
                    prefix: str = "repro") -> str:
    """Registry metrics + pull collectors (+ an optional extra nested
    dict, e.g. a ``pd.stats()`` snapshot) in text exposition format."""
    registry = registry if registry is not None else metrics.REGISTRY
    lines: List[str] = []
    for m in registry.collect():
        name = _san(f"{prefix}_{m.name}")
        lab = _labels(m.labels)
        if m.kind == "histogram":
            lines.append(f"# TYPE {name} summary")
            snap = m.snapshot()
            for q, key in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
                ql = dict(m.labels) if m.labels else {}
                ql["quantile"] = q
                lines.append(f"{name}{_labels(tuple(ql.items()))} "
                             f"{snap[key]}")
            lines.append(f"{name}_count{lab} {snap['count']}")
            lines.append(f"{name}_sum{lab} {snap['sum']}")
        else:
            lines.append(f"# TYPE {name} {m.kind}")
            lines.append(f"{name}{lab} {float(m.value)}")
    flat: Dict[str, float] = {}
    for cprefix, values in registry.collector_values().items():
        _flatten(f"{prefix}_{_san(cprefix)}", values, flat)
    if extra:
        for k, v in extra.items():
            _flatten(f"{prefix}_{_san(str(k))}", v, flat)
    for name in sorted(flat):
        lines.append(f"# TYPE {name} gauge")
        lines.append(f"{name} {flat[name]}")
    return "\n".join(lines) + "\n"
