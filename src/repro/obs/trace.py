"""obs.trace — thread-safe span recorder (DESIGN.md §12).

One process-wide ``Tracer`` holds a bounded ring of completed spans.
The design constraints come from the executor's dispatch loop, which
runs ~10µs work items:

  * **compiled-out when disabled** — every instrumentation site guards
    on ``TRACER.enabled`` (one attribute load + branch); ``span()``
    returns a shared no-op context manager, allocating nothing.
  * **lock-free record** — a ``deque(maxlen=N)`` append is atomic under
    the GIL, so the hot path takes NO lock and old spans fall off the
    far end instead of blocking; only ``snapshot``/``clear`` and ring
    resizing serialize.
  * **per-thread track ids** — spans carry ``threading.get_ident()``;
    long-lived workers register a human name via ``name_track`` so the
    exporter can label Perfetto tracks ("push-dev0", "MainThread").

Spans nest naturally: a span records on *exit*, and Chrome trace-event
viewers reconstruct nesting from (tid, ts, dur) containment — no parent
pointers needed.

Span taxonomy (what the built-in instrumentation emits) is documented
in DESIGN.md §12; the cats are ``executor``, ``store``, ``runtime``,
``serve``, ``decode``, ``bdl``.
"""
from __future__ import annotations

import functools
import threading
from collections import deque
from typing import Any, Dict, List, Optional

from . import clock

_DEFAULT_RING = 65536


class Tracer:
    def __init__(self, ring: int = _DEFAULT_RING):
        self.enabled = False
        self._lock = threading.Lock()
        # entries: (name, cat, t0, t1, tid, args-or-None)
        self._buf: deque = deque(maxlen=ring)
        self._recorded = 0
        self._tracks: Dict[int, str] = {}

    @property
    def ring(self) -> int:
        return self._buf.maxlen

    # -- lifecycle -----------------------------------------------------------
    def enable(self, ring: Optional[int] = None):
        with self._lock:
            if ring is not None and ring != self._buf.maxlen:
                self._buf = deque(self._buf, maxlen=ring)
        self.enabled = True

    def disable(self):
        self.enabled = False

    def clear(self):
        with self._lock:
            self._buf.clear()
            self._recorded = 0

    # -- the hot path --------------------------------------------------------
    def record(self, name: str, cat: str, t0: float, t1: float,
               args: Optional[dict] = None, tid: Optional[int] = None):
        """Append one completed span. Lock-free: bounded-deque append is
        atomic; ``_recorded`` is a best-effort counter (exact under any
        single recording thread, which is what the ring-bound test and
        the drop accounting care about)."""
        if tid is None:
            tid = threading.get_ident()
        self._buf.append((name, cat, t0, t1, tid, args))
        self._recorded += 1

    def instant(self, name: str, cat: str = "event", **args):
        """Zero-duration point event (exported as a Perfetto instant)."""
        if self.enabled:
            t = clock.now()
            self.record(name, cat, t, t, args or None)

    # -- track naming --------------------------------------------------------
    def name_track(self, name: str, tid: Optional[int] = None):
        with self._lock:
            self._tracks[tid if tid is not None
                         else threading.get_ident()] = name

    def track_names(self) -> Dict[int, str]:
        """{tid: name} for every known track — explicit registrations
        first, live threads (by their Python name) as fallback."""
        with self._lock:
            names = dict(self._tracks)
        for t in threading.enumerate():
            if t.ident is not None:
                names.setdefault(t.ident, t.name)
        return names

    # -- introspection -------------------------------------------------------
    def snapshot(self) -> List[Dict[str, Any]]:
        with self._lock:
            entries = list(self._buf)
        return [{"name": n, "cat": c, "t0": t0, "t1": t1, "tid": tid,
                 "args": dict(args) if args else {}}
                for (n, c, t0, t1, tid, args) in entries]

    def counts(self) -> Dict[str, int]:
        with self._lock:
            buffered = len(self._buf)
            recorded = self._recorded
        return {"recorded": recorded, "buffered": buffered,
                "dropped": max(0, recorded - buffered)}


TRACER = Tracer()


# ---------------------------------------------------------------------------
# module-level front-end (what instrumentation sites import)
# ---------------------------------------------------------------------------

class _NoopSpan:
    """Shared do-nothing context manager: the disabled path allocates
    nothing and its __enter__/__exit__ are empty-body calls."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


class _Span:
    __slots__ = ("name", "cat", "args", "t0")

    def __init__(self, name, cat, args):
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self.t0 = clock.now()
        return self

    def __exit__(self, *exc):
        TRACER.record(self.name, self.cat, self.t0, clock.now(), self.args)
        return False


def span(name: str, cat: str = "span", **args):
    """``with span("store.commit", "store", key=key): ...`` — records a
    complete span on exit; a shared no-op when tracing is disabled."""
    if not TRACER.enabled:
        return _NOOP
    return _Span(name, cat, args or None)


def traced(name: Optional[str] = None, cat: str = "fn"):
    """Decorator form of ``span`` (label defaults to the qualname)."""
    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            if not TRACER.enabled:
                return fn(*a, **kw)
            with _Span(label, cat, None):
                return fn(*a, **kw)
        return wrapper
    return deco


def instant(name: str, cat: str = "event", **args):
    TRACER.instant(name, cat, **args)


def enabled() -> bool:
    return TRACER.enabled


def enable(ring: Optional[int] = None):
    TRACER.enable(ring)


def disable():
    TRACER.disable()


def clear():
    TRACER.clear()


def snapshot() -> List[Dict[str, Any]]:
    return TRACER.snapshot()
