"""obs.clock — the ONE timebase every subsystem stamps time with.

Before this module the runtime mixed clocks: the executor stamped
``time.perf_counter`` while the serving layer stamped ``time.monotonic``,
so a span recorded by a worker loop and a latency recorded by a batcher
were not comparable on one axis. Everything now routes through
``clock.now()`` — monotonic, highest resolution available — and the
exporters translate to microseconds relative to ``EPOCH`` (captured at
import, i.e. before any span can exist), which is what Chrome/Perfetto
trace-event ``ts`` fields want.
"""
from __future__ import annotations

import time

# perf_counter is monotonic AND sub-microsecond; monotonic() is only
# guaranteed millisecond-ish on some platforms. Bound as a module-level
# alias so the hot paths pay one global load, no wrapper frame.
now = time.perf_counter

# zero point for exported timestamps (all spans happen after import)
EPOCH = now()


def to_us(t: float) -> float:
    """A ``now()`` timestamp as microseconds since ``EPOCH``."""
    return (t - EPOCH) * 1e6
