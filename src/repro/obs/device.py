"""obs.device — device-level gauges + per-Program cost attribution.

Gauges answer the ROADMAP's HBM-budgeting questions: what does each
device hold (``memory_stats`` — None on CPU backends, so every field is
best-effort), how full is the store (capacity / live mask / per-device
bytes per key), how full is a decode page pool.

``program_cost`` is the attribution side: for one lowered ``Program`` it
runs XLA's own ``compiled.cost_analysis()`` (FLOPs, bytes accessed) AND
the repo's loop-aware HLO cost model (launch/hlo_cost.py — XLA counts a
``while`` body once; the loop-aware model multiplies by trip count, which
is what makes multi-epoch fused programs comparable). The analysis is an
AOT ``lower().compile()`` over the program's abstract args — a second
compile of the same HLO, paid once per program ON DEMAND and memoized on
the Program (``Program.cost()``), never on a dispatch path: JAX's AOT
path does not share the jit wrapper's dispatch cache, so doing this
eagerly at ``lower()`` time would double every cold compile.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import numpy as np

_MEM_KEYS = ("bytes_in_use", "peak_bytes_in_use", "bytes_limit",
             "largest_alloc_size")


def device_gauges() -> List[Dict[str, Any]]:
    """One entry per local device. Memory fields are None where the
    backend exposes no allocator stats (CPU)."""
    out = []
    for d in jax.local_devices():
        try:
            ms = d.memory_stats()
        except Exception:
            ms = None
        entry: Dict[str, Any] = {"id": int(d.id), "platform": d.platform,
                                 "kind": d.device_kind}
        for k in _MEM_KEYS:
            entry[k] = int(ms[k]) if ms and k in ms else None
        out.append(entry)
    return out


def store_gauges(store) -> Dict[str, Any]:
    """Store occupancy as the autoscaler wants it: capacity, live count,
    the live-slot mask (host-side), per-device resident bytes for every
    key under the current placement, and the precision surface — actual
    leaf dtypes + per-particle bytes per key (computed from leaf dtypes,
    NOT an itemsize assumption: a bf16 store reports half the fp32
    bytes) plus the resolved policy."""
    lc = store.lifecycle_stats()
    live = set(store.live_slots())
    prec = getattr(store, "precision", None)
    return {
        "capacity": lc["capacity"],
        "live": lc["live"],
        "free_slots": lc["free_slots"],
        "generation": lc["generation"],
        "live_mask": [1 if s in live else 0 for s in range(lc["capacity"])],
        "per_device_bytes": {k: store.per_device_bytes(k)
                             for k in store.keys()},
        "per_particle_bytes": {k: store.per_particle_bytes(k)
                               for k in store.keys()},
        "dtypes": {k: store.key_dtypes(k) for k in store.keys()},
        "precision": prec.describe() if prec is not None else None,
    }


def pool_gauges(pool) -> Dict[str, Any]:
    """Page-pool occupancy (paged KV decode)."""
    return pool.snapshot_stats()


def _cost_dict(ca) -> Dict[str, float]:
    # cost_analysis() returns a list of per-computation dicts on current
    # JAX (one per module); older versions return the dict directly
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}


def program_cost(program) -> Optional[Dict[str, Any]]:
    """Full cost attribution for one lowered Program; None for programs
    without abstract args (AOT-preloaded blobs). Prefer the memoizing
    ``Program.cost()`` over calling this directly."""
    if program.abstract_args is None:
        return None
    compiled = program.fn.lower(*program.abstract_args).compile()
    out: Dict[str, Any] = _cost_dict(compiled.cost_analysis())
    out["param_bytes_per_device"] = program.param_bytes_per_device
    try:
        ma = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
        }
    except Exception:
        out["memory"] = None
    try:
        from ..launch.hlo_cost import cost as hlo_cost
        lc = hlo_cost(compiled.as_text())
        out["loop_aware"] = {"flops": lc["flops"], "bytes": lc["bytes"],
                             "collectives": lc.get("coll", {})}
    except Exception:   # cost model is best-effort on exotic HLO
        out["loop_aware"] = None
    return out
