# Unified observability: tracing, metrics, device gauges, cost profiling.
# clock.py   — the ONE timebase (perf_counter) every subsystem stamps
# trace.py   — thread-safe bounded-ring span recorder, compiled-out when
#              disabled; instruments executor / store / cache / serve /
#              decode / bdl (span taxonomy: DESIGN.md §12)
# metrics.py — Counter/Gauge/Histogram registry + the one percentile
#              implementation behind every latency_p* stats key
# device.py  — per-device memory gauges, store/page-pool occupancy,
#              per-Program FLOPs/bytes cost attribution (hlo_cost +
#              compiled.cost_analysis)
# export.py  — Chrome/Perfetto trace-event JSON + Prometheus text
from typing import Any, Dict

# device.py is imported lazily (inside Obs): it pulls in jax, and the
# core executor — which is deliberately jax-free — imports this package
# for clock + trace on its hot path
from . import clock, export, metrics, trace


def summary() -> Dict[str, Any]:
    """The ``pd.stats()["obs"]`` section: tracer + registry state."""
    c = trace.TRACER.counts()
    return {
        "tracing_enabled": trace.TRACER.enabled,
        "spans_recorded": c["recorded"],
        "spans_buffered": c["buffered"],
        "spans_dropped": c["dropped"],
        "ring": trace.TRACER.ring,
        "clock": "perf_counter",
        "metrics": metrics.REGISTRY.size(),
    }


class Obs:
    """``pd.obs()`` front-end: one handle for snapshot / dump / export.

        pd.obs().snapshot()             # stats + devices + program costs
        pd.obs().dump_trace("t.json")   # open at ui.perfetto.dev
        pd.obs().prometheus()           # text exposition for a scrape
    """

    def __init__(self, pd):
        self.pd = pd

    def snapshot(self, *, costs: bool = False) -> Dict[str, Any]:
        """Everything at once: the unified stats dict, device gauges,
        store occupancy, per-program cost attribution (``costs=True``
        forces the lazy FLOPs/bytes analysis per cache entry), and the
        tracer's counters."""
        from . import device
        return {
            "stats": self.pd.stats(),
            "devices": device.device_gauges(),
            "store": device.store_gauges(self.pd.store),
            "programs": self.pd.runtime.cache.program_costs(compute=costs),
            "trace": trace.TRACER.counts(),
        }

    def chrome_trace(self) -> Dict[str, Any]:
        return export.chrome_trace()

    def dump_trace(self, path: str) -> str:
        return export.dump_chrome_trace(path)

    def prometheus(self) -> str:
        return export.prometheus_text(extra=self.pd.stats())
