"""obs.metrics — typed metric registry (Counter / Gauge / Histogram).

Two usage modes, matching how the repo's stats actually grew:

  * **Native metrics**: code that needs a latency distribution or a
    monotonically increasing count creates a typed metric. The serving
    layer's three duplicated latency implementations
    (``service.percentile`` + two hand-rolled ``_latencies`` ring
    deques in batcher.py) collapse onto ``Histogram`` — same ring
    bound, same ``np.percentile`` semantics, one implementation.
  * **Pull collectors**: the existing ``pd.stats()`` sections stay the
    canonical counters (their keys are asserted byte-compatible by
    tests/test_obs.py); the registry exports them to Prometheus via
    registered collector callables instead of duplicating them.

Histograms keep a bounded ring of raw observations (default 4096 — the
serving layer's historical ``_LAT_RING``) so percentiles are exact over
the recent window, plus lifetime count/sum for rate math.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

DEFAULT_RING = 4096


def percentile(xs, q: float) -> float:
    """Linear-interpolated percentile (``np.percentile`` semantics; q in
    [0, 100]); 0.0 on empty input. The single implementation behind
    every latency_p* stats key in the repo."""
    xs = np.asarray(list(xs) if not isinstance(xs, np.ndarray) else xs)
    if xs.size == 0:
        return 0.0
    return float(np.percentile(xs, q))


class _Metric:
    __slots__ = ("name", "labels")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels


class Counter(_Metric):
    kind = "counter"
    __slots__ = ("_v",)

    def __init__(self, name: str, labels=()):
        super().__init__(name, labels)
        self._v = 0

    def inc(self, n: int = 1):
        self._v += n

    @property
    def value(self):
        return self._v


class Gauge(_Metric):
    kind = "gauge"
    __slots__ = ("_v", "_fn")

    def __init__(self, name: str, labels=()):
        super().__init__(name, labels)
        self._v = 0.0
        self._fn: Optional[Callable[[], float]] = None

    def set(self, v: float):
        self._v = v

    def set_fn(self, fn: Callable[[], float]):
        """Pull gauge: ``value`` calls ``fn`` at read time."""
        self._fn = fn

    @property
    def value(self):
        return self._fn() if self._fn is not None else self._v


class Histogram(_Metric):
    """Bounded-ring distribution: exact percentiles over the last
    ``ring`` observations, lifetime count/sum. ``observe`` is lock-free
    for the same reason the tracer's record is (bounded-deque append is
    atomic; count/sum are best-effort under concurrent writers, exact
    under the single pump threads that own them here)."""
    kind = "histogram"
    __slots__ = ("_ring", "count", "sum")

    def __init__(self, name: str, labels=(), ring: int = DEFAULT_RING):
        super().__init__(name, labels)
        self._ring: deque = deque(maxlen=ring)
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float):
        self._ring.append(v)
        self.count += 1
        self.sum += v

    def values(self) -> List[float]:
        return list(self._ring)

    def percentile(self, q: float) -> float:
        return percentile(list(self._ring), q)

    def snapshot(self) -> Dict[str, float]:
        xs = list(self._ring)
        return {"count": self.count, "sum": self.sum,
                "p50": percentile(xs, 50), "p95": percentile(xs, 95),
                "p99": percentile(xs, 99)}


class Registry:
    """Name+labels -> metric, get-or-create; plus pull collectors that
    surface existing stats dicts at export time without copying them
    into typed metrics."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple, _Metric] = {}
        self._collectors: List[Tuple[str, Callable[[], Dict]]] = []

    def _get(self, cls, name: str, labels: Dict[str, Any], **kw):
        lab = tuple(sorted((k, str(v)) for k, v in labels.items()))
        key = (name, lab)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, lab, **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r}{dict(lab)} exists as {m.kind}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, ring: int = DEFAULT_RING,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, ring=ring)

    def register_collector(self, prefix: str, fn: Callable[[], Dict]):
        """``fn()`` returns a (possibly nested) dict whose numeric
        leaves are exported as gauges under ``prefix``."""
        with self._lock:
            self._collectors.append((prefix, fn))

    def collect(self) -> List[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    def collector_values(self) -> Dict[str, Dict]:
        with self._lock:
            collectors = list(self._collectors)
        out = {}
        for prefix, fn in collectors:
            try:
                out[prefix] = fn()
            except Exception:   # a dead collector must not kill export
                continue
        return out

    def size(self) -> int:
        with self._lock:
            return len(self._metrics)

    def clear(self):
        with self._lock:
            self._metrics.clear()
            self._collectors.clear()


REGISTRY = Registry()
