"""Pallas TPU kernels for the SVGD hot spot: the pairwise RBF kernel matrix
and the driving force over flattened particle parameters.

The paper identifies the kernel-matrix computation as SVGD's fundamental
bottleneck (§5.1). On TPU the shape is extreme: n (particles) is tiny
(2..256) while D (flattened parameters) is huge (1e6..1e9). The TPU-native
blocking is therefore over D: stream (n, Db) tiles of theta/grads through
VMEM and accumulate the (n, n) Gram/distance matrix (which always fits
VMEM) across grid steps; the force pass re-streams D tiles against the
resident (n, n) kernel matrix. Both kernels are MXU-shaped: every grid
step is an (n x Db) @ (Db x n) or (n x n) @ (n x Db) matmul.

  pairwise_sqdist_kernel: grid (D // Db,), out (n, n) accumulated in place
  svgd_force_kernel:      grid (D // Db,), out (n, Db) tiles

ops.py wraps these with padding + jit; ref.py is the pure-jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_D = 2048


def _sqdist_kernel(theta_ref, out_ref):
    """One D-tile: accumulate ||theta_i - theta_j||^2 partial sums."""
    t = theta_ref[...].astype(jnp.float32)              # (n, Db)
    sq = jnp.sum(t * t, axis=1)                         # (n,)
    gram = jax.lax.dot_general(t, t, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    # clamp: each block's partial is a squared distance over a dim slice
    part = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * gram, 0.0)
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
    out_ref[...] += part


def _force_kernel(ktn_ref, ksum_ref, inv_ell2_ref, theta_ref, grads_ref, out_ref):
    """One D-tile of phi = (K^T G + (ksum*theta - K^T theta) * inv_ell2)/n."""
    kt = ktn_ref[...]                                   # (n, n) = K^T / n
    t = theta_ref[...].astype(jnp.float32)              # (n, Db)
    g = grads_ref[...].astype(jnp.float32)
    ksum = ksum_ref[...]                                # (n, 1), sum_j k_ji / n
    inv_ell2 = inv_ell2_ref[0, 0]
    attract = jax.lax.dot_general(kt, g, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    ktt = jax.lax.dot_general(kt, t, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    out_ref[...] = attract - (ksum * t - ktt) * inv_ell2


def pairwise_sqdist(theta, *, block_d: int = DEFAULT_BLOCK_D,
                    interpret: bool = True):
    """theta: (n, D) -> (n, n) squared distances."""
    n, D = theta.shape
    block_d = min(block_d, D)
    nb = -(-D // block_d)
    pad = nb * block_d - D
    if pad:
        theta = jnp.pad(theta, ((0, 0), (0, pad)))      # zeros don't change d2
    return pl.pallas_call(
        _sqdist_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((n, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n, n), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(theta)


def svgd_force(theta, grads, lengthscale, *, block_d: int = DEFAULT_BLOCK_D,
               interpret: bool = True):
    """theta, grads: (n, D) f32 -> phi (n, D): the SVGD descent direction."""
    n, D = theta.shape
    d2 = pairwise_sqdist(theta, block_d=block_d, interpret=interpret)
    if not isinstance(lengthscale, jnp.ndarray):
        lengthscale = jnp.asarray(lengthscale, jnp.float32)
    ell2 = lengthscale * lengthscale
    d2 = d2 * (1.0 - jnp.eye(n, dtype=d2.dtype))        # exact-zero diagonal
    K = jnp.exp(-0.5 * d2 / ell2)                       # (n, n) k_ji
    ktn = K.T / n                                       # rows: receiving i
    ksum = (K.sum(axis=0) / n)[:, None]                 # (n, 1)
    inv_ell2 = (1.0 / ell2).reshape(1, 1)

    block_d = min(block_d, D)
    nb = -(-D // block_d)
    pad = nb * block_d - D
    if pad:
        theta = jnp.pad(theta, ((0, 0), (0, pad)))
        grads = jnp.pad(grads, ((0, 0), (0, pad)))
    phi = pl.pallas_call(
        _force_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
            pl.BlockSpec((n, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, nb * block_d), jnp.float32),
        interpret=interpret,
    )(ktn, ksum, inv_ell2, theta, grads)
    return phi[:, :D]
