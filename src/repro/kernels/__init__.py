# Pallas TPU kernels for the paper's compute hot spots:
#   svgd_rbf.py      — pairwise sqdist + SVGD force over (n_particles, D)
#   swag_moments.py  — fused SWAG running-moment update
#   attention.py     — blocked online-softmax (flash) prefill attention
#   decode_attention.py — single-token decode over a (ring) KV cache
#   paged_decode_attention.py — decode over a paged KV pool via block tables
# ops.py: jit'd wrappers (interpret on CPU, compiled on TPU)
# ref.py: pure-jnp oracles (allclose targets for tests)
from . import (attention, decode_attention, ops, paged_decode_attention, ref,
               svgd_rbf, swag_moments)
