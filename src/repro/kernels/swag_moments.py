"""Pallas TPU kernel: fused SWAG running-moment update.

mean' = (mean*n + theta)/(n+1); sq' = (sq*n + theta^2)/(n+1), fused in one
pass over the flattened parameter vector (one HBM read of theta instead of
two, one kernel launch instead of a tree of elementwise HLOs). Tiled
(8, 1024) f32 blocks in VMEM.

update_moments() is the pytree-level entry point used by repro.bdl.swag.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 1024


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    """Interpret mode is a platform property, not a call-site choice:
    compiled on TPU, interpreted everywhere else (None = auto)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _moments_kernel(n_ref, mean_ref, sq_ref, p_ref, out_mean_ref, out_sq_ref):
    n = n_ref[0, 0]
    inv = 1.0 / (n + 1.0)
    p = p_ref[...].astype(jnp.float32)
    out_mean_ref[...] = (mean_ref[...] * n + p) * inv
    out_sq_ref[...] = (sq_ref[...] * n + p * p) * inv


def moments_flat(mean, sq_mean, params, n, *,
                 interpret: Optional[bool] = None):
    """mean/sq_mean/params: (D,) f32. Returns (mean', sq')."""
    interpret = _resolve_interpret(interpret)
    D = mean.shape[0]
    nb = -(-D // BLOCK)
    pad = nb * BLOCK - D
    if pad:
        mean = jnp.pad(mean, (0, pad))
        sq_mean = jnp.pad(sq_mean, (0, pad))
        params = jnp.pad(params, (0, pad))
    shp = (nb, BLOCK)
    n_arr = jnp.asarray(n, jnp.float32).reshape(1, 1)
    out_mean, out_sq = pl.pallas_call(
        _moments_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
            pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
                   pl.BlockSpec((1, BLOCK), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(shp, jnp.float32),
                   jax.ShapeDtypeStruct(shp, jnp.float32)],
        interpret=interpret,
    )(n_arr, mean.reshape(shp), sq_mean.reshape(shp), params.reshape(shp))
    return out_mean.reshape(-1)[:D], out_sq.reshape(-1)[:D]


def _diag_std_kernel(mean_ref, sq_ref, out_ref):
    m = mean_ref[...]
    out_ref[...] = jnp.sqrt(jnp.maximum(sq_ref[...] - m * m, 1e-30))


def diag_std_flat(mean, sq_mean, *, interpret: Optional[bool] = None):
    """sqrt(max(sq_mean - mean^2, eps)) fused over (D,) f32 — the SWAG
    diagonal scale read at serve-time sampling (one HBM pass instead of
    three elementwise HLOs). Same platform gating as the moment update:
    ``interpret=None`` resolves via ``_resolve_interpret``."""
    interpret = _resolve_interpret(interpret)
    D = mean.shape[0]
    nb = -(-D // BLOCK)
    pad = nb * BLOCK - D
    if pad:
        mean = jnp.pad(mean, (0, pad))
        sq_mean = jnp.pad(sq_mean, (0, pad), constant_values=1.0)
    shp = (nb, BLOCK)
    out = pl.pallas_call(
        _diag_std_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
                  pl.BlockSpec((1, BLOCK), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(shp, jnp.float32),
        interpret=interpret,
    )(mean.reshape(shp), sq_mean.reshape(shp))
    return out.reshape(-1)[:D]


def update_moments(mean, sq_mean, params, n, *,
                   interpret: Optional[bool] = None):
    """Pytree-level fused moment update (ravel -> kernel -> unravel)."""
    from jax.flatten_util import ravel_pytree
    m_flat, unravel = ravel_pytree(mean)
    s_flat, _ = ravel_pytree(sq_mean)
    p_flat, _ = ravel_pytree(params)
    nm, ns = moments_flat(m_flat.astype(jnp.float32), s_flat.astype(jnp.float32),
                          p_flat.astype(jnp.float32), n, interpret=interpret)
    return unravel(nm), unravel(ns)
