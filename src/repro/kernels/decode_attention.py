"""Pallas TPU kernel: single-token decode attention over a KV cache.

The decode-shape hot spot (§Roofline: every decode pair is memory-bound on
reading the cache). One grid cell per (batch*kv_head); the cache streams
through VMEM in (c_block, hd) tiles with an online-softmax accumulator in
scratch — one HBM pass over the cache, no (C,) score materialization in
HBM. Invalid slots (pos < 0, ring-cache holes) are masked via the pos
tile; a ragged last tile (C % c_block != 0) is masked in-kernel with a
column iota rather than padding the caches in HBM — callers never copy.
GQA: the G query heads of a kv head ride in one (G, hd) tile.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale: float, n_cb: int, c_block: int, c_len: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32).reshape(-1, q_ref.shape[-1]) * scale
    k = k_ref[...].astype(jnp.float32).reshape(-1, k_ref.shape[-1])
    v = v_ref[...].astype(jnp.float32).reshape(-1, v_ref.shape[-1])
    pos = pos_ref[...].reshape(1, -1)                      # (1, cb)
    # Ragged tail: columns past the true cache length are out-of-bounds
    # reads (undefined contents) — mask them by index, not by pos.
    col = ci * c_block + jax.lax.broadcasted_iota(jnp.int32, (1, c_block), 1)
    valid = (pos >= 0) & (col < c_len)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, cb)
    s = jnp.where(valid, s, NEG_INF)
    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    # Zero masked weights explicitly: on an all-masked tile exp(0)=1, and
    # 0 * (undefined v) would still poison the accumulator with NaNs.
    p = jnp.where(valid, p, 0.0)
    v = jnp.where(valid.reshape(-1, 1), v, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ci == n_cb - 1)
    def _finish():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, k_pos, *, c_block: int = 512,
                     interpret: bool = True):
    """q: (B, 1, H, hd); k/v_cache: (B, C, KVH, hd); k_pos: (B, C) i32
    (slot position, -1 = empty). Returns (B, 1, H, hd)."""
    B, _, H, hd = q.shape
    C, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    c_block = min(c_block, C)
    n_cb = -(-C // c_block)

    qr = q.reshape(B, KVH, G, hd).reshape(B * KVH, G, hd)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(B * KVH, C, hd)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(B * KVH, C, hd)
    pr = jnp.repeat(k_pos, KVH, axis=0)                    # (B*KVH, C)

    kernel = functools.partial(_decode_kernel, scale=scale, n_cb=n_cb,
                               c_block=c_block, c_len=C)
    out = pl.pallas_call(
        kernel,
        grid=(B * KVH, n_cb),
        in_specs=[
            pl.BlockSpec((1, G, hd), lambda b, ci: (b, 0, 0)),
            pl.BlockSpec((1, c_block, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, c_block, hd), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, c_block), lambda b, ci: (b, ci)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, ci: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KVH, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr, pr)
    return out.reshape(B, KVH, G, hd).reshape(B, 1, H, hd)
