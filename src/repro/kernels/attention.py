"""Pallas TPU flash-attention (forward) kernel with GQA + causal masking.

Blocked online-softmax: grid (batch*kv_head, q_blocks, k_blocks), with the
(m, l, acc) running state held in VMEM scratch across the innermost k-block
loop. Block shapes are MXU-aligned (q_block x head_dim and k_block x
head_dim tiles; head_dim is padded to a multiple of 128 by ops.py if
needed). Used on the inference path (prefill); training uses the pure-jnp
flash in models.blocks (differentiable). Validated against ref.flash_attention
in interpret mode over shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, q_block: int, k_block: int,
                  n_kb: int, s_real: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # skip blocks strictly above the diagonal
        run = (ki * k_block) <= (qi * q_block + q_block - 1)

    @pl.when(run if causal else True)
    def _body():
        q = q_ref[...].astype(jnp.float32).reshape(-1, q_ref.shape[-1]) * scale
        k = k_ref[...].astype(jnp.float32).reshape(-1, k_ref.shape[-1])
        v = v_ref[...].astype(jnp.float32).reshape(-1, v_ref.shape[-1])
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        g_qb, kb = s.shape
        kpos = ki * k_block + jax.lax.broadcasted_iota(jnp.int32, (g_qb, kb), 1)
        mask = kpos < s_real                               # padded keys
        if causal:
            qpos = qi * q_block + (jax.lax.broadcasted_iota(
                jnp.int32, (g_qb, kb), 0) % q_block)
            mask = mask & (kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_kb - 1)
    def _finish():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, q_block: int = 128,
                    k_block: int = 128, interpret: bool = True):
    """q: (B, S, H, hd); k, v: (B, S, KVH, hd) -> (B, S, H, hd).

    GQA: queries are grouped per kv head; each grid cell handles one
    (batch, kv_head) pair with its G query heads folded into the q tile.
    """
    B, S, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    q_block = min(q_block, S)
    k_block = min(k_block, S)
    n_qb = -(-S // q_block)
    n_kb = -(-S // k_block)
    pad = n_qb * q_block - S
    if pad:  # pad sequence (padded q rows are discarded; padded k cols are
             # masked by causal or produce uniform attn rows we slice off)
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S_p = S + pad
    else:
        S_p = S

    # layout: (B*KVH, G, S, hd) for q; (B*KVH, S, hd) for k/v
    qr = q.reshape(B, S_p, KVH, G, hd).transpose(0, 2, 3, 1, 4)
    qr = qr.reshape(B * KVH, G, S_p, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KVH, S_p, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KVH, S_p, hd)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               q_block=q_block, k_block=k_block, n_kb=n_kb,
                               s_real=S)
    out = pl.pallas_call(
        kernel,
        grid=(B * KVH, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, G, q_block, hd), lambda b, qi, ki: (b, 0, qi, 0)),
            pl.BlockSpec((1, k_block, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, k_block, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, q_block, hd), lambda b, qi, ki: (b, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KVH, G, S_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * q_block, 1), jnp.float32),
            pltpu.VMEM((G * q_block, 1), jnp.float32),
            pltpu.VMEM((G * q_block, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(B, KVH, G, S_p, hd).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, S_p, H, hd)[:, :S]
