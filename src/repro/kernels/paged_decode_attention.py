"""Pallas TPU kernel: paged single-token decode attention.

The continuous-batching hot spot. KV state lives in a fixed pool of
fixed-size pages — (NP, page_size, KVH, hd) per layer — and each active
sequence owns a row of a block table mapping its logical page index to a
physical page. The kernel never sees a dense per-sequence cache: the
block table and the per-row sequence lengths ride in as scalar-prefetch
operands, and the page index_map gathers exactly the pages a row needs,
one (page_size, hd) tile per grid step, into the same online-softmax
scratch accumulator ``decode_attention.py`` uses. One HBM pass over the
*live* pages only; dead pages are never read.

Row conventions (shared with serve.paging.PagePool):
  * ``seq_lens[b]`` is the index of the LAST valid position (the token
    being decoded attends to positions ``0..seq_lens[b]`` inclusive);
  * ``seq_lens[b] == -1`` marks an inactive row — its output is zeros
    and no page contents influence it;
  * block-table entries past the live page count are unread garbage as
    far as correctness goes, but schedulers keep them at 0 so the
    index_map stays in bounds.

The kernel vmaps over a leading particle axis (q and pages batched,
block table / seq_lens shared) — validated in interpret mode, which is
how serve stacks it over the ParticleStore capacity axis.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(bt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, page_size: int,
                  n_pmax: int):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32).reshape(-1, q_ref.shape[-1]) * scale
    k = k_ref[...].astype(jnp.float32).reshape(page_size, -1)
    v = v_ref[...].astype(jnp.float32).reshape(page_size, -1)
    sl = sl_ref[b]
    col = pi * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    valid = (col <= sl) & (sl >= 0)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, ps)
    s = jnp.where(valid, s, NEG_INF)
    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    # Zero masked weights: on an all-masked page exp(0)=1, and the slots
    # past the sequence tail hold stale writes from a previous owner.
    p = jnp.where(valid, p, 0.0)
    v = jnp.where(valid.reshape(-1, 1), v, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(pi == n_pmax - 1)
    def _finish():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


def _paged_window_kernel(bt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, scale: float,
                         page_size: int, n_pmax: int, group: int):
    """Drafted-window variant: the q tile carries W queries per row
    (folded into the row dimension as w*G + g), each at absolute position
    ``sl + w`` — so every page is streamed from HBM ONCE for the whole
    window, and causality within the window falls out of the per-query
    position mask (window token w sits at column sl + w)."""
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32).reshape(-1, q_ref.shape[-1]) * scale
    k = k_ref[...].astype(jnp.float32).reshape(page_size, -1)
    v = v_ref[...].astype(jnp.float32).reshape(page_size, -1)
    sl = sl_ref[b]
    wg = q.shape[0]                                 # W * G rows
    col = pi * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    w_idx = jax.lax.broadcasted_iota(jnp.int32, (wg, 1), 0) // group
    # query w may see columns 0..sl+w: causal within the drafted window,
    # the full prefix outside it; the sl >= 0 leg zeroes inactive rows
    valid = (col <= sl + w_idx) & (sl >= 0)         # (W*G, ps)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = jnp.where(valid, s, NEG_INF)
    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(pi == n_pmax - 1)
    def _finish():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


def paged_decode_window_attention(q, k_pages, v_pages, block_tables,
                                  seq_lens, *, interpret: bool = True):
    """Speculative-verify attention: q: (B, W, H, hd) — W drafted-window
    queries per row, query w at absolute position ``seq_lens[b] + w``;
    k/v_pages: (NP, page_size, KVH, hd); block_tables: (B, n_pmax) i32;
    seq_lens: (B,) i32 (position of query 0, -1 = inactive row).
    Returns (B, W, H, hd); inactive rows come back as zeros.

    Same scalar-prefetched page gather and triple masking as the
    single-token kernel; the grid stays (B, KVH, n_pmax) and the window
    rides inside the q tile so pages are read once per window, not once
    per drafted token."""
    B, W, H, hd = q.shape
    page_size, KVH = k_pages.shape[1], k_pages.shape[2]
    G = H // KVH
    n_pmax = block_tables.shape[1]
    scale = 1.0 / math.sqrt(hd)
    # row r = w*G + g: window-major so the kernel recovers w as r // G
    qr = q.reshape(B, W, KVH, G, hd).transpose(0, 2, 1, 3, 4) \
         .reshape(B, KVH, W * G, hd)
    kr = k_pages.transpose(2, 0, 1, 3)        # (KVH, NP, ps, hd)
    vr = v_pages.transpose(2, 0, 1, 3)

    kernel = functools.partial(_paged_window_kernel, scale=scale,
                               page_size=page_size, n_pmax=n_pmax, group=G)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, n_pmax),
        in_specs=[
            pl.BlockSpec((1, 1, W * G, hd),
                         lambda b, h, pi, bt, sl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, hd),
                         lambda b, h, pi, bt, sl: (h, bt[b, pi], 0, 0)),
            pl.BlockSpec((1, 1, page_size, hd),
                         lambda b, h, pi, bt, sl: (h, bt[b, pi], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, W * G, hd),
                               lambda b, h, pi, bt, sl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((W * G, 1), jnp.float32),
            pltpu.VMEM((W * G, 1), jnp.float32),
            pltpu.VMEM((W * G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, W * G, hd), q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, qr, kr, vr)
    return out.reshape(B, KVH, W, G, hd).transpose(0, 2, 1, 3, 4) \
              .reshape(B, W, H, hd)


def paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                           interpret: bool = True):
    """q: (B, 1, H, hd); k/v_pages: (NP, page_size, KVH, hd);
    block_tables: (B, n_pmax) i32; seq_lens: (B,) i32 (last valid
    position, -1 = inactive row). Returns (B, 1, H, hd); inactive rows
    come back as zeros."""
    B, _, H, hd = q.shape
    page_size, KVH = k_pages.shape[1], k_pages.shape[2]
    G = H // KVH
    n_pmax = block_tables.shape[1]
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(B, KVH, G, hd)
    kr = k_pages.transpose(2, 0, 1, 3)        # (KVH, NP, ps, hd)
    vr = v_pages.transpose(2, 0, 1, 3)

    kernel = functools.partial(_paged_kernel, scale=scale,
                               page_size=page_size, n_pmax=n_pmax)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KVH, n_pmax),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, pi, bt, sl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, page_size, hd),
                         lambda b, h, pi, bt, sl: (h, bt[b, pi], 0, 0)),
            pl.BlockSpec((1, 1, page_size, hd),
                         lambda b, h, pi, bt, sl: (h, bt[b, pi], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, pi, bt, sl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, hd), q.dtype),
        interpret=interpret,
    )(block_tables, seq_lens, qr, kr, vr)
    return out.reshape(B, 1, H, hd)
