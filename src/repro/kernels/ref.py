"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def pairwise_sqdist(theta):
    sq = jnp.sum(theta * theta, axis=1)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * theta @ theta.T, 0.0)


def svgd_force(theta, grads, lengthscale):
    n = theta.shape[0]
    ell2 = jnp.asarray(lengthscale, jnp.float32) ** 2
    d2 = pairwise_sqdist(theta) * (1.0 - jnp.eye(n))
    K = jnp.exp(-0.5 * d2 / ell2)
    ksum = K.sum(axis=0)
    attract = K.T @ grads
    repulse = (ksum[:, None] * theta - K.T @ theta) / ell2
    return (attract - repulse) / n


def swag_moments(mean, sq_mean, params, n):
    new_mean = (mean * n + params) / (n + 1)
    new_sq = (sq_mean * n + params * params) / (n + 1)
    return new_mean, new_sq


def flash_attention(q, k, v, *, causal=True):
    """q: (B, S, H, hd); k, v: (B, S, KVH, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qq = q.reshape(B, S, KVH, G, hd)
    s = jnp.einsum("bqngh,bknh->bngqk", qq, k).astype(jnp.float32) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bngqk,bknh->bngqh", p, v)
    return jnp.moveaxis(o, 3, 1).reshape(B, S, H, hd)


def decode_attention(q, k_cache, v_cache, k_pos):
    """q: (B,1,H,hd); caches (B,C,KVH,hd); k_pos (B,C) -> (B,1,H,hd)."""
    import math
    B, _, H, hd = q.shape
    KVH = k_cache.shape[2]
    G = H // KVH
    qq = (q[:, 0] / math.sqrt(hd)).reshape(B, KVH, G, hd)
    s = jnp.einsum("bngh,bknh->bngk", qq, k_cache).astype(jnp.float32)
    s = jnp.where((k_pos >= 0)[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bngk,bknh->bngh", p, v_cache)
    return o.reshape(B, 1, H, hd)
