"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def pairwise_sqdist(theta):
    sq = jnp.sum(theta * theta, axis=1)
    return jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * theta @ theta.T, 0.0)


def svgd_force(theta, grads, lengthscale):
    n = theta.shape[0]
    ell2 = jnp.asarray(lengthscale, jnp.float32) ** 2
    d2 = pairwise_sqdist(theta) * (1.0 - jnp.eye(n))
    K = jnp.exp(-0.5 * d2 / ell2)
    ksum = K.sum(axis=0)
    attract = K.T @ grads
    repulse = (ksum[:, None] * theta - K.T @ theta) / ell2
    return (attract - repulse) / n


def swag_moments(mean, sq_mean, params, n):
    new_mean = (mean * n + params) / (n + 1)
    new_sq = (sq_mean * n + params * params) / (n + 1)
    return new_mean, new_sq


def flash_attention(q, k, v, *, causal=True):
    """q: (B, S, H, hd); k, v: (B, S, KVH, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qq = q.reshape(B, S, KVH, G, hd)
    s = jnp.einsum("bqngh,bknh->bngqk", qq, k).astype(jnp.float32) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bngqk,bknh->bngqh", p, v)
    return jnp.moveaxis(o, 3, 1).reshape(B, S, H, hd)


def decode_attention(q, k_cache, v_cache, k_pos):
    """q: (B,1,H,hd); caches (B,C,KVH,hd); k_pos (B,C) -> (B,1,H,hd)."""
    import math
    B, _, H, hd = q.shape
    KVH = k_cache.shape[2]
    G = H // KVH
    qq = (q[:, 0] / math.sqrt(hd)).reshape(B, KVH, G, hd)
    s = jnp.einsum("bngh,bknh->bngk", qq, k_cache).astype(jnp.float32)
    s = jnp.where((k_pos >= 0)[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bngk,bknh->bngh", p, v_cache)
    return o.reshape(B, 1, H, hd)


def paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens):
    """q: (B,1,H,hd); pages (NP,ps,KVH,hd); block_tables (B,n_pmax) i32;
    seq_lens (B,) i32 (-1 = inactive row) -> (B,1,H,hd).

    Gathers each row's pages to a dense cache and reuses the dense
    oracle; inactive rows return zeros."""
    B = q.shape[0]
    ps = k_pages.shape[1]
    n_pmax = block_tables.shape[1]
    k = jnp.take(k_pages, block_tables, axis=0).reshape(
        B, n_pmax * ps, *k_pages.shape[2:])
    v = jnp.take(v_pages, block_tables, axis=0).reshape(
        B, n_pmax * ps, *v_pages.shape[2:])
    col = jnp.arange(n_pmax * ps)[None, :]
    pos = jnp.where(col <= seq_lens[:, None], col, -1)
    out = decode_attention(q, k, v, pos)
    return jnp.where((seq_lens >= 0)[:, None, None, None], out, 0.0)


def paged_decode_window_attention(q, k_pages, v_pages, block_tables,
                                  seq_lens):
    """Multi-query (drafted-window) paged decode oracle.

    q: (B,W,H,hd) — window query w sits at absolute position
    ``seq_lens[b] + w`` and attends causally to positions
    ``0..seq_lens[b] + w`` through the row's block table; pages
    (NP,ps,KVH,hd); block_tables (B,n_pmax) i32; seq_lens (B,) i32
    position of query 0 (-1 = inactive row) -> (B,W,H,hd), inactive
    rows zeros."""
    B, W, H, hd = q.shape
    ps, KVH = k_pages.shape[1], k_pages.shape[2]
    G = H // KVH
    n_pmax = block_tables.shape[1]
    k = jnp.take(k_pages, block_tables, axis=0).reshape(
        B, n_pmax * ps, KVH, hd)
    v = jnp.take(v_pages, block_tables, axis=0).reshape(
        B, n_pmax * ps, KVH, hd)
    qq = (q / math.sqrt(hd)).reshape(B, W, KVH, G, hd)
    s = jnp.einsum("bwngh,bknh->bnwgk", qq, k).astype(jnp.float32)
    limit = seq_lens[:, None] + jnp.arange(W)[None, :]        # (B, W)
    col = jnp.arange(n_pmax * ps)
    valid = col[None, None, :] <= limit[:, :, None]           # (B, W, C)
    s = jnp.where(valid[:, None, :, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bnwgk,bknh->bnwgh", p, v)
    out = jnp.moveaxis(o, 2, 1).reshape(B, W, H, hd)
    return jnp.where((seq_lens >= 0)[:, None, None, None], out, 0.0)
