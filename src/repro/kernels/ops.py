"""Jit'd public wrappers for the Pallas kernels.

On this CPU container every kernel runs in interpret mode (the kernel body
executed in Python/XLA:CPU, numerically identical to the TPU lowering);
on a TPU backend `interpret` flips to False automatically.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import attention as _attention
from . import decode_attention as _decode
from . import paged_decode_attention as _paged
from . import svgd_rbf as _svgd
from . import swag_moments as _swag


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_d",))
def pairwise_sqdist(theta, block_d: int = _svgd.DEFAULT_BLOCK_D):
    return _svgd.pairwise_sqdist(theta, block_d=block_d, interpret=_interpret())


@partial(jax.jit, static_argnames=("block_d",))
def svgd_force(theta, grads, lengthscale, block_d: int = _svgd.DEFAULT_BLOCK_D):
    return _svgd.svgd_force(theta, grads, lengthscale, block_d=block_d,
                            interpret=_interpret())


@jax.jit
def swag_update_moments(mean, sq_mean, params, n):
    return _swag.update_moments(mean, sq_mean, params, n)


@partial(jax.jit, static_argnames=("causal", "q_block", "k_block"))
def flash_attention(q, k, v, causal: bool = True, q_block: int = 128,
                    k_block: int = 128):
    return _attention.flash_attention(q, k, v, causal=causal, q_block=q_block,
                                      k_block=k_block, interpret=_interpret())


@partial(jax.jit, static_argnames=("c_block",))
def decode_attention(q, k_cache, v_cache, k_pos, c_block: int = 512):
    return _decode.decode_attention(q, k_cache, v_cache, k_pos,
                                    c_block=c_block, interpret=_interpret())


@jax.jit
def paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens):
    return _paged.paged_decode_attention(q, k_pages, v_pages, block_tables,
                                         seq_lens, interpret=_interpret())


@jax.jit
def paged_decode_window_attention(q, k_pages, v_pages, block_tables,
                                  seq_lens):
    return _paged.paged_decode_window_attention(
        q, k_pages, v_pages, block_tables, seq_lens, interpret=_interpret())
