from .loader import DataLoader
from . import synthetic
