"""Synthetic datasets for every task family (fully offline, seeded).

  lm_batch        : Zipf-ish token stream with local n-gram structure so a
                    LM has signal to fit (loss visibly decreases).
  mnist_like      : class-conditional blob images, 28x28x1, 10 classes —
                    a stand-in for MNIST in the paper's ViT experiments.
  advection_batch : 1-D advection PDE u_t + c u_x = 0 pairs (u(t), u(t+dt))
                    with random smooth initial conditions — the paper's
                    PDEBench UNet task, 1-D.
  frames / patches: stub frontend embeddings for audio/vlm families.
"""
from __future__ import annotations

import numpy as np


_PERM_CACHE = {}


def lm_batch(rng: np.random.Generator, batch: int, seq: int, vocab: int,
             noise_p: float = 0.1):
    """Markov token stream: next = perm[prev] with prob 1-noise_p, else
    uniform — a bigram-learnable signal (optimal CE ~= H(noise) ~ 1.1 nats
    at the default noise), seeded per vocab so every batch shares the map."""
    if vocab not in _PERM_CACHE:
        _PERM_CACHE[vocab] = np.random.default_rng(vocab).permutation(vocab)
    perm = _PERM_CACHE[vocab]
    toks = np.empty((batch, seq + 1), np.int32)
    toks[:, 0] = rng.integers(0, vocab, batch)
    flip = rng.random((batch, seq)) < noise_p
    rand = rng.integers(0, vocab, (batch, seq))
    for t in range(seq):
        toks[:, t + 1] = np.where(flip[:, t], rand[:, t], perm[toks[:, t]])
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def mnist_like(rng: np.random.Generator, batch: int, n_classes: int = 10):
    """Class-conditional blobs: class c -> bright blob at a c-specific spot."""
    labels = rng.integers(0, n_classes, batch).astype(np.int32)
    xs = np.zeros((batch, 28, 28, 1), np.float32)
    yy, xx = np.mgrid[0:28, 0:28].astype(np.float32)
    for i, c in enumerate(labels):
        cy, cx = 6 + 3 * (c % 4), 6 + 3 * (c // 4)
        blob = np.exp(-((yy - cy) ** 2 + (xx - cx) ** 2) / 12.0)
        xs[i, :, :, 0] = blob + 0.1 * rng.standard_normal((28, 28))
    return {"images": xs, "labels": labels}


def advection_batch(rng: np.random.Generator, batch: int, L: int = 128,
                    c: float = 1.0, dt: float = 4.0):
    """Periodic 1-D advection: u(x, t+dt) = u(x - c*dt, t) (exact shift)."""
    x = np.arange(L, dtype=np.float32)
    u0 = np.zeros((batch, L), np.float32)
    for k in range(1, 4):
        amp = rng.standard_normal((batch, 1)).astype(np.float32) / k
        phase = rng.uniform(0, 2 * np.pi, (batch, 1)).astype(np.float32)
        u0 += amp * np.sin(2 * np.pi * k * x[None] / L + phase)
    shift = int(round(c * dt)) % L
    u1 = np.roll(u0, shift, axis=1)
    return {"u0": u0[..., None], "u1": u1[..., None]}


def frontend_stub(rng: np.random.Generator, batch: int, length: int, d: int):
    """Precomputed frame/patch embeddings (audio conv stub / SigLIP stub)."""
    return rng.standard_normal((batch, length, d)).astype(np.float32) * 0.1


def make_batch(cfg, rng: np.random.Generator, batch: int, seq: int):
    """Family-dispatching batch builder for a ModelConfig."""
    if cfg.family == "vision":
        return mnist_like(rng, batch, cfg.vocab_size)
    if cfg.family == "pde":
        return advection_batch(rng, batch, cfg.max_seq_len)
    out = lm_batch(rng, batch, seq, cfg.vocab_size)
    if cfg.family == "audio":
        out["frames"] = frontend_stub(rng, batch, cfg.n_frames, cfg.d_model)
    if cfg.family == "vlm":
        out["patches"] = frontend_stub(rng, batch, cfg.n_prefix_tokens, cfg.d_model)
    return out
