"""Seeded, batched host data loader.

Deterministic per (seed, epoch): supports the paper's "40 batches per
epoch" protocol. Batches are plain dicts of numpy arrays; jit'd steps
consume them directly (device transfer happens at trace/dispatch).
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

from .synthetic import make_batch


class DataLoader:
    def __init__(self, cfg, *, batch_size: int, seq_len: int = 128,
                 num_batches: int = 40, seed: int = 0):
        self.cfg = cfg
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.num_batches = num_batches
        self.seed = seed
        self._epoch = 0

    def __len__(self) -> int:
        return self.num_batches

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng((self.seed, self._epoch))
        self._epoch += 1
        for _ in range(self.num_batches):
            yield make_batch(self.cfg, rng, self.batch_size, self.seq_len)
