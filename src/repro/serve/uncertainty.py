"""Predictive heads computed *inside* the fused BMA program.

Serving a Bayesian model is only cheap if uncertainty is free: every head
here is a pure jnp function of the stacked member outputs (leading
particle axis), so the engine traces them into the same XLA program as
the forward pass — the heads ride along on device and cost zero extra
host transfers (the per-member logits never leave the device unless the
caller explicitly asks for them).

For classification (member outputs = logits, (P, B, C)):

  mean            BMA predictive distribution p̄ = (1/P) Σ_i softmax(z_i)
  entropy         H[p̄]                       — total predictive uncertainty
  expected_entropy(1/P) Σ_i H[p_i]           — aleatoric part
  mutual_info     H[p̄] − (1/P) Σ_i H[p_i]    — epistemic part (BALD)
  variance        mean_c Var_i[p_i(c)]       — particle disagreement

For regression (member outputs = point predictions, (P, B, ...)):

  mean / variance  moments of the particle mixture (epistemic)
  entropy          Gaussian-approx ½ log(2πe σ²), averaged over outputs
  mutual_info      = variance averaged over outputs (all spread between
                   members is epistemic when members are deltas)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

EPS = 1e-12

KINDS = ("classify", "regress")


def bma_mean_probs(member_logits):
    """(P, B, C) logits -> (B, C) BMA predictive probabilities."""
    probs = jax.nn.softmax(member_logits.astype(jnp.float32), axis=-1)
    return jnp.mean(probs, axis=0)


def predictive_entropy(mean_probs):
    """H[p̄] in nats, (B, C) -> (B,)."""
    return -jnp.sum(mean_probs * jnp.log(mean_probs + EPS), axis=-1)


def expected_entropy(member_logits):
    """(1/P) Σ_i H[p_i] in nats, (P, B, C) -> (B,)."""
    logp = jax.nn.log_softmax(member_logits.astype(jnp.float32), axis=-1)
    ent = -jnp.sum(jnp.exp(logp) * logp, axis=-1)      # (P, B)
    return jnp.mean(ent, axis=0)


def mutual_information(member_logits):
    """BALD score H[p̄] − E_i H[p_i], (P, B, C) -> (B,). Clamped >= 0
    (float cancellation can push the difference slightly negative)."""
    mi = (predictive_entropy(bma_mean_probs(member_logits))
          - expected_entropy(member_logits))
    return jnp.maximum(mi, 0.0)


def particle_variance(member_probs):
    """Mean over classes of the across-particle variance, (P,B,C) -> (B,)."""
    return jnp.mean(jnp.var(member_probs, axis=0), axis=-1)


def _mask_stats(x, mask):
    """Masked mean/variance over the leading particle axis: dead rows are
    where-zeroed (NaN in a padding slot can never leak) and the divisor
    is the live count. With an all-ones mask this reduces to
    ``jnp.mean``/``jnp.var`` up to float associativity. The mean is
    ``functional.masked_mean``; the variance reuses the same masking."""
    from ..core.functional import expand_mask, masked_mean
    m = expand_mask(mask, x.ndim) > 0
    live = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
    mean = masked_mean(x, mask)
    var = jnp.sum(jnp.where(m, (x - mean) ** 2, 0.0), axis=0) / live
    return mean, var


def predictive_heads(member_outputs, kind: str = "classify", mask=None):
    """All heads from one stacked member-output tensor (leading axis P).

    Returns a dict of arrays with leading batch axis B — the engine's
    fused program returns exactly this dict, so adding a head here makes
    it free at serve time for every model.

    ``mask`` is the store's (P,) active mask for capacity-padded member
    axes (DESIGN.md §9): every particle reduction becomes mask-weighted
    over live slots, exactly matching the dense heads computed on just
    those members.
    """
    if kind not in KINDS:
        raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
    x = member_outputs.astype(jnp.float32)
    if kind == "classify":
        probs = jax.nn.softmax(x, axis=-1)              # (P, B, C)
        logp = jax.nn.log_softmax(x, axis=-1)
        member_ent = -jnp.sum(probs * logp, axis=-1)    # (P, B)
        if mask is None:
            # composed from the standalone heads above (XLA CSEs the
            # shared softmax across them — one fused program either way)
            mean = bma_mean_probs(x)                    # (B, C)
            var = jnp.mean(jnp.var(probs, axis=0), axis=-1)
            exp_ent = jnp.mean(member_ent, axis=0)
        else:
            mean, pvar = _mask_stats(probs, mask)
            var = jnp.mean(pvar, axis=-1)
            exp_ent, _ = _mask_stats(member_ent, mask)
        ent = predictive_entropy(mean)
        return {
            "mean": mean,
            "variance": var,
            "entropy": ent,
            "expected_entropy": exp_ent,
            "mutual_info": jnp.maximum(ent - exp_ent, 0.0),
        }
    # regression: members are point predictions (P, B, ...)
    if mask is None:
        mean = jnp.mean(x, axis=0)
        var = jnp.var(x, axis=0)
    else:
        mean, var = _mask_stats(x, mask)
    reduce_axes = tuple(range(1, mean.ndim))            # all but batch
    var_scalar = (jnp.mean(var, axis=reduce_axes) if reduce_axes
                  else var)
    ent = 0.5 * jnp.log(2.0 * math.pi * math.e * (var_scalar + EPS))
    return {
        "mean": mean,
        "variance": var,
        "entropy": ent,
        "expected_entropy": jnp.zeros_like(ent),
        "mutual_info": var_scalar,
    }
