"""Calibration metrics for served predictive distributions.

jnp implementations (jit-friendly, usable on device right after a fused
BMA forward) plus independent NumPy references (``*_ref``) that the test
suite checks them against — the references are written in the most
literal textbook form, no shared code with the jnp path.

  nll     mean −log p̄(y)                 (proper score; nats)
  brier   mean ‖p̄ − onehot(y)‖²          (quadratic proper score)
  ece     Σ_b (n_b/N) |acc(b) − conf(b)|  (expected calibration error,
          equal-width confidence bins over (0, 1])
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

EPS = 1e-12


# ---------------------------------------------------------------------------
# jnp implementations (the serving path)
# ---------------------------------------------------------------------------

def nll(probs, labels):
    """probs: (B, C) predictive distribution; labels: (B,) i32 -> scalar."""
    p_gold = jnp.take_along_axis(probs, labels[:, None], axis=-1)[:, 0]
    return -jnp.mean(jnp.log(p_gold + EPS))


def brier(probs, labels):
    onehot = jnp.zeros_like(probs).at[jnp.arange(probs.shape[0]),
                                      labels].set(1.0)
    return jnp.mean(jnp.sum((probs - onehot) ** 2, axis=-1))


def accuracy(probs, labels):
    return jnp.mean((jnp.argmax(probs, axis=-1) == labels)
                    .astype(jnp.float32))


def ece(probs, labels, n_bins: int = 15):
    """Equal-width confidence binning over (0, 1]; empty bins contribute 0."""
    conf = jnp.max(probs, axis=-1)
    correct = (jnp.argmax(probs, axis=-1) == labels).astype(jnp.float32)
    # bin i covers (i/n, (i+1)/n]; conf==0 is clamped into bin 0
    idx = jnp.clip(jnp.ceil(conf * n_bins).astype(jnp.int32) - 1, 0,
                   n_bins - 1)
    n_b = jnp.zeros(n_bins).at[idx].add(1.0)
    conf_b = jnp.zeros(n_bins).at[idx].add(conf)
    acc_b = jnp.zeros(n_bins).at[idx].add(correct)
    gap = jnp.abs(acc_b - conf_b)          # n_b * |acc(b) - conf(b)|
    return jnp.sum(jnp.where(n_b > 0, gap, 0.0)) / probs.shape[0]


def calibration_report(probs, labels, n_bins: int = 15) -> Dict[str, float]:
    """Host-side summary of every metric (one device sync)."""
    return {"nll": float(nll(probs, labels)),
            "brier": float(brier(probs, labels)),
            "ece": float(ece(probs, labels, n_bins)),
            "accuracy": float(accuracy(probs, labels))}


# ---------------------------------------------------------------------------
# NumPy references (tests only — deliberately independent, literal forms)
# ---------------------------------------------------------------------------

def nll_ref(probs, labels) -> float:
    probs, labels = np.asarray(probs), np.asarray(labels)
    return float(np.mean([-np.log(probs[i, labels[i]] + EPS)
                          for i in range(len(labels))]))


def brier_ref(probs, labels) -> float:
    probs, labels = np.asarray(probs), np.asarray(labels)
    total = 0.0
    for i in range(len(labels)):
        onehot = np.zeros(probs.shape[1])
        onehot[labels[i]] = 1.0
        total += float(np.sum((probs[i] - onehot) ** 2))
    return total / len(labels)


def accuracy_ref(probs, labels) -> float:
    probs, labels = np.asarray(probs), np.asarray(labels)
    return float(np.mean(np.argmax(probs, axis=-1) == labels))


def ece_ref(probs, labels, n_bins: int = 15) -> float:
    probs, labels = np.asarray(probs), np.asarray(labels)
    conf = np.max(probs, axis=-1)
    pred = np.argmax(probs, axis=-1)
    total = 0.0
    for b in range(n_bins):
        lo, hi = b / n_bins, (b + 1) / n_bins
        sel = (conf > lo) & (conf <= hi) if b else (conf <= hi)
        if not np.any(sel):
            continue
        acc_b = float(np.mean(pred[sel] == labels[sel]))
        conf_b = float(np.mean(conf[sel]))
        total += (np.sum(sel) / len(labels)) * abs(acc_b - conf_b)
    return total
