"""Paged KV-cache pool over the particle axis (DESIGN.md §10).

The device side is ONE ParticleStore scratch key (``"kv_pages"``): every
attention layer's K/V state lives in a fixed pool of fixed-size pages —
per particle, ``(num_pages, page_size, KVH, hd)`` per layer — stacked
over the store's capacity axis exactly like params, so pages shard over
the particle axis, ride ``p_clone`` row copies, and flow through the
fused decode program by checkout/commit with donation (in-place on
device, one canonical tree, zero per-step copies).

The host side is this module: a free-page allocator plus per-sequence
block tables. Crucially the split means page allocation and reclaim are
*pure host metadata* — handing page 17 from a finished sequence to a new
one changes two Python lists and the next step's block-table upload, not
the device tree, so churn in WHO owns a page never bumps the store
version, let alone its generation. The single generation bump is pool
*creation* (a new store key), which callers do before warmup.

Block-table conventions (shared with kernels/paged_decode_attention.py):
rows are ``(max_seq_pages,)`` i32, logical page i of a sequence at entry
i, unused entries 0 (in bounds for the gather, masked by seq_len).
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def create_kv_pages(store, make_pages: Callable, *, key: str = "kv_pages",
                    dtype=None):
    """Install the stacked page pool as a store scratch key.

    ``make_pages()`` builds ONE particle's page pytree (e.g.
    ``models.api.paged_cache_init``); the stacked tree broadcasts it over
    the store capacity. ``dtype=`` overrides the storage dtype of every
    floating page leaf — the precision ladder's ``kv_dtype`` lands here
    when the factory itself is not dtype-parameterized. This is the one
    generation bump of the paged path (a new key in the schema) — do it
    before serving warmup."""
    shapes = jax.eval_shape(make_pages)
    if dtype is not None:
        dtype = jnp.dtype(dtype)
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating)
                else s.dtype), shapes)
    stacked = jax.tree.map(
        lambda s: jnp.zeros((store.capacity,) + s.shape, s.dtype), shapes)
    store.commit(key, stacked)
    return key


class PagePool:
    """Host-side free-page allocator + per-sequence page lists.

    Thread-safe; never touches the device. ``alloc`` returns None rather
    than blocking when the pool is dry — the scheduler turns that into
    admission backpressure (hold new sequences) or preemption (return a
    running sequence's pages and requeue it)."""

    def __init__(self, num_pages: int, page_size: int, max_seq_pages: int):
        if num_pages < 1 or page_size < 1 or max_seq_pages < 1:
            raise ValueError("num_pages, page_size, max_seq_pages must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_seq_pages = max_seq_pages
        self._lock = threading.Lock()
        self._free: deque = deque(range(num_pages))
        self._owned: Dict[int, List[int]] = {}      # seq id -> page ids
        self.stats = {"allocs": 0, "releases": 0, "alloc_failures": 0,
                      "peak_used": 0}

    # -- allocation ----------------------------------------------------------
    def alloc(self, seq_id: int, n: int = 1) -> Optional[List[int]]:
        """Append ``n`` pages to ``seq_id``'s list; None if the pool has
        fewer than ``n`` free pages or the sequence would exceed
        ``max_seq_pages`` (callers treat both as backpressure)."""
        with self._lock:
            owned = self._owned.setdefault(seq_id, [])
            if len(self._free) < n or len(owned) + n > self.max_seq_pages:
                self.stats["alloc_failures"] += 1
                return None
            got = [self._free.popleft() for _ in range(n)]
            owned.extend(got)
            self.stats["allocs"] += n
            used = self.num_pages - len(self._free)
            if used > self.stats["peak_used"]:
                self.stats["peak_used"] = used
            return got

    def release(self, seq_id: int) -> int:
        """Return all of ``seq_id``'s pages to the free list (retire or
        preempt). Host metadata only — page contents are dead the moment
        no block table references them."""
        with self._lock:
            pages = self._owned.pop(seq_id, [])
            self._free.extend(pages)
            self.stats["releases"] += len(pages)
            return len(pages)

    def release_tail(self, seq_id: int, n_tokens: int) -> int:
        """Truncate ``seq_id``'s page list to exactly what ``n_tokens``
        tokens need (``ceil(n_tokens / page_size)`` pages), returning the
        tail pages to the free list — the rejected-draft rollback of
        speculative decode. Page-granular: a partially-used last page is
        kept; stale slots past the tail are never read (position-masked
        by the kernels). Raises ``KeyError`` for a sequence the pool does
        not own (e.g. a double release) and ``ValueError`` on a negative
        token count. Returns the number of pages freed."""
        if n_tokens < 0:
            raise ValueError(f"n_tokens must be >= 0, got {n_tokens}")
        with self._lock:
            if seq_id not in self._owned:
                raise KeyError(f"pool does not own sequence {seq_id}")
            owned = self._owned[seq_id]
            keep = -(-n_tokens // self.page_size)
            if keep >= len(owned):
                return 0
            tail = owned[keep:]
            del owned[keep:]
            self._free.extend(tail)
            self.stats["releases"] += len(tail)
            return len(tail)

    def pages_of(self, seq_id: int) -> List[int]:
        with self._lock:
            return list(self._owned.get(seq_id, ()))

    # -- block tables --------------------------------------------------------
    def fill_block_row(self, seq_id: int, out: np.ndarray):
        """Write ``seq_id``'s block table into ``out`` (max_seq_pages,)
        in place — unused tail stays 0 (in bounds, masked by seq_len)."""
        with self._lock:
            pages = self._owned.get(seq_id, ())
            out[:len(pages)] = pages
            out[len(pages):] = 0

    # -- introspection -------------------------------------------------------
    @property
    def free_pages(self) -> int:
        with self._lock:
            return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - self.free_pages

    def snapshot_stats(self) -> Dict[str, int]:
        with self._lock:
            used = self.num_pages - len(self._free)
            return dict(self.stats, num_pages=self.num_pages,
                        page_size=self.page_size, free_pages=len(self._free),
                        used_pages=used)
