"""Thin sync + async serving front-end: ``serve(pd).predict(x)``.

Wires a PredictiveEngine (fused BMA + uncertainty heads, engine.py) to a
MicroBatcher (request coalescing, batcher.py) behind a two-call API:

    svc = serve(infer_or_pd)              # after bayes_infer(...)
    pred = svc.predict(x)                 # one example -> Prediction
    fut  = svc.predict_async(x)           # PFuture-backed handle
    preds = fut.result()                  # Prediction
    heads = svc.predict_batch(batch)      # caller-batched fast path

``stats()`` mirrors the executor's introspection: request/batch counts,
flush-trigger mix, queue depth, padding occupancy, compile-cache state,
and p50/p95/p99 request latency from the batcher's ring buffer.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from ..core.messages import PFuture
from ..core.store import Placement
from .batcher import MicroBatcher
from .engine import PredictiveEngine


@dataclass
class Prediction:
    """One request's posterior-predictive summary (all heads computed
    inside the fused program — reading them costs no extra device work)."""
    mean: Any                       # BMA mean (probs / regression mean)
    variance: Any                   # particle disagreement
    entropy: Any                    # total predictive uncertainty
    mutual_info: Any                # epistemic part (BALD)
    expected_entropy: Any = None    # aleatoric part
    extras: Dict[str, Any] = field(default_factory=dict)

    @staticmethod
    def from_heads(heads: Dict[str, Any]) -> "Prediction":
        known = ("mean", "variance", "entropy", "mutual_info",
                 "expected_entropy")
        return Prediction(**{k: heads[k] for k in known if k in heads},
                          extras={k: v for k, v in heads.items()
                                  if k not in known})


class PendingPrediction:
    """Async handle: wraps the batcher's PFuture; ``result()`` blocks."""

    __slots__ = ("_future",)

    def __init__(self, future: PFuture):
        self._future = future

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: Optional[float] = None) -> Prediction:
        return Prediction.from_heads(self._future.wait(timeout))


def percentile(xs: List[float], q: float) -> float:
    """Linear-interpolated percentile (np.percentile; q in [0, 100]);
    0.0 on empty input. bench_serve reports these same values."""
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs), q))


class PredictiveService:
    def __init__(self, engine: PredictiveEngine, *, max_batch: int = 32,
                 max_wait_ms: float = 2.0, max_queue: int = 512):
        self.engine = engine
        self.batcher = MicroBatcher(engine.predict, max_batch=max_batch,
                                    max_wait_ms=max_wait_ms,
                                    max_queue=max_queue)
        self._t_start = time.monotonic()

    # -- request paths -------------------------------------------------------
    def predict_async(self, x) -> PendingPrediction:
        """Enqueue ONE example (no leading batch axis) for the next fused
        micro-batch; returns immediately."""
        return PendingPrediction(self.batcher.submit(x))

    def predict(self, x, timeout: Optional[float] = None) -> Prediction:
        """Synchronous single-example predict (enqueue + wait)."""
        return self.predict_async(x).result(timeout)

    def predict_batch(self, batch, members: bool = False):
        """Caller-assembled batch straight through the engine (no
        coalescing latency); returns the raw heads dict (leading axis B)."""
        return self.engine.predict(batch, members=members)

    # -- introspection -------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        lat = self.batcher.latencies_s()
        bstats = self.batcher.snapshot_stats()
        elapsed = max(time.monotonic() - self._t_start, 1e-9)
        return {
            **bstats,
            "engine": self.engine.snapshot_stats(),
            "latency_p50_ms": percentile(lat, 50) * 1e3,
            "latency_p95_ms": percentile(lat, 95) * 1e3,
            "latency_p99_ms": percentile(lat, 99) * 1e3,
            "requests_per_s": bstats["requests"] / elapsed,
        }

    # -- lifecycle -----------------------------------------------------------
    def close(self):
        self.batcher.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def _resolve_pd(obj):
    """Accept an Infer, a PushDistribution, or anything with .push_dist."""
    pd = getattr(obj, "push_dist", obj)
    if not hasattr(pd, "store") or not hasattr(pd, "module"):
        raise TypeError(f"cannot serve {type(obj).__name__}: "
                        "expected an Infer or PushDistribution")
    return pd


def serve(obj, *, kind: str = "classify", max_batch: int = 32,
          max_wait_ms: float = 2.0, max_queue: int = 512,
          params: Any = None, forward=None,
          placement: Optional[Placement] = None) -> PredictiveService:
    """Turn a trained PushDistribution (or its Infer) into a batched
    posterior-predictive service.

    Default: serve the store's live ``"params"`` (deep-ensemble BMA over
    the current particles). ``params=`` overrides with a static stacked
    tree — the MultiSWAG serve-time sampling handoff
    (``MultiSWAG.posterior_predictive``) uses this.
    """
    pd = _resolve_pd(obj)
    fwd = forward if forward is not None else pd.module.forward
    if params is not None:
        engine = PredictiveEngine(fwd, params=params, kind=kind,
                                  placement=placement or pd.placement)
    else:
        engine = PredictiveEngine(fwd, store=pd.store, kind=kind,
                                  placement=placement)
    return PredictiveService(engine, max_batch=max_batch,
                             max_wait_ms=max_wait_ms, max_queue=max_queue)
