"""Thin sync + async serving front-end: ``serve(pd).predict(x)``.

Wires a PredictiveEngine (fused BMA + uncertainty heads, engine.py) to a
MicroBatcher (request coalescing, batcher.py) behind a two-call API:

    svc = serve(infer_or_pd)              # after bayes_infer(...)
    pred = svc.predict(x)                 # one example -> Prediction
    fut  = svc.predict_async(x)           # PFuture-backed handle
    preds = fut.result()                  # Prediction
    heads = svc.predict_batch(batch)      # caller-batched fast path

``stats()`` mirrors the executor's introspection: request/batch counts,
flush-trigger mix, queue depth, padding occupancy, compile-cache state,
and p50/p95/p99 request latency from the batcher's ring buffer.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from ..core.messages import PFuture
from ..core.store import Placement
from ..obs import clock, metrics
from .batcher import DecodeScheduler, Generation, MicroBatcher
from .engine import PagedDecodeEngine, PredictiveEngine
from .paging import PagePool, create_kv_pages


@dataclass
class Prediction:
    """One request's posterior-predictive summary (all heads computed
    inside the fused program — reading them costs no extra device work)."""
    mean: Any                       # BMA mean (probs / regression mean)
    variance: Any                   # particle disagreement
    entropy: Any                    # total predictive uncertainty
    mutual_info: Any                # epistemic part (BALD)
    expected_entropy: Any = None    # aleatoric part
    extras: Dict[str, Any] = field(default_factory=dict)

    @staticmethod
    def from_heads(heads: Dict[str, Any]) -> "Prediction":
        known = ("mean", "variance", "entropy", "mutual_info",
                 "expected_entropy")
        return Prediction(**{k: heads[k] for k in known if k in heads},
                          extras={k: v for k, v in heads.items()
                                  if k not in known})


class PendingPrediction:
    """Async handle: wraps the batcher's PFuture; ``result()`` blocks."""

    __slots__ = ("_future",)

    def __init__(self, future: PFuture):
        self._future = future

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: Optional[float] = None) -> Prediction:
        return Prediction.from_heads(self._future.wait(timeout))


def percentile(xs: List[float], q: float) -> float:
    """Linear-interpolated percentile (q in [0, 100]); 0.0 on empty
    input. Delegates to the one implementation in ``repro.obs.metrics``
    (bench_serve reports these same values)."""
    return metrics.percentile(xs, q)


class PredictiveService:
    def __init__(self, engine: PredictiveEngine, *, max_batch: int = 32,
                 max_wait_ms: float = 2.0, max_queue: int = 512):
        self.engine = engine
        self.batcher = MicroBatcher(engine.predict, max_batch=max_batch,
                                    max_wait_ms=max_wait_ms,
                                    max_queue=max_queue)
        self._t_start = clock.now()

    # -- request paths -------------------------------------------------------
    def predict_async(self, x) -> PendingPrediction:
        """Enqueue ONE example (no leading batch axis) for the next fused
        micro-batch; returns immediately."""
        return PendingPrediction(self.batcher.submit(x))

    def predict(self, x, timeout: Optional[float] = None) -> Prediction:
        """Synchronous single-example predict (enqueue + wait)."""
        return self.predict_async(x).result(timeout)

    def predict_batch(self, batch, members: bool = False):
        """Caller-assembled batch straight through the engine (no
        coalescing latency); returns the raw heads dict (leading axis B)."""
        return self.engine.predict(batch, members=members)

    # -- introspection -------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        lat = self.batcher.latency          # obs.metrics Histogram view
        bstats = self.batcher.snapshot_stats()
        elapsed = max(clock.now() - self._t_start, 1e-9)
        return {
            **bstats,
            "engine": self.engine.snapshot_stats(),
            "latency_p50_ms": lat.percentile(50) * 1e3,
            "latency_p95_ms": lat.percentile(95) * 1e3,
            "latency_p99_ms": lat.percentile(99) * 1e3,
            "requests_per_s": bstats["requests"] / elapsed,
        }

    # -- lifecycle -----------------------------------------------------------
    def close(self):
        self.batcher.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def _resolve_pd(obj):
    """Accept an Infer, a PushDistribution, or anything with .push_dist."""
    pd = getattr(obj, "push_dist", obj)
    if not hasattr(pd, "store") or not hasattr(pd, "module"):
        raise TypeError(f"cannot serve {type(obj).__name__}: "
                        "expected an Infer or PushDistribution")
    return pd


def serve(obj, *, kind: str = "classify", max_batch: int = 32,
          max_wait_ms: float = 2.0, max_queue: int = 512,
          params: Any = None, forward=None,
          placement: Optional[Placement] = None,
          precision: Any = None) -> PredictiveService:
    """Turn a trained PushDistribution (or its Infer) into a batched
    posterior-predictive service.

    Default: serve the store's live ``"params"`` (deep-ensemble BMA over
    the current particles). ``params=`` overrides with a static stacked
    tree — the MultiSWAG serve-time sampling handoff
    (``MultiSWAG.posterior_predictive``) uses this. ``precision=``
    overrides the serve-side policy; store-backed engines default to the
    store's own (so a PD built with ``precision="mixed"`` serves bf16
    without any flag here).
    """
    pd = _resolve_pd(obj)
    fwd = forward if forward is not None else pd.module.forward
    if precision is None and params is not None:
        # static trees detach from the store; inherit the PD's policy
        precision = getattr(pd, "precision", None)
    if params is not None:
        engine = PredictiveEngine(fwd, params=params, kind=kind,
                                  placement=placement or pd.placement,
                                  precision=precision)
    else:
        engine = PredictiveEngine(fwd, store=pd.store, kind=kind,
                                  placement=placement, precision=precision)
    return PredictiveService(engine, max_batch=max_batch,
                             max_wait_ms=max_wait_ms, max_queue=max_queue)


# ---------------------------------------------------------------------------
# continuous-batching LM decode front-end
# ---------------------------------------------------------------------------

class PendingGeneration:
    """Async handle: resolves to a ``Generation`` when the sequence
    retires (eos or max_new)."""

    __slots__ = ("_future",)

    def __init__(self, future: PFuture):
        self._future = future

    def done(self) -> bool:
        return self._future.done()

    def result(self, timeout: Optional[float] = None) -> Generation:
        return self._future.wait(timeout)


class DecodeService:
    """``serve_decode(pd, ...)`` handle: streaming generate over the
    continuous-batching DecodeScheduler. Submit any time — sequences join
    the running decode grid at the next step, not at the next flush."""

    def __init__(self, scheduler: DecodeScheduler):
        self.scheduler = scheduler
        self.engine = scheduler.engine
        self.pool = scheduler.pool
        self._t_start = clock.now()

    # -- request paths -------------------------------------------------------
    def generate_async(self, prompt, *, max_new: int,
                       eos_id: Optional[int] = None) -> PendingGeneration:
        """Enqueue one prompt (token id list/array); returns immediately."""
        return PendingGeneration(
            self.scheduler.submit(prompt, max_new=max_new, eos_id=eos_id))

    def generate(self, prompt, *, max_new: int, eos_id: Optional[int] = None,
                 timeout: Optional[float] = None) -> Generation:
        """Synchronous single-sequence generate (enqueue + wait)."""
        return self.generate_async(prompt, max_new=max_new,
                                   eos_id=eos_id).result(timeout)

    # -- introspection -------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        lat = self.scheduler.latency        # obs.metrics Histogram view
        sstats = self.scheduler.snapshot_stats()
        elapsed = max(clock.now() - self._t_start, 1e-9)
        return {
            **sstats,
            "engine": self.engine.snapshot_stats(),
            "latency_p50_ms": lat.percentile(50) * 1e3,
            "latency_p95_ms": lat.percentile(95) * 1e3,
            "latency_p99_ms": lat.percentile(99) * 1e3,
            "tokens_per_s": sstats["generated_tokens"] / elapsed,
        }

    # -- lifecycle -----------------------------------------------------------
    def close(self):
        self.scheduler.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def serve_decode(obj, cfg=None, *, num_pages: int, page_size: int,
                 max_active: int = 8, max_seq_pages: Optional[int] = None,
                 eos_id: Optional[int] = None, max_queue: int = 256,
                 decode_kernel: bool = True, cache_dtype=None,
                 placement: Optional[Placement] = None,
                 pages_key: str = "kv_pages", warmup: bool = True,
                 warmup_buckets=(), precision: Any = None,
                 speculative: Any = None) -> DecodeService:
    """Turn a PushDistribution holding an LM ensemble into a
    continuous-batching posterior-predictive decode service.

    Installs the paged KV pool as a store scratch key (the ONE generation
    bump of the paged path — done here, before warmup), builds the host
    PagePool + block tables, and wires the PagedDecodeEngine's two
    fixed-shape programs behind a DecodeScheduler:

        svc = serve_decode(pd, cfg, num_pages=256, page_size=16)
        gen = svc.generate(prompt_ids, max_new=32)       # Generation
        h   = svc.generate_async(ids, max_new=8)         # streaming handle

    ``max_seq_pages`` bounds one sequence's block table (defaults to the
    config's max_seq_len, clamped to the pool). ``warmup=True`` compiles
    the decode-step program up front (plus one prefill program per pow2
    bucket in ``warmup_buckets``) so steady-state serving never cold
    compiles. ``pd.stats()`` grows a ``decode`` section while the service
    lives.

    ``speculative=`` turns on speculative BMA decoding (DESIGN.md §14):
    ``True`` for defaults, an int for that many drafted tokens per step,
    or a ``serve.SpecConfig`` for the full policy (adaptive K, int8
    draft). Greedy output stays token-exact; only throughput changes.
    """
    from ..models import api as models_api
    from .speculative import (SpecDecodeEngine, SpeculativeDecodeScheduler,
                              resolve_spec_config)

    spec_cfg = resolve_spec_config(speculative)
    pd = _resolve_pd(obj)
    cfg = cfg if cfg is not None else getattr(pd.module, "cfg", None)
    if cfg is None:
        raise ValueError("pass cfg= (the module carries none)")
    if cache_dtype is None:
        # the precision ladder's kv_dtype names the page storage dtype;
        # explicit cache_dtype= still wins, None falls through to the
        # model config's cache default
        prec = getattr(pd, "precision", None)
        cache_dtype = prec.kv_dtype if prec is not None else None
    if max_seq_pages is None:
        max_seq_pages = -(-cfg.max_seq_len // page_size)
    n_pmax = min(max_seq_pages, num_pages)

    def decode_fn(params, pages, tokens, block_tables, seq_lens):
        return models_api.decode_step_paged(params, tokens, pages,
                                            block_tables, seq_lens, cfg,
                                            decode_kernel=decode_kernel)

    def prefill_fn(params, pages, tokens, block_table_row, n_tokens):
        return models_api.prefill_paged(params, tokens, pages,
                                        block_table_row, n_tokens, cfg)

    create_kv_pages(
        pd.store,
        lambda: models_api.paged_cache_init(cfg, num_pages=num_pages,
                                            page_size=page_size,
                                            dtype=cache_dtype),
        key=pages_key)
    pool = PagePool(num_pages, page_size, max_seq_pages=n_pmax)
    if spec_cfg is not None:
        def verify_fn(params, pages, tokens, block_tables, seq_lens,
                      win_lens):
            return models_api.decode_window_paged(
                params, tokens, pages, block_tables, seq_lens, win_lens,
                cfg, decode_kernel=decode_kernel)

        engine = SpecDecodeEngine(decode_fn, prefill_fn, verify_fn,
                                  spec_cfg=spec_cfg, store=pd.store,
                                  n_pmax=n_pmax, pages_key=pages_key,
                                  placement=placement, precision=precision)
        scheduler = SpeculativeDecodeScheduler(engine, pool,
                                               max_active=max_active,
                                               eos_id=eos_id,
                                               max_queue=max_queue)
    else:
        engine = PagedDecodeEngine(decode_fn, prefill_fn, store=pd.store,
                                   n_pmax=n_pmax, pages_key=pages_key,
                                   placement=placement, precision=precision)
        scheduler = DecodeScheduler(engine, pool, max_active=max_active,
                                    eos_id=eos_id, max_queue=max_queue)
    if warmup:
        scheduler.warmup(warmup_buckets)
    return DecodeService(scheduler)
