"""Request scheduling: micro-batching (stateless) + continuous batching
(LM decode).

``MicroBatcher`` is the serving analogue of the paper's dispatch problem:
one fused BMA forward per *request* wastes the accelerator exactly the
way thread-per-dispatch wasted the host (PR 1), so requests are
coalesced into padded batches and flushed by whichever trigger fires
first:

  size      the pending set reached ``max_batch`` — flush immediately;
  deadline  the oldest pending request has waited ``max_wait_ms`` —
            flush whatever has accumulated (bounded tail latency);
  close     the batcher is shutting down — flush the remainder.

The flush loop is not a new thread model: it runs as work items on a
``core.executor.Executor`` (the PR 1 persistent worker loop — one device
queue, FIFO mailbox, no thread churn), scheduled only while requests are
pending, so an idle batcher costs one parked worker. The queue is
bounded: ``submit`` blocks once ``max_queue`` requests are pending
(backpressure, mirroring the executor's ``max_pending`` admission).

Each request is ONE example (no leading batch axis); the batcher fills a
*preallocated per-bucket host staging buffer* (reused across flushes —
no np.stack scratch allocation per flush, exactly one H2D transfer per
flush), calls ``predict_fn`` once, and resolves each request's PFuture
with its row of the result tree. Per-request latency (enqueue ->
resolve) lands in a ring buffer for the service's p50/p95/p99.

``DecodeScheduler`` is the continuous-batching upgrade for stateful LM
decode (DESIGN.md §10): where MicroBatcher admits and retires work per
*flush*, the decode loop admits and retires sequences per *decode step*.
A fixed grid of ``max_active`` rows runs one fused paged-decode program
per step; finished rows free their KV pages and are refilled from the
waiting queue in the SAME loop iteration, so divergent sequence lengths
never leave rows idling at the barrier the way flush-batched decode
does. Admission backpressure is keyed on free pages in the PagePool;
when a running row cannot get its next page, the youngest row is
preempted (pages reclaimed, sequence requeued — greedy sampling makes
the re-run deterministic).
"""
from __future__ import annotations

import threading
import time
import weakref
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..core.executor import Executor
from ..core.messages import PFuture
from ..obs import clock, metrics
from ..obs import trace as _trace
from .engine import bucket_size

_LAT_RING = 4096


class _Request:
    __slots__ = ("x", "future", "t_enqueue")

    def __init__(self, x, future: PFuture):
        self.x = x
        self.future = future
        self.t_enqueue = clock.now()


class _Staging:
    """Preallocated host staging buffers, one set per (bucket, tree
    structure, leaf shapes/dtypes).

    ``np.stack`` per flush allocates a fresh scratch batch every time; a
    steady-state server flushing the same bucket thousands of times per
    second spends that allocation (and the page faults behind it) on
    every flush. Instead each distinct batch signature gets ONE
    preallocated buffer per leaf, request rows are copied in place, and
    pad rows repeat the last real row (same semantics as
    ``runtime.bucketing.pad_rows``). The buffer feeds exactly one H2D
    transfer when the fused program consumes it.

    Reuse across flushes is safe because the flush is synchronous: the
    single pump thread device_gets the result before the next flush can
    touch the buffer, and JAX copies host numpy input at dispatch."""

    def __init__(self):
        self._bufs: Dict[Any, List[np.ndarray]] = {}
        self.builds = 0
        self.reuses = 0

    def batch(self, rows: List[Any], bucket: int):
        leaves, treedef = jax.tree.flatten(rows[0])
        sig = (bucket, treedef,
               tuple((np.shape(l), np.result_type(l)) for l in leaves))
        bufs = self._bufs.get(sig)
        if bufs is None:
            bufs = [np.empty((bucket,) + np.shape(l), np.result_type(l))
                    for l in leaves]
            self._bufs[sig] = bufs
            self.builds += 1
        else:
            self.reuses += 1
        for i, row in enumerate(rows):
            for buf, leaf in zip(bufs, treedef.flatten_up_to(row)):
                buf[i] = leaf
        m = len(rows)
        if m < bucket:
            for buf in bufs:
                buf[m:] = buf[m - 1]        # pad = repeat last real row
        return jax.tree.unflatten(treedef, bufs)


class MicroBatcher:
    def __init__(self, predict_fn: Callable, *, max_batch: int = 32,
                 max_wait_ms: float = 2.0, max_queue: int = 512,
                 executor: Optional[Executor] = None):
        if max_batch < 1 or max_queue < 1:
            raise ValueError("max_batch and max_queue must be >= 1")
        self.predict_fn = predict_fn
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.max_queue = max_queue
        self._owns_executor = executor is None
        # one "device" worker is the flush loop; no pool threads needed
        self._exec = executor or Executor(num_devices=1, pool_size=0,
                                          max_pending=2 * max_queue)
        self._pump_pid = id(self)  # any stable key works as a mailbox id
        self._exec.add_particle(self._pump_pid, 0)
        self._cond = threading.Condition()
        self._pending: deque = deque()
        self._pump_scheduled = False
        self._closed = False
        # per-request enqueue->resolve latency: an obs.metrics Histogram
        # (same ring bound as the old hand-rolled deque, one percentile
        # implementation for the whole serving layer)
        self.latency = metrics.Histogram("serve_request_latency_seconds",
                                         ring=_LAT_RING)
        self._staging = _Staging()
        self.stats: Dict[str, Any] = {
            "requests": 0, "batches": 0, "rows": 0, "padded_rows": 0,
            "size_flushes": 0, "deadline_flushes": 0, "close_flushes": 0,
            "max_queue_depth": 0, "errors": 0, "h2d_transfers": 0,
        }

    # -- submission ----------------------------------------------------------
    def submit(self, x) -> PFuture:
        """Enqueue one example; resolves to its row of the prediction.
        Blocks while ``max_queue`` requests are already pending."""
        fut = PFuture()
        req = _Request(x, fut)
        with self._cond:
            if self._closed:
                raise RuntimeError("batcher is closed")
            while len(self._pending) >= self.max_queue:
                self._cond.wait(0.05)
                if self._closed:
                    raise RuntimeError("batcher is closed")
            self._pending.append(req)
            self.stats["requests"] += 1
            depth = len(self._pending)
            if depth > self.stats["max_queue_depth"]:
                self.stats["max_queue_depth"] = depth
            if not self._pump_scheduled:
                self._pump_scheduled = True
                self._exec.submit(self._pump_pid, self._pump)
            self._cond.notify_all()
        return fut

    # -- flush loop (runs on the executor worker) ----------------------------
    def _pump(self):
        while True:
            with self._cond:
                if not self._pending:
                    self._pump_scheduled = False
                    return
                deadline = self._pending[0].t_enqueue + self.max_wait
                while (not self._closed
                       and len(self._pending) < self.max_batch):
                    rem = deadline - clock.now()
                    if rem <= 0:
                        break
                    self._cond.wait(rem)
                if len(self._pending) >= self.max_batch:
                    reason = "size"
                elif self._closed:
                    reason = "close"
                else:
                    reason = "deadline"
                reqs = [self._pending.popleft()
                        for _ in range(min(len(self._pending),
                                           self.max_batch))]
                self._cond.notify_all()   # wake backpressured submitters
            if not reqs:    # close() raced the deadline wait and drained
                continue    # the queue itself; nothing to flush
            self._flush(reqs, reason)

    def _flush(self, reqs: List[_Request], reason: str):
        self.stats[f"{reason}_flushes"] += 1
        self.stats["batches"] += 1
        self.stats["rows"] += len(reqs)
        try:
            bucket = bucket_size(len(reqs))
            with _trace.span("serve.flush", "serve", reason=reason,
                             rows=len(reqs), bucket=bucket):
                padded = self._staging.batch([r.x for r in reqs], bucket)
                self.stats["padded_rows"] += bucket - len(reqs)
                # the staging buffer is the ONE host->device transfer of
                # the flush (asserted: h2d_transfers == batches)
                self.stats["h2d_transfers"] += 1
                # one host transfer for the whole result tree; per-request
                # rows are then free numpy slices (n lazy device slices
                # would each pay a dispatch)
                result = jax.device_get(self.predict_fn(padded))
            now = clock.now()
            for i, r in enumerate(reqs):
                self.latency.observe(now - r.t_enqueue)
                r.future._resolve(
                    jax.tree.map(lambda a, i=i: a[i], result))
        except BaseException as e:       # surfaced on each request's wait()
            self.stats["errors"] += 1
            for r in reqs:
                r.future._reject(e)

    # -- introspection -------------------------------------------------------
    def queue_depth(self) -> int:
        with self._cond:
            return len(self._pending)

    def latencies_s(self) -> List[float]:
        return self.latency.values()

    def snapshot_stats(self) -> Dict[str, Any]:
        with self._cond:
            out = dict(self.stats)
            out["queue_depth"] = len(self._pending)
            out["staging_builds"] = self._staging.builds
            out["staging_reuses"] = self._staging.reuses
        n = max(1, out["rows"] + out["padded_rows"])
        out["occupancy"] = out["rows"] / n
        return out

    # -- lifecycle -----------------------------------------------------------
    def close(self, timeout: float = 30.0):
        """Flush whatever is pending, then stop accepting requests."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._owns_executor:
            self._exec.shutdown(drain=True, timeout=timeout)
        # reject anything the pump never got to (executor already down)
        with self._cond:
            leftovers = list(self._pending)
            self._pending.clear()
        for r in leftovers:
            r.future._reject(RuntimeError("batcher closed"))

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# ---------------------------------------------------------------------------
# Continuous batching for paged LM decode
# ---------------------------------------------------------------------------

@dataclass
class Generation:
    """Resolved result of one decode request (PFuture payload)."""
    prompt: List[int]
    tokens: List[int]                       # generated ids (incl. eos if hit)
    logprobs: List[float] = field(default_factory=list)   # BMA log p(token)
    entropy: List[float] = field(default_factory=list)    # total predictive
    mutual_info: List[float] = field(default_factory=list)  # epistemic part
    finish_reason: str = "length"           # "eos" | "length"
    preemptions: int = 0

    @property
    def text_ids(self) -> List[int]:
        return self.prompt + self.tokens


class _Seq:
    """One in-flight sequence. ``all_tokens`` (prompt + generated) is the
    whole decode state: the KV pool holds entries for ``all_tokens[:-1]``
    and the next step feeds ``all_tokens[-1]`` at position
    ``len(all_tokens) - 1`` — so preemption can drop every page and later
    rebuild them with one prefill over ``all_tokens[:-1]`` (greedy
    sampling makes the replay exact)."""
    __slots__ = ("sid", "prompt", "max_new", "eos_id", "future", "generated",
                 "logprobs", "entropy", "mutual_info", "t_enqueue",
                 "preemptions")

    def __init__(self, sid: int, prompt: List[int], max_new: int,
                 eos_id: Optional[int], future: PFuture):
        self.sid = sid
        self.prompt = prompt
        self.max_new = max_new
        self.eos_id = eos_id
        self.future = future
        self.generated: List[int] = []
        self.logprobs: List[float] = []
        self.entropy: List[float] = []
        self.mutual_info: List[float] = []
        self.t_enqueue = clock.now()
        self.preemptions = 0

    @property
    def all_tokens(self) -> List[int]:
        return self.prompt + self.generated

    def finish_reason(self) -> Optional[str]:
        if self.generated and self.eos_id is not None \
                and self.generated[-1] == self.eos_id:
            return "eos"
        if len(self.generated) >= self.max_new:
            return "length"
        return None

    def result(self) -> Generation:
        return Generation(prompt=self.prompt, tokens=self.generated,
                          logprobs=self.logprobs, entropy=self.entropy,
                          mutual_info=self.mutual_info,
                          finish_reason=self.finish_reason() or "length",
                          preemptions=self.preemptions)


# store -> scheduler, consumed by runtime/backends.stats() (lazy import
# there avoids a runtime<->serve cycle). Weak values: a dropped scheduler
# must not be pinned by its stats hook.
_DECODE_SCHEDULERS: "weakref.WeakValueDictionary" = \
    weakref.WeakValueDictionary()


def decode_stats_for(store) -> Optional[Dict[str, Any]]:
    """Decode-section stats for ``pd.stats()`` — None when no
    DecodeScheduler serves this store."""
    sched = _DECODE_SCHEDULERS.get(id(store))
    return None if sched is None else sched.snapshot_stats()


class DecodeScheduler:
    """Continuous batching over a ``PagedDecodeEngine`` + ``PagePool``.

    A fixed grid of ``max_active`` rows runs ONE fused decode program per
    step (fixed shapes: the packed ``(max_active, 2 + n_pmax)`` i32 step
    input is a preallocated staging buffer refilled in place — one H2D
    per step, one D2H for the small heads). Scheduling is per step, not
    per flush:

      admit    while rows are free and the pool can cover a waiting
               prompt's pages, pop it, prefill its prompt (one program
               per pow2 prompt bucket), seat it in a row;
      grow     a running row crossing a page boundary allocates its next
               page; if the pool is dry the YOUNGEST row is preempted —
               pages released, sequence requeued at the front, replayed
               later via prefill over its accumulated tokens (greedy
               sampling ⇒ deterministic);
      decode   one fused step for all seated rows (inactive rows ride
               along masked with seq_len -1);
      retire   rows hitting eos/max_new release pages and resolve their
               PFuture in the SAME iteration the row frees up.

    ``step_lock`` serializes steps against external store churn: hold it
    around ``pd.p_clone``/``p_kill`` so lifecycle ops never interleave
    with the engine's pages checkout/commit window (the lifecycle test
    drives exactly this). The loop itself runs as work items on a
    PR 1 Executor, like MicroBatcher — idle scheduler, parked worker.
    """

    def __init__(self, engine, pool, *, max_active: int = 8,
                 eos_id: Optional[int] = None, max_queue: int = 256,
                 executor: Optional[Executor] = None):
        if max_active < 1 or max_queue < 1:
            raise ValueError("max_active and max_queue must be >= 1")
        self.engine = engine
        self.pool = pool
        self.max_active = max_active
        self.eos_id = eos_id
        self.max_queue = max_queue
        self.n_pmax = engine.n_pmax
        if pool.max_seq_pages != self.n_pmax:
            raise ValueError(
                f"pool.max_seq_pages ({pool.max_seq_pages}) must equal the "
                f"engine's block-table width n_pmax ({self.n_pmax})")
        self._owns_executor = executor is None
        self._exec = executor or Executor(num_devices=1, pool_size=0,
                                          max_pending=2 * max_queue)
        self._pump_pid = id(self)
        self._exec.add_particle(self._pump_pid, 0)
        self._cond = threading.Condition()
        self._waiting: deque = deque()
        self._rows: List[Optional[_Seq]] = [None] * max_active
        self._pump_scheduled = False
        self._closed = False
        self._next_sid = 0
        # submit->retire latency per sequence (obs.metrics Histogram,
        # same ring bound as the old hand-rolled deque)
        self.latency = metrics.Histogram("decode_request_latency_seconds",
                                         ring=_LAT_RING)
        # fixed-shape decode staging buffer: [:, 0] token, [:, 1] seq_len,
        # [:, 2:] block table — refilled in place, ONE H2D per step
        self._packed = np.zeros((max_active, 2 + self.n_pmax), np.int32)
        self._prefill_bufs: Dict[int, np.ndarray] = {}
        self.step_lock = threading.Lock()
        self.stats: Dict[str, Any] = {
            "submitted": 0, "admitted": 0, "retired": 0, "preempted": 0,
            "steps": 0, "prefills": 0, "generated_tokens": 0,
            "active_row_steps": 0, "admission_blocked": 0,
            "h2d_transfers": 0, "errors": 0, "max_queue_depth": 0,
        }
        _DECODE_SCHEDULERS[id(engine.store)] = self

    # -- submission ----------------------------------------------------------
    def submit(self, prompt, *, max_new: int,
               eos_id: Optional[int] = None) -> PFuture:
        """Enqueue one prompt (list/array of token ids); resolves to a
        ``Generation``. Blocks while ``max_queue`` sequences wait."""
        prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
        if not prompt:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        ps = self.pool.page_size
        worst = len(prompt) + max_new
        limit = min(self.n_pmax, self.pool.num_pages) * ps
        if worst > limit:
            raise ValueError(
                f"prompt + max_new = {worst} tokens needs "
                f"{-(-worst // ps)} pages; pool/block-table limit is "
                f"{limit // ps} pages ({limit} tokens)")
        fut = PFuture()
        seq = _Seq(self._next_sid, prompt, max_new,
                   self.eos_id if eos_id is None else eos_id, fut)
        with self._cond:
            if self._closed:
                raise RuntimeError("scheduler is closed")
            self._next_sid += 1
            seq.sid = self._next_sid - 1
            while len(self._waiting) >= self.max_queue:
                self._cond.wait(0.05)
                if self._closed:
                    raise RuntimeError("scheduler is closed")
            self._waiting.append(seq)
            self.stats["submitted"] += 1
            depth = len(self._waiting)
            if depth > self.stats["max_queue_depth"]:
                self.stats["max_queue_depth"] = depth
            if not self._pump_scheduled:
                self._pump_scheduled = True
                self._exec.submit(self._pump_pid, self._pump)
            self._cond.notify_all()
        return fut

    def warmup(self, prompt_buckets=()):
        """Compile the decode-step program (all rows masked inactive) and
        one prefill program per requested pow2 prompt bucket — with zero
        tokens, so no page is written and the pool is untouched. After
        this, steady-state serving is ZERO cold compiles."""
        with self.step_lock:
            self._packed[:, 0] = 0
            self._packed[:, 1] = -1
            self._packed[:, 2:] = 0
            jax.block_until_ready(
                jax.tree.leaves(self.engine.decode_step(self._packed)))
            for b in prompt_buckets:
                buf = self._prefill_buf(bucket_size(int(b)))
                buf[:] = 0          # n_tokens = 0: every write masked out
                jax.block_until_ready(
                    jax.tree.leaves(self.engine.prefill(buf)))

    # -- scheduler loop (runs on the executor worker) ------------------------
    def _pump(self):
        while True:
            with self._cond:
                if not self._waiting and not any(self._rows):
                    self._pump_scheduled = False
                    self._cond.notify_all()
                    return
            try:
                with self.step_lock:
                    self._step()
            except BaseException as e:
                # engine-level failure (not per-sequence): fail every
                # in-flight sequence rather than spin on a broken program
                self.stats["errors"] += 1
                self._fail_all(e)

    def _step(self):
        self._admit()
        active = [(i, s) for i, s in enumerate(self._rows) if s is not None]
        if not active:
            if self._waiting:     # admission blocked on a dry pool with
                time.sleep(1e-3)  # nothing decoding: don't spin hot
            return
        # grow: every seated row needs the page holding the position its
        # next token writes; dry pool preempts youngest-first
        for i, seq in active:
            if self._rows[i] is seq:    # not preempted by an earlier row
                self._ensure_page(seq)
        active = [(i, s) for i, s in enumerate(self._rows) if s is not None]
        if not active:
            return
        with _trace.span("decode.step", "decode", rows=len(active)):
            self._packed[:, 0] = 0
            self._packed[:, 1] = -1
            self._packed[:, 2:] = 0
            for i, seq in active:
                self._packed[i, 0] = seq.all_tokens[-1]
                self._packed[i, 1] = len(seq.all_tokens) - 1
                self.pool.fill_block_row(seq.sid, self._packed[i, 2:])
            self.stats["h2d_transfers"] += 1
            heads = jax.device_get(self.engine.decode_step(self._packed))
        self.stats["steps"] += 1
        self.stats["active_row_steps"] += len(active)
        for i, seq in active:
            self._append_token(seq, heads, i)
            self._maybe_retire(i, seq)

    def _admit(self):
        ps = self.pool.page_size
        while True:
            with self._cond:
                if not self._waiting:
                    return
                try:
                    row = self._rows.index(None)
                except ValueError:
                    return
                seq = self._waiting[0]
                # initial admission prefills the prompt; re-admission
                # after preemption replays everything but the pending
                # token (whose KV slot the next decode step writes)
                n_pf = len(seq.prompt) if not seq.generated \
                    else len(seq.all_tokens) - 1
                if self.pool.alloc(seq.sid, -(-n_pf // ps)) is None:
                    self.stats["admission_blocked"] += 1
                    return                    # backpressure: pool is dry
                self._waiting.popleft()
                self._cond.notify_all()       # wake backpressured submitters
            try:
                heads = self._prefill(seq, n_pf)
            except BaseException as e:
                self.stats["errors"] += 1
                self.pool.release(seq.sid)
                seq.future._reject(e)
                continue
            self._rows[row] = seq
            self.stats["admitted"] += 1
            _trace.instant("decode.admit", "decode", sid=seq.sid,
                           replay=bool(seq.generated))
            if not seq.generated:
                # the prefill head IS the first generated token; replays
                # discard it (greedy ⇒ it equals the token already held)
                self._append_token(seq, heads, 0)
                self._maybe_retire(row, seq)

    def _prefill_buf(self, bucket: int) -> np.ndarray:
        buf = self._prefill_bufs.get(bucket)
        if buf is None:
            buf = np.zeros((bucket + self.n_pmax + 1,), np.int32)
            self._prefill_bufs[bucket] = buf
        return buf

    def _prefill(self, seq: _Seq, n_pf: int):
        tokens = seq.all_tokens[:n_pf]
        bucket = bucket_size(n_pf)
        with _trace.span("decode.prefill", "decode", sid=seq.sid,
                         tokens=n_pf, bucket=bucket):
            buf = self._prefill_buf(bucket)
            buf[:n_pf] = tokens
            buf[n_pf:bucket] = 0
            self.pool.fill_block_row(seq.sid,
                                     buf[bucket:bucket + self.n_pmax])
            buf[-1] = n_pf
            self.stats["prefills"] += 1
            self.stats["h2d_transfers"] += 1
            return jax.device_get(self.engine.prefill(buf))

    def _ensure_page(self, seq: _Seq, extra: int = 0) -> bool:
        """Make the page for ``seq``'s next write position resident —
        plus ``extra`` further positions (a speculative draft window
        writes through position ``len - 1 + extra``); preempt youngest
        rows while the pool is dry. False iff ``seq`` itself got
        preempted (it WAS the youngest)."""
        need = (len(seq.all_tokens) - 1 + extra) // self.pool.page_size + 1
        while len(self.pool.pages_of(seq.sid)) < need:
            if self.pool.alloc(seq.sid,
                               need - len(self.pool.pages_of(seq.sid))):
                _trace.instant("decode.grow", "decode", sid=seq.sid,
                               pages=need)
                return True
            victim = max((s for s in self._rows if s is not None),
                         key=lambda s: s.sid)
            self._preempt(victim)
            if victim is seq:
                return False
        return True

    def _preempt(self, seq: _Seq):
        row = self._rows.index(seq)
        self._rows[row] = None
        self.pool.release(seq.sid)
        seq.preemptions += 1
        self.stats["preempted"] += 1
        _trace.instant("decode.preempt", "decode", sid=seq.sid,
                       tokens=len(seq.all_tokens))
        with self._cond:
            self._waiting.appendleft(seq)

    def _append_token(self, seq: _Seq, heads, i: int):
        seq.generated.append(int(heads["token"][i]))
        seq.logprobs.append(float(heads["logprob"][i]))
        seq.entropy.append(float(heads["entropy"][i]))
        seq.mutual_info.append(float(heads["mutual_info"][i]))
        self.stats["generated_tokens"] += 1

    def _maybe_retire(self, row: int, seq: _Seq):
        if seq.finish_reason() is None:
            return
        self._rows[row] = None
        self.pool.release(seq.sid)
        self.stats["retired"] += 1
        self.latency.observe(clock.now() - seq.t_enqueue)
        _trace.instant("decode.retire", "decode", sid=seq.sid,
                       tokens=len(seq.generated),
                       reason=seq.finish_reason() or "length")
        seq.future._resolve(seq.result())

    def _fail_all(self, e: BaseException):
        for i, seq in enumerate(self._rows):
            if seq is not None:
                self._rows[i] = None
                self.pool.release(seq.sid)
                seq.future._reject(e)
        with self._cond:
            leftovers = list(self._waiting)
            self._waiting.clear()
            self._cond.notify_all()
        for seq in leftovers:
            self.pool.release(seq.sid)
            seq.future._reject(e)

    # -- introspection -------------------------------------------------------
    def queue_depth(self) -> int:
        with self._cond:
            return len(self._waiting)

    def active_count(self) -> int:
        return sum(1 for s in self._rows if s is not None)

    def latencies_s(self) -> List[float]:
        return self.latency.values()

    def snapshot_stats(self) -> Dict[str, Any]:
        with self._cond:
            out = dict(self.stats)
            out["queue_depth"] = len(self._waiting)
        out["active_seqs"] = self.active_count()
        out["max_active"] = self.max_active
        steps = max(1, out["steps"])
        out["row_occupancy"] = out["active_row_steps"] / (
            steps * self.max_active)
        out["pool"] = self.pool.snapshot_stats()
        # KV storage gauges (precision ladder, DESIGN.md §13): page dtype
        # histogram + resident bytes, so bf16/fp8 pools are visible in
        # pd.stats()["decode"] next to the page-churn counters
        out["kv_pages"] = self.engine.kv_page_info()
        # speculative-decode gauges (DESIGN.md §14): None on the plain
        # scheduler; SpeculativeDecodeScheduler fills the section in
        out["speculative"] = None
        return out

    # -- lifecycle -----------------------------------------------------------
    def close(self, timeout: float = 60.0):
        """Stop accepting, drain everything in flight (waiting sequences
        included — rows free up as retirements land), shut the pump."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._owns_executor:
            self._exec.shutdown(drain=True, timeout=timeout)
        with self._cond:
            leftovers = list(self._waiting)
            self._waiting.clear()
        for seq in leftovers:    # pump never got to them (executor down)
            seq.future._reject(RuntimeError("scheduler closed"))

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
