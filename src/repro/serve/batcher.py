"""Adaptive micro-batcher: coalesce concurrent requests into fused calls.

The serving analogue of the paper's dispatch problem: one fused BMA
forward per *request* wastes the accelerator exactly the way
thread-per-dispatch wasted the host (PR 1), so requests are coalesced
into padded batches and flushed by whichever trigger fires first:

  size      the pending set reached ``max_batch`` — flush immediately;
  deadline  the oldest pending request has waited ``max_wait_ms`` —
            flush whatever has accumulated (bounded tail latency);
  close     the batcher is shutting down — flush the remainder.

The flush loop is not a new thread model: it runs as work items on a
``core.executor.Executor`` (the PR 1 persistent worker loop — one device
queue, FIFO mailbox, no thread churn), scheduled only while requests are
pending, so an idle batcher costs one parked worker. The queue is
bounded: ``submit`` blocks once ``max_queue`` requests are pending
(backpressure, mirroring the executor's ``max_pending`` admission).

Each request is ONE example (no leading batch axis); the batcher stacks
rows, pads to the engine's power-of-two bucket, calls ``predict_fn``
once, and resolves each request's PFuture with its row of the result
tree. Per-request latency (enqueue -> resolve) lands in a ring buffer
for the service's p50/p95/p99.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..core.executor import Executor
from ..core.messages import PFuture
from .engine import bucket_size, pad_rows

_LAT_RING = 4096


class _Request:
    __slots__ = ("x", "future", "t_enqueue")

    def __init__(self, x, future: PFuture):
        self.x = x
        self.future = future
        self.t_enqueue = time.monotonic()


def stack_requests(rows: List[Any]):
    """Stack per-request example trees into one batch (leading axis m).

    Stacks on the HOST (np.stack): one device transfer for the whole
    batch when the fused program consumes it, instead of one dispatch
    per request row (32 tiny jnp ops cost ~100ms on CPU; one np.stack
    costs microseconds)."""
    return jax.tree.map(lambda *xs: np.stack(xs), *rows)


class MicroBatcher:
    def __init__(self, predict_fn: Callable, *, max_batch: int = 32,
                 max_wait_ms: float = 2.0, max_queue: int = 512,
                 executor: Optional[Executor] = None):
        if max_batch < 1 or max_queue < 1:
            raise ValueError("max_batch and max_queue must be >= 1")
        self.predict_fn = predict_fn
        self.max_batch = max_batch
        self.max_wait = max_wait_ms / 1e3
        self.max_queue = max_queue
        self._owns_executor = executor is None
        # one "device" worker is the flush loop; no pool threads needed
        self._exec = executor or Executor(num_devices=1, pool_size=0,
                                          max_pending=2 * max_queue)
        self._pump_pid = id(self)  # any stable key works as a mailbox id
        self._exec.add_particle(self._pump_pid, 0)
        self._cond = threading.Condition()
        self._pending: deque = deque()
        self._pump_scheduled = False
        self._closed = False
        self._latencies: deque = deque(maxlen=_LAT_RING)
        self.stats: Dict[str, Any] = {
            "requests": 0, "batches": 0, "rows": 0, "padded_rows": 0,
            "size_flushes": 0, "deadline_flushes": 0, "close_flushes": 0,
            "max_queue_depth": 0, "errors": 0,
        }

    # -- submission ----------------------------------------------------------
    def submit(self, x) -> PFuture:
        """Enqueue one example; resolves to its row of the prediction.
        Blocks while ``max_queue`` requests are already pending."""
        fut = PFuture()
        req = _Request(x, fut)
        with self._cond:
            if self._closed:
                raise RuntimeError("batcher is closed")
            while len(self._pending) >= self.max_queue:
                self._cond.wait(0.05)
                if self._closed:
                    raise RuntimeError("batcher is closed")
            self._pending.append(req)
            self.stats["requests"] += 1
            depth = len(self._pending)
            if depth > self.stats["max_queue_depth"]:
                self.stats["max_queue_depth"] = depth
            if not self._pump_scheduled:
                self._pump_scheduled = True
                self._exec.submit(self._pump_pid, self._pump)
            self._cond.notify_all()
        return fut

    # -- flush loop (runs on the executor worker) ----------------------------
    def _pump(self):
        while True:
            with self._cond:
                if not self._pending:
                    self._pump_scheduled = False
                    return
                deadline = self._pending[0].t_enqueue + self.max_wait
                while (not self._closed
                       and len(self._pending) < self.max_batch):
                    rem = deadline - time.monotonic()
                    if rem <= 0:
                        break
                    self._cond.wait(rem)
                if len(self._pending) >= self.max_batch:
                    reason = "size"
                elif self._closed:
                    reason = "close"
                else:
                    reason = "deadline"
                reqs = [self._pending.popleft()
                        for _ in range(min(len(self._pending),
                                           self.max_batch))]
                self._cond.notify_all()   # wake backpressured submitters
            if not reqs:    # close() raced the deadline wait and drained
                continue    # the queue itself; nothing to flush
            self._flush(reqs, reason)

    def _flush(self, reqs: List[_Request], reason: str):
        self.stats[f"{reason}_flushes"] += 1
        self.stats["batches"] += 1
        self.stats["rows"] += len(reqs)
        try:
            batch = stack_requests([r.x for r in reqs])
            padded = pad_rows(batch, bucket_size(len(reqs)))
            self.stats["padded_rows"] += (bucket_size(len(reqs)) - len(reqs))
            # one host transfer for the whole result tree; per-request
            # rows are then free numpy slices (n lazy device slices
            # would each pay a dispatch)
            result = jax.device_get(self.predict_fn(padded))
            now = time.monotonic()
            for i, r in enumerate(reqs):
                self._latencies.append(now - r.t_enqueue)
                r.future._resolve(
                    jax.tree.map(lambda a, i=i: a[i], result))
        except BaseException as e:       # surfaced on each request's wait()
            self.stats["errors"] += 1
            for r in reqs:
                r.future._reject(e)

    # -- introspection -------------------------------------------------------
    def queue_depth(self) -> int:
        with self._cond:
            return len(self._pending)

    def latencies_s(self) -> List[float]:
        with self._cond:
            return list(self._latencies)

    def snapshot_stats(self) -> Dict[str, Any]:
        with self._cond:
            out = dict(self.stats)
            out["queue_depth"] = len(self._pending)
        n = max(1, out["rows"] + out["padded_rows"])
        out["occupancy"] = out["rows"] / n
        return out

    # -- lifecycle -----------------------------------------------------------
    def close(self, timeout: float = 30.0):
        """Flush whatever is pending, then stop accepting requests."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self._owns_executor:
            self._exec.shutdown(drain=True, timeout=timeout)
        # reject anything the pump never got to (executor already down)
        with self._cond:
            leftovers = list(self._pending)
            self._pending.clear()
        for r in leftovers:
            r.future._reject(RuntimeError("batcher closed"))

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
