"""PredictiveEngine: one fused Bayesian-model-averaging forward per request.

The serving counterpart of ``core/functional``'s training builders: the
engine compiles a single XLA program that runs *all* particles over the
store's stacked axis (``vmap(forward, spmd_axis_name=...)``), computes
every uncertainty head (serve/uncertainty.py) inside that program, and
reduces over the particle axis **on device** — on a mesh placement the
member outputs are sharding-constrained particle-sharded for the local
math, then constrained replicated, which GSPMD lowers to an all-gather
over the particle axis (the same transition pattern as SVGD's kernel
matrix, DESIGN.md §6) rather than a host round trip.

Stacked params are read straight from the ParticleStore (``stacked()``
returns the canonical placed tree; the store's version counter lets the
engine cache the reference between commits), so serving never unshards,
restacks, or re-places particle state — the sharded subprocess test
asserts those stats stay flat across requests.

Compile caching is bucketed per model size: request batches are padded up
to the next power of two, so an engine serving mixed batch sizes holds
one compiled program per (particle count, bucket, abstract batch shape)
instead of one per distinct size.

Two program shapes:

  predict(batch)        stateless BMA forward     forward(params, batch)
  step(state, batch)    stateful serving (LM decode: per-particle KV
                        caches ride the stacked axis and never leave the
                        device)                    forward(params, state,
                                                   batch) -> (out, state)
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.store import ParticleStore, Placement
from . import uncertainty


def bucket_size(m: int) -> int:
    """Next power of two >= m (compile-cache bucketing)."""
    if m < 1:
        raise ValueError("batch must be non-empty")
    b = 1
    while b < m:
        b <<= 1
    return b


def pad_rows(tree, target: int):
    """Pad every leaf's leading axis to `target` by repeating the last
    row (repeat, not zeros: padding must stay in-distribution for
    normalization layers; padded rows are sliced off after the call)."""
    m = jax.tree.leaves(tree)[0].shape[0]
    if m == target:
        return tree
    return jax.tree.map(
        lambda x: jnp.concatenate(
            [x, jnp.broadcast_to(x[-1:], (target - m,) + x.shape[1:])]),
        tree)


def _abstract(tree) -> Tuple:
    """Hashable (structure, shapes, dtypes) key for the compile cache."""
    leaves, treedef = jax.tree.flatten(tree)
    return (str(treedef),
            tuple((tuple(x.shape), jnp.result_type(x).name) for x in leaves))


def _leading(tree) -> int:
    return jax.tree.leaves(tree)[0].shape[0]


class PredictiveEngine:
    """Compiled posterior-predictive service core over a ParticleStore.

    Parameters
    ----------
    forward:    stateless mode — ``forward(params_row, batch) -> outputs``
                (leading batch axis); stateful mode (``stateful=True``) —
                ``forward(params_row, state_row, batch) -> (out, state)``.
    store/key:  where stacked params live (the PD's store). Mutually
                exclusive with ``params`` — a static stacked tree (e.g.
                serve-time SWAG samples, bdl/swag.py).
    placement:  mesh plan; defaults to the store's. Decides the particle
                axis sharding + the on-device BMA all-gather.
    kind:       "classify" (member outputs are logits) or "regress".
    """

    def __init__(self, forward: Callable, *,
                 store: Optional[ParticleStore] = None, key: str = "params",
                 params: Any = None, placement: Optional[Placement] = None,
                 kind: str = "classify", stateful: bool = False):
        if (store is None) == (params is None):
            raise ValueError("pass exactly one of store= or params=")
        if kind not in uncertainty.KINDS:
            raise ValueError(f"kind must be one of {uncertainty.KINDS}")
        self.forward = forward
        self.store = store
        self.key = key
        self.kind = kind
        self.stateful = stateful
        if placement is None:
            placement = store.placement if store is not None else Placement()
        self.placement = placement
        self._static_params = params
        if params is not None and placement.mesh is not None:
            self._static_params = jax.device_put(
                params, placement.shardings(params))
        self._params_version: Any = None
        self._params_cache: Any = None
        self._programs: Dict[Tuple, Callable] = {}
        self.stats = {"calls": 0, "compiles": 0, "bucket_hits": 0,
                      "param_refreshes": 0}

    # -- stacked params ------------------------------------------------------
    def stacked_params(self):
        """The canonical stacked params, cached between store commits
        (store.version) so the hot path is one dict lookup — and never a
        reshard: the store hands back the already-placed tree."""
        if self._static_params is not None:
            return self._static_params
        v = self.store.version(self.key)
        if v != self._params_version:
            self._params_cache = self.store.stacked(self.key)
            self._params_version = v
            self.stats["param_refreshes"] += 1
        return self._params_cache

    @property
    def num_particles(self) -> int:
        return _leading(self.stacked_params())

    # -- program construction ------------------------------------------------
    def _bma_reduce_heads(self, outs, n: int):
        """Heads from stacked member outputs, with the particle-axis
        reduction expressed as sharding-constraint transitions."""
        pl = self.placement
        if pl.mesh is not None:
            row_sh = pl.vector(n)                  # P(particle_axis), rest ∅
            outs = jax.lax.with_sharding_constraint(outs, row_sh)
            # the BMA all-to-all as one on-device collective: every device
            # gets all members' outputs, then reduces locally (replicated)
            outs = jax.lax.with_sharding_constraint(outs, pl.replicated(outs))
        return uncertainty.predictive_heads(outs, self.kind), outs

    def _compile(self, cache_key, build: Callable):
        prog = self._programs.get(cache_key)
        if prog is None:
            prog = build()
            self._programs[cache_key] = prog
            self.stats["compiles"] += 1
        else:
            self.stats["bucket_hits"] += 1
        return prog

    def _build_predict(self, stacked, batch, members: bool):
        pl = self.placement
        n = _leading(stacked)
        spmd = pl.spmd_axis(n)

        def fused(stacked_params, b):
            outs = jax.vmap(self.forward, in_axes=(0, None),
                            spmd_axis_name=spmd)(stacked_params, b)
            heads, outs_rep = self._bma_reduce_heads(outs, n)
            return (heads, outs_rep) if members else heads

        if pl.mesh is None:
            return jax.jit(fused)
        return jax.jit(fused,
                       in_shardings=(pl.shardings(stacked),
                                     pl.replicated(batch)),
                       out_shardings=pl.replicated(0))

    def _build_step(self, stacked, state, batch):
        pl = self.placement
        n = _leading(stacked)
        spmd = pl.spmd_axis(n)

        def fused(stacked_params, st, b):
            outs, new_st = jax.vmap(self.forward, in_axes=(0, 0, None),
                                    spmd_axis_name=spmd)(stacked_params, st, b)
            heads, _ = self._bma_reduce_heads(outs, n)
            return heads, new_st

        if pl.mesh is None:
            return jax.jit(fused)
        st_sh = jax.tree.map(lambda _: pl.vector(n), state)
        return jax.jit(
            fused,
            in_shardings=(pl.shardings(stacked), st_sh,
                          pl.replicated(batch)),
            out_shardings=(pl.replicated(0), st_sh))

    # -- serving entry points ------------------------------------------------
    def predict(self, batch, members: bool = False):
        """Fused BMA forward over a request batch (leading axis B).

        Pads B up to the bucket, runs the cached program for that bucket,
        slices the heads back to B. ``members=True`` additionally returns
        the raw stacked member outputs (P, B, ...)."""
        if self.stateful:
            raise RuntimeError("stateful engine: use step(state, batch)")
        self.stats["calls"] += 1
        stacked = self.stacked_params()
        m = _leading(batch)
        padded = pad_rows(batch, bucket_size(m))
        cache_key = (_leading(stacked), members, _abstract(padded))
        prog = self._compile(
            cache_key, lambda: self._build_predict(stacked, padded, members))
        out = prog(stacked, padded)
        heads, outs = out if members else (out, None)
        heads = jax.tree.map(lambda a: a[:m], heads)
        if members:
            return heads, jax.tree.map(lambda a: a[:, :m], outs)
        return heads

    def step(self, state, batch):
        """One stateful serving step (LM decode): per-particle state (KV
        caches, leading axis P) stays stacked and on device across steps.
        Returns (heads, new_state)."""
        if not self.stateful:
            raise RuntimeError("stateless engine: use predict(batch)")
        self.stats["calls"] += 1
        stacked = self.stacked_params()
        cache_key = (_leading(stacked), "step", _abstract(state),
                     _abstract(batch))
        prog = self._compile(
            cache_key, lambda: self._build_step(stacked, state, batch))
        return prog(stacked, state, batch)

    def init_state(self, make_state: Callable):
        """Build stacked per-particle serving state: ``make_state(row)``
        maps one particle's params to its state (e.g. prefill -> caches);
        vmapped over the stacked axis so state is born sharded."""
        stacked = self.stacked_params()
        n = _leading(stacked)
        return jax.jit(jax.vmap(make_state,
                                spmd_axis_name=self.placement.spmd_axis(n))
                       )(stacked)

    def snapshot_stats(self) -> Dict[str, int]:
        return dict(self.stats, programs=len(self._programs))
