"""PredictiveEngine: one fused Bayesian-model-averaging forward per request.

The serving head of the runtime layer (DESIGN.md §8): the engine builds
*ProgramSpecs* — a single XLA program that runs *all* particles over the
store's stacked axis (``vmap(forward, spmd_axis_name=...)``), computes
every uncertainty head (serve/uncertainty.py) inside that program, and
reduces over the particle axis **on device** — on a mesh placement the
member outputs are sharding-constrained particle-sharded for the local
math, then constrained replicated, which GSPMD lowers to an all-gather
over the particle axis (the same transition pattern as SVGD's kernel
matrix, DESIGN.md §6) rather than a host round trip.

Stacked params are read straight from the ParticleStore (``stacked()``
returns the canonical placed tree; the store's version counter lets the
engine cache the reference between commits), so serving never unshards,
restacks, or re-places particle state — the sharded subprocess test
asserts those stats stay flat across requests.

Compilation and caching are the shared ProgramCache's job: request
batches are padded up to the next power of two (runtime.bucketing), the
cache key carries (spec, placement, store generation, bucketed shapes) —
so an engine serving mixed batch sizes holds one program per bucket, a
second engine over the same store+module compiles NOTHING, and training
commits (which bump the version but not the generation) never invalidate
serving programs.

Elastic stores (DESIGN.md §9) serve through the same programs under
clone/kill churn: the stacked tree is capacity-padded (shapes are
churn-invariant), the store's active mask is re-read per request and
threaded in as a replicated runtime value, and every particle-axis head
is mask-weighted over live slots — so p_clone/p_kill between requests
change WHAT is served, never what is compiled, and in-flight requests
drain against the mask and buffers they already read.

Two program shapes:

  predict(batch)        stateless BMA forward     forward(params, batch)
  step(state, batch)    stateful serving (LM decode: per-particle KV
                        caches ride the stacked axis and never leave the
                        device)                    forward(params, state,
                                                   batch) -> (out, state)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import precision as precision_mod
from ..core.store import ParticleStore, Placement
from ..runtime import (ProgramCache, ProgramSpec, abstract_key, bucket_size,
                       global_cache, ident, pad_rows)
from ..runtime.specs import paged_decode_step, paged_prefill
from . import uncertainty


def _leading(tree) -> int:
    return jax.tree.leaves(tree)[0].shape[0]


def _bma_reduce_heads(outs, placement: Placement, n: int, kind: str,
                      mask=None):
    """Heads from stacked member outputs, with the particle-axis
    reduction expressed as sharding-constraint transitions. ``mask`` is
    the (capacity,) active mask of an elastic store — the heads weight
    live slots only (replicated alongside the gathered outputs, so the
    masked reduction is local to every device)."""
    if placement.mesh is not None:
        row_sh = placement.vector(n)           # P(particle_axis), rest ∅
        outs = jax.lax.with_sharding_constraint(outs, row_sh)
        # the BMA all-to-all as one on-device collective: every device
        # gets all members' outputs, then reduces locally (replicated)
        outs = jax.lax.with_sharding_constraint(
            outs, placement.replicated(outs))
    return uncertainty.predictive_heads(outs, kind, mask), outs


class PredictiveEngine:
    """Compiled posterior-predictive service core over a ParticleStore.

    Parameters
    ----------
    forward:    stateless mode — ``forward(params_row, batch) -> outputs``
                (leading batch axis); stateful mode (``stateful=True``) —
                ``forward(params_row, state_row, batch) -> (out, state)``.
    store/key:  where stacked params live (the PD's store). Mutually
                exclusive with ``params`` — a static stacked tree (e.g.
                serve-time SWAG samples, bdl/swag.py).
    placement:  mesh plan; defaults to the store's. Decides the particle
                axis sharding + the on-device BMA all-gather.
    kind:       "classify" (member outputs are logits) or "regress".
    cache:      ProgramCache override (tests); defaults process-wide.
    """

    def __init__(self, forward: Callable, *,
                 store: Optional[ParticleStore] = None, key: str = "params",
                 params: Any = None, placement: Optional[Placement] = None,
                 kind: str = "classify", stateful: bool = False,
                 cache: Optional[ProgramCache] = None, precision: Any = None):
        if (store is None) == (params is None):
            raise ValueError("pass exactly one of store= or params=")
        if kind not in uncertainty.KINDS:
            raise ValueError(f"kind must be one of {uncertainty.KINDS}")
        self.forward = forward
        self.store = store
        self.key = key
        self.kind = kind
        self.stateful = stateful
        # serve-side precision ladder (DESIGN.md §13): explicit arg >
        # the store's policy > fp32. ``casts_serve`` engines maintain a
        # version-memoized serve-dtype (optionally int8-quantized) copy
        # of the stacked params; the fp32 default is a zero-overhead
        # pass-through (identical programs to the pre-policy code).
        if precision is None and store is not None:
            precision = getattr(store, "precision", None)
        self.precision = precision_mod.get(precision)
        if placement is None:
            placement = store.placement if store is not None else Placement()
        self.placement = placement
        # explicit None test: an *empty* ProgramCache is falsy (__len__)
        self.cache = cache if cache is not None else global_cache()
        self._static_params = params
        if params is not None and placement.mesh is not None:
            self._static_params = jax.device_put(
                params, placement.shardings(params))
        if params is not None and self.precision.casts_serve:
            # one-time transform for static trees (serve-time SWAG
            # samples): eager is fine here, there is no commit cadence
            self._static_params = precision_mod.cast_for_serve(
                self._static_params, self.precision)
        self._static_mask: Any = None
        self._live_idx: Any = None      # (mask object, live row indices)
        self._params_version: Any = None
        self._params_cache: Any = None
        # hot-path memos: the abstract key of the (large) stacked-params
        # tree is recomputed only on store refresh, and the ProgramSpecs
        # (whose construction takes the ident() token lock) are built
        # once per engine — a request's host cost is one cache lookup
        # over the (small) batch shapes
        self._params_key: Any = None
        self._spec_memo: Dict[Any, ProgramSpec] = {}
        self._keys = set()
        self.stats = {"calls": 0, "compiles": 0, "bucket_hits": 0,
                      "param_refreshes": 0}
        if self._static_params is not None:
            self._params_key = abstract_key(self._static_params)

    # -- stacked params ------------------------------------------------------
    def stacked_params(self):
        """The canonical stacked params, cached between store commits
        (store.version) so the hot path is one dict lookup — and never a
        reshard: the store hands back the already-placed tree."""
        if self._static_params is not None:
            return self._static_params
        v = self.store.version(self.key)
        if v != self._params_version:
            self._refresh_params(v, self.store.stacked(self.key))
        return self._params_cache

    def _serve_cast_spec(self) -> ProgramSpec:
        memo = self._spec_memo.get("serve_cast")
        if memo is not None:
            return memo
        prec = self.precision
        spec = ProgramSpec(
            name="serve_cast",
            # no ident(): every engine over any store shares one compiled
            # cast per (policy, placement, shapes) — a second service on
            # the same store compiles nothing, and churn re-runs it warm
            key=("serve_cast",),
            make=lambda ctx: lambda stacked: precision_mod.cast_for_serve(
                stacked, prec),
            in_kinds=("state",),
            out_kinds=None,
            precision=prec.key())
        self._spec_memo["serve_cast"] = spec
        return spec

    def _refresh_params(self, version, stacked):
        """Install a freshly flushed stacked tree in the memo. Shapes are
        capacity-padded, so the abstract key can only change with the
        generation — content edits (incl. clone/kill churn) refresh the
        tree reference without re-walking it.

        Under a ``casts_serve`` policy the memo holds the serve copy —
        cast (and optionally int8-packed) from the masters by one
        compiled program per store commit. The copy is a derived value:
        never a store key, never committed back."""
        if self.precision.casts_serve:
            stacked = self.cache.run(self._serve_cast_spec(), stacked,
                                     placement=self.placement,
                                     state_token=self._state_token())
        self._params_cache = stacked
        if self._params_version is None \
                or version[0] != self._params_version[0]:
            self._params_key = abstract_key(stacked)
        self._params_version = version
        self.stats["param_refreshes"] += 1

    def active_mask(self):
        """The store's (capacity,) live-slot mask, re-read per request —
        THIS is what lets clone/kill churn between requests take effect
        with zero recompiles (mask content is a runtime value, not part
        of any cache key). Static-params engines serve a dense all-ones
        mask."""
        if self.store is not None:
            return self.store.active_mask()
        if self._static_mask is None:
            self._static_mask = jnp.ones((_leading(self._static_params),),
                                         jnp.float32)
        return self._static_mask

    def _mask_and_params(self):
        """Consistent (mask, stacked params) pair under concurrent
        churn: one atomic ``store.snapshot`` under the store lock, so a
        mask bit can never go live before its slot's data landed and
        capacity growth can never split the pair. The engine-side memos
        (params reference, abstract key) refresh off the snapshot's
        version exactly as ``stacked_params`` would."""
        if self.store is None:
            return self.active_mask(), self.stacked_params()
        v, mask, stacked = self.store.snapshot(self.key)
        if v != self._params_version:
            self._refresh_params(v, stacked)
        return mask, self._params_cache

    @property
    def num_particles(self) -> int:
        """Leading member axis of the served stacked tree — the store's
        capacity for elastic stores (use ``store.live_count()`` for the
        live member count)."""
        return _leading(self.stacked_params())

    def _state_token(self):
        """Store generation for the ProgramCache key (particle-set
        changes recompile; content commits do not); static-params
        engines key purely on shapes."""
        return self.store.generation() if self.store is not None else None

    # -- ProgramSpec builders ------------------------------------------------
    def _predict_spec(self, members: bool) -> ProgramSpec:
        memo = self._spec_memo.get(("predict", members))
        if memo is not None:
            return memo
        fwd, kind, prec = self.forward, self.kind, self.precision

        def make(ctx):
            def fused(stacked_params, b, mask):
                if prec.casts_serve:
                    # dequantize int8 packs / finish the serve cast at
                    # trace top (a no-op on already-cast leaves), cast
                    # the batch floats alongside; the uncertainty heads
                    # reduce in fp32 regardless of the member dtype
                    stacked_params = precision_mod.dequantize(stacked_params,
                                                              prec.serve)
                    b = precision_mod.cast_floats(b, prec.serve)
                outs = jax.vmap(fwd, in_axes=(0, None),
                                spmd_axis_name=ctx.spmd_axis)(
                    stacked_params, b)
                if prec.casts_serve:
                    outs = precision_mod.cast_floats(outs, jnp.float32)
                heads, outs_rep = _bma_reduce_heads(outs, ctx.placement,
                                                    ctx.num_particles, kind,
                                                    mask)
                return (heads, outs_rep) if members else heads

            return fused

        spec = ProgramSpec(
            name="bma_predict",
            key=("bma_predict", ident(fwd), kind, members),
            make=make,
            in_kinds=("state", "replicated", "replicated"),
            out_kinds=("replicated",),
            precision=prec.key() if prec.casts_serve else None)
        self._spec_memo[("predict", members)] = spec
        return spec

    def _step_spec(self) -> ProgramSpec:
        memo = self._spec_memo.get("step")
        if memo is not None:
            return memo
        fwd, kind, prec = self.forward, self.kind, self.precision

        def make(ctx):
            def fused(stacked_params, st, b, mask):
                if prec.casts_serve:
                    stacked_params = precision_mod.dequantize(stacked_params,
                                                              prec.serve)
                    b = precision_mod.cast_floats(b, prec.serve)
                outs, new_st = jax.vmap(fwd, in_axes=(0, 0, None),
                                        spmd_axis_name=ctx.spmd_axis)(
                    stacked_params, st, b)
                if prec.casts_serve:
                    outs = precision_mod.cast_floats(outs, jnp.float32)
                heads, _ = _bma_reduce_heads(outs, ctx.placement,
                                             ctx.num_particles, kind, mask)
                return heads, new_st

            return fused

        spec = ProgramSpec(
            name="bma_step",
            key=("bma_step", ident(fwd), kind),
            make=make,
            in_kinds=("state", "rows", "replicated", "replicated"),
            out_kinds=("replicated", "in:1"),
            precision=prec.key() if prec.casts_serve else None)
        self._spec_memo["step"] = spec
        return spec

    def _program(self, spec: ProgramSpec, args):
        # args[0] is always the stacked params tree: reuse the key
        # memoized at refresh time instead of re-flattening per request
        arg_keys = (self._params_key,) + (None,) * (len(args) - 1)
        prog, hit = self.cache.lookup(spec, self.placement, args,
                                      self._state_token(), arg_keys)
        self._keys.add(prog.cache_key)
        self.stats["bucket_hits" if hit else "compiles"] += 1
        return prog

    # -- serving entry points ------------------------------------------------
    def predict(self, batch, members: bool = False):
        """Fused BMA forward over a request batch (leading axis B).

        Pads B up to the bucket, runs the cached program for that bucket,
        slices the heads back to B. ``members=True`` additionally returns
        the raw stacked member outputs (P, B, ...)."""
        if self.stateful:
            raise RuntimeError("stateful engine: use step(state, batch)")
        self.stats["calls"] += 1
        mask, stacked = self._mask_and_params()
        m = _leading(batch)
        padded = pad_rows(batch, bucket_size(m))
        prog = self._program(self._predict_spec(members),
                             (stacked, padded, mask))
        out = prog(stacked, padded, mask)
        heads, outs = out if members else (out, None)
        heads = jax.tree.map(lambda a: a[:m], heads)
        if members:
            # members keeps its pre-elastic contract: exactly the live
            # rows, slot order (a host-side gather on the replicated
            # outputs — per-request live counts never touch the program).
            # Live indices memoized on the mask object, which the store
            # caches between lifecycle events: no per-request device sync
            if self._live_idx is None or self._live_idx[0] is not mask:
                self._live_idx = (mask,
                                  np.flatnonzero(np.asarray(mask) > 0))
            live = self._live_idx[1]
            return heads, jax.tree.map(lambda a: a[live, :m], outs)
        return heads

    def step(self, state, batch):
        """One stateful serving step (LM decode): per-particle state (KV
        caches, leading axis P) stays stacked and on device across steps.
        Returns (heads, new_state)."""
        if not self.stateful:
            raise RuntimeError("stateless engine: use predict(batch)")
        self.stats["calls"] += 1
        mask, stacked = self._mask_and_params()
        prog = self._program(self._step_spec(), (stacked, state, batch, mask))
        return prog(stacked, state, batch, mask)

    def init_state(self, make_state: Callable):
        """Build stacked per-particle serving state: ``make_state(row)``
        maps one particle's params to its state (e.g. prefill -> caches);
        vmapped over the stacked axis so state is born sharded."""
        stacked = self.stacked_params()
        prec = self.precision

        def make(ctx):
            row_fn = make_state
            if prec.casts_serve:
                # the memoized copy may hold int8 packs: expand per row
                # so make_state sees ordinary float params
                def row_fn(row):
                    return make_state(precision_mod.dequantize(row,
                                                               prec.serve))
            return jax.vmap(row_fn, spmd_axis_name=ctx.spmd_axis)

        spec = ProgramSpec(
            name="serve_init_state",
            key=("serve_init_state", ident(make_state)),
            make=make,
            in_kinds=("state",),
            precision=prec.key() if prec.casts_serve else None)
        # not counted in the request-path compile stats: state init is a
        # one-off setup call, not part of the serving hot path
        return self.cache.run(spec, stacked,
                              placement=self.placement,
                              state_token=self._state_token())

    def snapshot_stats(self) -> Dict[str, int]:
        return dict(self.stats, programs=len(self._keys),
                    program_cache=self.cache.snapshot_stats())


class PagedDecodeEngine(PredictiveEngine):
    """Continuous-batching LM decode core over the paged KV pool.

    Two fixed-shape programs ride the shared ProgramCache:

      decode_step(packed)   one token for every active row — params and
                            pages stacked over the particle axis, BMA +
                            greedy sampling fused on device, pages
                            donated (in-place pool update);
      prefill(packed)       admit one sequence: chunked prompt prefill
                            into its pages + the first sampled token
                            (one program per pow2 prompt bucket).

    The pages tree lives in the store under ``pages_key`` and crosses
    each call by checkout/commit — content-version bumps only, so churn
    in page ownership or page contents never recompiles anything; the
    cache key carries the store *generation* exactly like params.

    Packed input layouts are the runtime.specs contract: decode ships
    ``(B, 2 + n_pmax)`` i32 (tokens / seq_lens / block tables), prefill
    ships ``(Sp + n_pmax + 1,)`` i32 (tokens / block row / n_tokens) —
    ONE H2D transfer per scheduler step by construction.
    """

    def __init__(self, decode_fn: Callable, prefill_fn: Callable, *,
                 store: ParticleStore, n_pmax: int, key: str = "params",
                 pages_key: str = "kv_pages",
                 placement: Optional[Placement] = None,
                 cache: Optional[ProgramCache] = None, precision: Any = None):
        super().__init__(decode_fn, store=store, key=key, kind="classify",
                         placement=placement, cache=cache,
                         precision=precision)
        if self.precision.serve_quant is not None:
            # int8 packing is a BMA-forward optimization; the decode path
            # serves the plain serve-dtype cast (pages dominate its HBM)
            self.precision = dataclasses.replace(self.precision,
                                                 serve_quant=None)
        self.decode_fn = decode_fn
        self.prefill_fn = prefill_fn
        self.pages_key = pages_key
        self.n_pmax = n_pmax
        self._pages_gen = None
        self._pages_abs_key = None

    # -- fused BMA + sampling head -------------------------------------------
    def _reduce_fn(self):
        kind = self.kind

        def reduce_fn(member_logits, mask, ctx):
            heads, _ = _bma_reduce_heads(member_logits, ctx.placement,
                                         ctx.num_particles, kind, mask)
            mean = heads["mean"]                        # (B, V) BMA probs
            token = jnp.argmax(mean, axis=-1).astype(jnp.int32)
            logprob = jnp.log(jnp.take_along_axis(
                mean, token[:, None], axis=-1)[:, 0] + 1e-12)
            return {"token": token, "logprob": logprob,
                    "entropy": heads["entropy"],
                    "mutual_info": heads["mutual_info"]}

        return reduce_fn

    def _decode_spec(self) -> ProgramSpec:
        memo = self._spec_memo.get("paged_decode")
        if memo is None:
            memo = paged_decode_step(
                self.decode_fn, self._reduce_fn(),
                key=(ident(self.decode_fn), self.kind))
            if self.precision.casts_serve:
                memo = dataclasses.replace(memo,
                                           precision=self.precision.key())
            self._spec_memo["paged_decode"] = memo
        return memo

    def _prefill_spec(self) -> ProgramSpec:
        memo = self._spec_memo.get("paged_prefill")
        if memo is None:
            memo = paged_prefill(
                self.prefill_fn, self._reduce_fn(), n_pmax=self.n_pmax,
                key=(ident(self.prefill_fn), self.kind))
            if self.precision.casts_serve:
                memo = dataclasses.replace(memo,
                                           precision=self.precision.key())
            self._spec_memo["paged_prefill"] = memo
        return memo

    def kv_page_info(self) -> Dict[str, Any]:
        """Dtype + bytes gauges for the paged KV pool (DecodeScheduler
        stats / obs.device): leaf dtype histogram and total store bytes
        of the ``pages_key`` tree."""
        info: Dict[str, Any] = {"key": self.pages_key}
        try:
            info["dtypes"] = self.store.key_dtypes(self.pages_key)
            info["per_device_bytes"] = \
                self.store.per_device_bytes(self.pages_key)
        except Exception:
            info["dtypes"] = {}
        return info

    # -- pages checkout/commit ------------------------------------------------
    def _checkout_pages(self):
        pages = self.store.checkout(self.pages_key)
        gen = self.store.generation()
        if gen != self._pages_gen:
            # capacity-padded shapes: the abstract key can only change
            # with the generation, so churn steps skip the tree walk
            self._pages_abs_key = abstract_key(pages)
            self._pages_gen = gen
        return pages

    def _run_paged(self, spec: ProgramSpec, packed):
        self.stats["calls"] += 1
        mask, params = self._mask_and_params()
        pages = self._checkout_pages()
        try:
            args = (params, pages, packed, mask)
            prog, hit = self.cache.lookup(
                spec, self.placement, args, self._state_token(),
                (self._params_key, self._pages_abs_key, None, None))
            self._keys.add(prog.cache_key)
            self.stats["bucket_hits" if hit else "compiles"] += 1
            heads, new_pages = prog(*args)
        except BaseException:
            # return the (possibly donated-and-dead on a mid-execute
            # failure, but always schema-correct) tree so the store key
            # stays present for the next caller
            self.store.commit(self.pages_key, pages)
            raise
        self.store.commit(self.pages_key, new_pages)
        return heads

    # -- serving entry points -------------------------------------------------
    def decode_step(self, packed):
        """packed: (B, 2 + n_pmax) i32 host array — [tokens, seq_lens,
        block tables]; rows with seq_len -1 are inactive (their heads are
        garbage — mask downstream). Returns the heads tree on device."""
        return self._run_paged(self._decode_spec(), packed)

    def prefill(self, packed):
        """packed: (Sp + n_pmax + 1,) i32 host array — [prompt tokens
        padded to the Sp bucket, block table row, n_tokens]. Returns
        heads for the first generated token (leading axis 1)."""
        return self._run_paged(self._prefill_spec(), packed)
