"""Speculative BMA decoding: one particle drafts, the ensemble verifies
(DESIGN.md §14).

The fused-BMA decode program (DESIGN.md §10) pays its dispatch + particle
fan-out once PER TOKEN. But any single particle is a cheap approximation
of the full Bayesian model average — so let it run ahead: a designated
*draft particle* autoregressively proposes K tokens per sequence through
the existing single-row paged decode path (ONE program with an internal
``lax.scan``, the argmax fed back in-trace), then ONE fused verify
program scores the whole drafted window across every live particle at
once (``kernels.paged_decode_attention.paged_decode_window_attention``
streams each KV page once per window instead of once per token) and the
scheduler accepts the longest prefix on which the drafts match the BMA
argmax. Every emitted token IS a verify-output BMA argmax, so greedy
decode stays token-exact by construction — the draft particle's quality
affects only speed, never output.

Cache-key anatomy (the churn invariants of §9/§10 carry over):

  * program shapes are fixed at ``(max_active, k_max)`` — per-sequence
    adaptive K rides in the packed array as a runtime value (``k_len`` /
    ``win_len`` columns), so admission/retirement/preemption and any
    K schedule reuse the same two compiled programs;
  * the draft slot is a traced i32 scalar sliced with
    ``dynamic_index_in_dim`` — clone/kill churn re-picks the slot by
    re-uploading one scalar, never recompiling;
  * pages cross both programs by checkout/commit with donation, exactly
    like the plain decode step — no ``generation()`` bump anywhere in
    the steady loop.

Rollback protocol: the draft program writes the draft particle's KV for
positions ``n-1 .. n+k-2``; verify then overwrites them (bit-identical —
same tokens, same params row, same rope positions) and writes every
OTHER particle's window KV before attending, so after accepting m tokens
the pool is exactly what m sequential committed steps would have left
for positions ``<= n+m-2``. Device state past the accepted prefix is
stale-but-unreachable (the kernels mask on position), so rollback is
pure host page accounting: ``PagePool.release_tail`` returns any page
the rejected tail had crossed into.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import precision as precision_mod
from ..obs import trace as _trace
from ..runtime import abstract_key, ident
from ..runtime.specs import spec_draft_step, spec_verify
from .batcher import DecodeScheduler, _Seq
from .engine import PagedDecodeEngine, _bma_reduce_heads


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decode policy knobs (``serve_decode(speculative=...)``).

    k_max:      drafted tokens per sequence per step (the program shape;
                per-sequence K adapts at runtime below it).
    adaptive:   drive per-sequence K from an acceptance-rate EMA (greedy
                text that the draft particle nails gets longer windows;
                disagreeing rows fall back toward K=1).
    ema_alpha:  EMA smoothing for the measured acceptance rate.
    ema_init:   optimistic prior (start at full K, shrink on evidence).
    quantized:  draft from an int8-quantized copy of the draft particle
                (rebuilt per params commit) instead of the live row —
                cheaper drafts, identical outputs (verify decides).
    """
    k_max: int = 4
    adaptive: bool = True
    ema_alpha: float = 0.3
    ema_init: float = 1.0
    quantized: bool = False

    def __post_init__(self):
        if self.k_max < 1:
            raise ValueError("k_max must be >= 1")
        if not 0.0 < self.ema_alpha <= 1.0:
            raise ValueError("ema_alpha must be in (0, 1]")


def resolve_spec_config(speculative) -> Optional[SpecConfig]:
    """``None``/``False`` -> off; ``True`` -> defaults; int -> that
    ``k_max``; a ``SpecConfig`` passes through."""
    if speculative is None or speculative is False:
        return None
    if speculative is True:
        return SpecConfig()
    if isinstance(speculative, SpecConfig):
        return speculative
    if isinstance(speculative, int):
        return SpecConfig(k_max=speculative)
    raise TypeError(f"speculative= takes None/bool/int/SpecConfig, "
                    f"got {type(speculative).__name__}")


class SpecDecodeEngine(PagedDecodeEngine):
    """PagedDecodeEngine + the two speculative programs.

      draft_step(packed, slot)   K greedy tokens from ONE particle row
                                 (or its int8 copy), scan fused — one
                                 dispatch per drafted window;
      verify_step(packed)        the W = K+1 token window scored by the
                                 whole ensemble in one pass, per-position
                                 BMA heads fused on device.

    Both ride the shared ProgramCache with fixed shapes; the draft slot
    is a device-cached scalar re-uploaded only when churn re-picks it.
    """

    def __init__(self, decode_fn: Callable, prefill_fn: Callable,
                 verify_fn: Callable, *, spec_cfg: SpecConfig, **kw):
        super().__init__(decode_fn, prefill_fn, **kw)
        self.verify_fn = verify_fn
        self.spec_cfg = spec_cfg
        self.k_max = spec_cfg.k_max
        self.w_max = spec_cfg.k_max + 1
        self._slot_dev: Any = None          # (value, device scalar)
        self._draft_slot_memo: Any = None   # (mask object, slot int)
        self._qpack: Any = None             # (params version, slot, pack, key)
        self.stats.setdefault("slot_uploads", 0)
        self.stats.setdefault("draft_packs", 0)

    # -- draft slot ----------------------------------------------------------
    def pick_draft_slot(self, mask) -> int:
        """First live slot of the store's active mask, memoized on the
        mask object (the store caches it between lifecycle events, so the
        host sync happens once per churn event, not per step)."""
        memo = self._draft_slot_memo
        if memo is not None and memo[0] is mask:
            return memo[1]
        live = np.flatnonzero(np.asarray(mask) > 0)
        if live.size == 0:
            raise RuntimeError("no live particles to draft from")
        slot = int(live[0])
        self._draft_slot_memo = (mask, slot)
        return slot

    def _slot_scalar(self, slot: int):
        if self._slot_dev is None or self._slot_dev[0] != slot:
            self._slot_dev = (slot, jnp.asarray(slot, jnp.int32))
            self.stats["slot_uploads"] += 1
        return self._slot_dev[1]

    # -- quantized draft pack ------------------------------------------------
    def _draft_params(self, params, slot: int):
        """The draft program's first operand: the full stacked tree (the
        program slices the row in-trace), or — quantized mode — an int8
        pack of the draft row, memoized per (params version, slot). The
        pack is a derived value: never a store key, never in the mask."""
        if not self.spec_cfg.quantized:
            return params, self._params_key
        version = self._params_version
        memo = self._qpack
        if memo is not None and memo[0] == version and memo[1] == slot:
            return memo[2], memo[3]
        # keepdims row slice: the leading axis of 1 keeps quantize_int8's
        # stacked-tree semantics (per-output-channel scales, >=3D leaves)
        row = jax.tree.map(lambda a: a[slot:slot + 1], params)
        pack = precision_mod.quantize_int8(row)
        key = abstract_key(pack)
        self._qpack = (version, slot, pack, key)
        self.stats["draft_packs"] += 1
        return pack, key

    def _dequant_dtype(self):
        prec = self.precision
        return prec.serve if prec.casts_serve else jnp.float32

    # -- ProgramSpecs --------------------------------------------------------
    def _draft_spec(self):
        memo = self._spec_memo.get("spec_draft")
        if memo is None:
            memo = spec_draft_step(
                self.decode_fn, k_max=self.k_max,
                key=(ident(self.decode_fn),),
                quantized=self.spec_cfg.quantized,
                dequant_dtype=self._dequant_dtype())
            if self.precision.casts_serve:
                memo = dataclasses.replace(memo,
                                           precision=self.precision.key())
            self._spec_memo["spec_draft"] = memo
        return memo

    def _verify_reduce_fn(self):
        kind = self.kind

        def reduce_fn(member_logits, mask, ctx):
            # member_logits: (P, B, W, V); the heads are per window
            # position — predictive_heads is shape-generic (softmax and
            # mask-weighting over the trailing/leading axes)
            heads, _ = _bma_reduce_heads(member_logits, ctx.placement,
                                         ctx.num_particles, kind, mask)
            mean = heads["mean"]                    # (B, W, V) BMA probs
            token = jnp.argmax(mean, axis=-1).astype(jnp.int32)  # (B, W)
            logprob = jnp.log(jnp.take_along_axis(
                mean, token[..., None], axis=-1)[..., 0] + 1e-12)
            return {"token": token, "logprob": logprob,
                    "entropy": heads["entropy"],
                    "mutual_info": heads["mutual_info"]}

        return reduce_fn

    def _verify_spec(self):
        memo = self._spec_memo.get("spec_verify")
        if memo is None:
            memo = spec_verify(
                self.verify_fn, self._verify_reduce_fn(), w_max=self.w_max,
                key=(ident(self.verify_fn), self.kind))
            if self.precision.casts_serve:
                memo = dataclasses.replace(memo,
                                           precision=self.precision.key())
            self._spec_memo["spec_verify"] = memo
        return memo

    # -- entry points --------------------------------------------------------
    def draft_step(self, packed, slot: int):
        """packed: (B, 3 + n_pmax) i32 host array — [last token, its
        position (-1 inactive), k_len, block tables]. Returns the
        (B, k_max) drafted token array (host side ignores entries past
        each row's k_len)."""
        self.stats["calls"] += 1
        mask, params = self._mask_and_params()
        del mask
        draft_params, draft_key = self._draft_params(params, slot)
        pages = self._checkout_pages()
        try:
            args = (draft_params, pages, packed, self._slot_scalar(slot))
            prog, hit = self.cache.lookup(
                self._draft_spec(), self.placement, args,
                self._state_token(),
                (draft_key, self._pages_abs_key, None, None))
            self._keys.add(prog.cache_key)
            self.stats["bucket_hits" if hit else "compiles"] += 1
            drafts, new_pages = prog(*args)
        except BaseException:
            self.store.commit(self.pages_key, pages)
            raise
        self.store.commit(self.pages_key, new_pages)
        return drafts

    def verify_step(self, packed):
        """packed: (B, w_max + 2 + n_pmax) i32 host array — [window
        tokens, window-start position (-1 inactive), win_len, block
        tables]. Returns the per-position heads tree (each (B, w_max))."""
        return self._run_paged(self._verify_spec(), packed)


class _SpecState:
    """Per-sequence adaptive-K state (side table keyed by sid — survives
    preemption/replay, dropped at retirement)."""
    __slots__ = ("ema", "k")

    def __init__(self, ema: float, k: int):
        self.ema = ema
        self.k = k


class SpeculativeDecodeScheduler(DecodeScheduler):
    """DecodeScheduler whose step loop drafts K tokens per sequence and
    verifies them in one fused pass — variable tokens per step, identical
    tokens to the plain scheduler.

    One iteration: admit (unchanged) -> ensure pages THROUGH the drafted
    window -> ONE draft program call (skipped when every row's K is 0) ->
    ONE verify call -> per row accept the longest draft prefix matching
    the BMA argmax (eos-truncated), roll rejected-tail pages back
    page-granularly, update the acceptance EMA, retire. Two dispatches
    per iteration amortized over up to ``(K+1) x rows`` emitted tokens is
    the entire speedup; correctness never depends on K.
    """

    def __init__(self, engine: SpecDecodeEngine, pool, **kw):
        super().__init__(engine, pool, **kw)
        cfg = engine.spec_cfg
        self.spec_cfg = cfg
        self.k_max = cfg.k_max
        self.w_max = cfg.k_max + 1
        self._spec_state: Dict[int, _SpecState] = {}
        # fixed-shape staging: draft [tok, pos, k_len, bt...], verify
        # [window tokens, pos, win_len, bt...] — refilled in place, one
        # H2D each per iteration
        self._draft_packed = np.zeros((self.max_active, 3 + self.n_pmax),
                                      np.int32)
        self._verify_packed = np.zeros(
            (self.max_active, self.w_max + 2 + self.n_pmax), np.int32)
        self.spec_stats: Dict[str, Any] = {
            "spec_steps": 0, "draft_calls": 0, "verify_calls": 0,
            "drafted_tokens": 0, "accepted_tokens": 0, "rollback_pages": 0,
        }

    # -- adaptive K ----------------------------------------------------------
    def _state_for(self, seq: _Seq) -> _SpecState:
        st = self._spec_state.get(seq.sid)
        if st is None:
            st = _SpecState(self.spec_cfg.ema_init, self.k_max)
            self._spec_state[seq.sid] = st
        return st

    def _plan_k(self, seq: _Seq) -> int:
        """Tokens to draft for ``seq`` this iteration: the adaptive-K
        target clipped to what the sequence can still emit (k <=
        remaining - 1 keeps every emitted token a verify output)."""
        remaining = seq.max_new - len(seq.generated)
        k = self._state_for(seq).k if self.spec_cfg.adaptive else self.k_max
        return max(0, min(k, remaining - 1, self.k_max))

    def _observe_acceptance(self, seq: _Seq, k: int, accepted: int):
        if not self.spec_cfg.adaptive or k < 1:
            return
        st = self._state_for(seq)
        a = self.spec_cfg.ema_alpha
        st.ema = (1.0 - a) * st.ema + a * (accepted / k)
        st.k = max(1, min(self.k_max, 1 + round(st.ema * (self.k_max - 1))))

    # -- step loop -----------------------------------------------------------
    def warmup(self, prompt_buckets=()):
        """Compile the draft + verify programs (all rows inactive: no
        page writes, pool untouched) and one prefill program per bucket.
        The single-token decode program is never used in speculative
        mode, so it is not warmed."""
        from .engine import bucket_size
        with self.step_lock:
            self._draft_packed[:] = 0
            self._draft_packed[:, 1] = -1
            jax.block_until_ready(jax.tree.leaves(
                self.engine.draft_step(self._draft_packed,
                                       self.engine.pick_draft_slot(
                                           self.engine.active_mask()))))
            self._verify_packed[:] = 0
            self._verify_packed[:, self.w_max] = -1
            jax.block_until_ready(jax.tree.leaves(
                self.engine.verify_step(self._verify_packed)))
            for b in prompt_buckets:
                buf = self._prefill_buf(bucket_size(int(b)))
                buf[:] = 0
                jax.block_until_ready(
                    jax.tree.leaves(self.engine.prefill(buf)))

    def _step(self):
        import time
        self._admit()
        active = [(i, s) for i, s in enumerate(self._rows) if s is not None]
        if not active:
            if self._waiting:
                time.sleep(1e-3)
            return
        # grow THROUGH the drafted window: the draft writes positions
        # len-1 .. len-2+k, verify writes one more; submit-time bounds
        # guarantee the window always fits a sequence's page cap
        plans: Dict[int, int] = {}
        for i, seq in active:
            if self._rows[i] is not seq:
                continue
            k_i = self._plan_k(seq)
            if self._ensure_page(seq, extra=k_i):
                plans[seq.sid] = k_i
        active = [(i, s) for i, s in enumerate(self._rows) if s is not None]
        if not active:
            return
        slot = self.engine.pick_draft_slot(self.engine.active_mask())

        drafts = None
        if any(plans.get(s.sid, 0) > 0 for _, s in active):
            with _trace.span("decode.draft", "decode", rows=len(active),
                             slot=slot,
                             tokens=sum(plans.get(s.sid, 0)
                                        for _, s in active)):
                d = self._draft_packed
                d[:, 0] = 0
                d[:, 1] = -1
                d[:, 2:] = 0
                for i, seq in active:
                    d[i, 0] = seq.all_tokens[-1]
                    d[i, 1] = len(seq.all_tokens) - 1
                    d[i, 2] = plans.get(seq.sid, 0)
                    self.pool.fill_block_row(seq.sid, d[i, 3:])
                self.stats["h2d_transfers"] += 1
                drafts = np.asarray(
                    jax.device_get(self.engine.draft_step(d, slot)))
            self.spec_stats["draft_calls"] += 1
            self.spec_stats["drafted_tokens"] += int(
                sum(plans.get(s.sid, 0) for _, s in active))

        with _trace.span("decode.verify", "decode", rows=len(active)):
            v = self._verify_packed
            v[:] = 0
            v[:, self.w_max] = -1
            for i, seq in active:
                k_i = plans.get(seq.sid, 0)
                v[i, 0] = seq.all_tokens[-1]
                if k_i:
                    v[i, 1:1 + k_i] = drafts[i, :k_i]
                v[i, self.w_max] = len(seq.all_tokens) - 1
                v[i, self.w_max + 1] = k_i + 1
                self.pool.fill_block_row(seq.sid, v[i, self.w_max + 2:])
            self.stats["h2d_transfers"] += 1
            heads = jax.device_get(self.engine.verify_step(v))
        self.spec_stats["verify_calls"] += 1
        self.spec_stats["spec_steps"] += 1
        self.stats["steps"] += 1
        self.stats["active_row_steps"] += len(active)

        for i, seq in active:
            k_i = plans.get(seq.sid, 0)
            bma = heads["token"][i]             # (W,) per-position argmax
            # accept rule: position 0's argmax is always right (it
            # conditions only on committed tokens); draft j survives iff
            # it
            # equals the BMA argmax at position j-1, and each surviving
            # draft unlocks the argmax after it
            m = 1
            while m <= k_i and int(drafts[i, m - 1]) == int(bma[m - 1]):
                m += 1
            emitted = 0
            for j in range(m):
                self._append_window_token(seq, heads, i, j)
                emitted += 1
                if seq.finish_reason() == "eos":
                    break
            self.spec_stats["accepted_tokens"] += max(0, emitted - 1)
            self._observe_acceptance(seq, k_i, m - 1)
            # rollback: keep pages for the KV the accepted prefix needs
            # (entries for all_tokens[:-1]); the rejected tail's pages
            # come back page-granularly, its KV is position-masked dead
            freed = self.pool.release_tail(seq.sid,
                                           len(seq.all_tokens) - 1)
            if freed:
                self.spec_stats["rollback_pages"] += freed
                _trace.instant("decode.rollback", "decode", sid=seq.sid,
                               pages=freed)
            self._maybe_retire(i, seq)

    def _append_window_token(self, seq: _Seq, heads, i: int, j: int):
        seq.generated.append(int(heads["token"][i][j]))
        seq.logprobs.append(float(heads["logprob"][i][j]))
        seq.entropy.append(float(heads["entropy"][i][j]))
        seq.mutual_info.append(float(heads["mutual_info"][i][j]))
        self.stats["generated_tokens"] += 1

    # -- bookkeeping overrides ------------------------------------------------
    def _maybe_retire(self, row: int, seq: _Seq):
        done = seq.finish_reason() is not None
        super()._maybe_retire(row, seq)
        if done:
            self._spec_state.pop(seq.sid, None)

    def _fail_all(self, e: BaseException):
        super()._fail_all(e)
        self._spec_state.clear()

    def snapshot_stats(self) -> Dict[str, Any]:
        out = super().snapshot_stats()
        ss = dict(self.spec_stats)
        drafted = max(1, ss["drafted_tokens"])
        ss["acceptance_rate"] = ss["accepted_tokens"] / drafted
        steps = max(1, ss["spec_steps"])
        ss["tokens_per_step"] = self.stats["generated_tokens"] / steps
        ss["k_max"] = self.k_max
        ss["adaptive"] = self.spec_cfg.adaptive
        ss["quantized"] = self.spec_cfg.quantized
        ks = [st.k for st in self._spec_state.values()]
        ss["mean_k"] = (sum(ks) / len(ks)) if ks else float(self.k_max)
        out["speculative"] = ss
        return out
