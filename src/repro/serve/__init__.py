# Batched posterior-predictive serving over the sharded ParticleStore.
# engine.py      — PredictiveEngine: fused BMA forward + uncertainty heads,
#                  per-bucket compile cache, on-device particle reduction
# batcher.py     — MicroBatcher: deadline/size-triggered request coalescing
#                  on the PR-1 executor worker loop, bounded + backpressured
# uncertainty.py — predictive heads (BMA mean, variance, entropy, BALD MI)
# metrics.py     — NLL / ECE / Brier (+ NumPy references for tests)
# service.py     — serve(pd).predict(x) front-end with latency percentiles
from . import metrics, uncertainty
from .batcher import MicroBatcher
from .engine import PredictiveEngine, bucket_size, pad_rows
from .service import (PendingPrediction, Prediction, PredictiveService,
                      serve)
