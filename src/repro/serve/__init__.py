# Batched posterior-predictive serving over the sharded ParticleStore.
# engine.py      — PredictiveEngine: fused BMA forward + uncertainty heads,
#                  per-bucket compile cache, on-device particle reduction;
#                  PagedDecodeEngine: fixed-shape paged decode/prefill
# batcher.py     — MicroBatcher: deadline/size-triggered request coalescing
#                  on the PR-1 executor worker loop, bounded + backpressured;
#                  DecodeScheduler: per-step continuous batching for LM
#                  decode (admit/retire each step, page backpressure)
# paging.py      — paged KV pool: store scratch key + host free-page
#                  allocator and per-sequence block tables
# speculative.py — speculative BMA decode: one particle drafts K tokens,
#                  one fused verify program scores the window, accepts
#                  the longest matching prefix (token-exact greedy BMA)
# uncertainty.py — predictive heads (BMA mean, variance, entropy, BALD MI)
# metrics.py     — NLL / ECE / Brier (+ NumPy references for tests)
# service.py     — serve(pd).predict(x) / serve_decode(pd).generate(ids)
#                  front-ends with latency percentiles
from . import metrics, uncertainty
from .batcher import DecodeScheduler, Generation, MicroBatcher
from .engine import PagedDecodeEngine, PredictiveEngine, bucket_size, pad_rows
from .paging import PagePool, create_kv_pages
from .speculative import (SpecConfig, SpecDecodeEngine,
                          SpeculativeDecodeScheduler)
from .service import (DecodeService, PendingGeneration, PendingPrediction,
                      Prediction, PredictiveService, serve, serve_decode)
