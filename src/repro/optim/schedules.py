"""Learning-rate schedules (callables of the integer step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: lr


def cosine(lr: float, total_steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.minimum(step.astype(jnp.float32), total_steps) / total_steps
        return lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return f


def warmup_cosine(lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine(lr, max(total_steps - warmup, 1), final_frac)

    def f(step):
        s = step.astype(jnp.float32)
        return jnp.where(s < warmup, lr * s / max(warmup, 1), cos(step - warmup))
    return f
