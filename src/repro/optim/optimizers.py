"""Hand-rolled optimizers (no optax dependency).

Interface: opt.init(params) -> state ; opt.update(params, grads, state)
-> (new_params, new_state). All states are pytrees -> vmappable over the
particle axis and shardable under pjit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable
    name: str = "opt"


def _sched(lr):
    return lr if callable(lr) else (lambda step: lr)


def sgd(lr=1e-2, momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    lr_fn = _sched(lr)

    def init(params):
        mu = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(params, grads, state):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
            upd = mu
        else:
            mu = None
            upd = grads
        new = jax.tree.map(lambda p, u: p - lr_t * u.astype(p.dtype), params, upd)
        return new, {"step": step, "mu": mu}

    return Optimizer(init, update, "sgd")


def adam(lr=1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    lr_fn = _sched(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params)}

    def update(params, grads, state):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            u = (m_ / c1) / (jnp.sqrt(v_ / c2) + eps)
            if weight_decay:
                u = u + weight_decay * p
            return p - lr_t * u.astype(p.dtype)

        return jax.tree.map(upd, params, m, v), {"step": step, "m": m, "v": v}

    return Optimizer(init, update, "adam")


def adafactor(lr=1e-2, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second moment (Shazeer & Stern): O(rows+cols) state for
    matrices — what makes 405B-class optimizer state fit HBM (DESIGN.md §4)."""
    lr_fn = _sched(lr)

    def init(params):
        def one(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32), "v": jax.tree.map(
            one, params, is_leaf=lambda x: isinstance(x, jnp.ndarray))}

    def update(params, grads, state):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        beta = 1.0 - (step.astype(jnp.float32)) ** (-decay)

        def one(p, g, v):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if p.ndim >= 2:
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]) / jnp.maximum(
                    vr.mean(axis=-1, keepdims=True)[..., None], eps)
                u = g32 / jnp.sqrt(denom + eps)
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                u = g32 / jnp.sqrt(nv["v"] + eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return p - lr_t * u.astype(p.dtype), nv

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        out = [one(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_v = tdef.unflatten([o[1] for o in out])
        return new_p, {"step": step, "v": new_v}

    return Optimizer(init, update, "adafactor")


def make(name: str, lr=1e-3, **kw) -> Optimizer:
    return {"sgd": sgd, "adam": adam, "adafactor": adafactor}[name](lr, **kw)
