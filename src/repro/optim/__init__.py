from .optimizers import sgd, adam, adafactor, make as make_optimizer
from .schedules import constant, cosine, warmup_cosine
