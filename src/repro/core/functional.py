"""Compiled functional view of a Push distribution (beyond-paper fast path).

The host-side NEL (nel.py) reproduces the paper's runtime faithfully. This
module restates the same particle programs over a *stacked particle axis*:
params of all n particles live in one pytree with leading axis n, every
particle-local computation is vmapped, and every particle-to-particle
communication pattern becomes an array op (all-to-all gather = the stacked
matrix itself; on a sharded mesh, XLA's all-gather over the particle axis).

This removes the paper's per-message host round-trips and context switches
by construction and is what the multi-pod dry-run lowers. EXPERIMENTS.md
§Perf quantifies NEL vs compiled on identical SVGD workloads.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


def init_stacked(module, n: int, rng):
    """n independent inits, stacked on a leading particle axis."""
    return jax.vmap(module.init)(jax.random.split(rng, n))


def stack_pytrees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def unstack_pytree(stacked, n: int):
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(n)]


def flatten_stacked(stacked):
    """(pytree with leading n) -> (n, D) matrix + unravel for one particle."""
    one = jax.tree.map(lambda x: x[0], stacked)
    _, unravel = ravel_pytree(one)
    flat = jax.vmap(lambda t: ravel_pytree(t)[0])(stacked)
    return flat, unravel


def ensemble_value_and_grad(loss_fn: Callable):
    """vmap over particles; each particle sees the same batch (deep-ensemble
    semantics, paper §3.1) unless the batch itself has a particle axis."""
    vag = jax.value_and_grad(lambda p, b: loss_fn(p, b)[0])

    def f(stacked_params, batch):
        return jax.vmap(vag, in_axes=(0, None))(stacked_params, batch)

    return f


def ensemble_step(loss_fn: Callable, optimizer):
    """One compiled train step for all particles: grads + optimizer update."""
    vag = ensemble_value_and_grad(loss_fn)

    def step(stacked_params, stacked_opt_state, batch):
        losses, grads = vag(stacked_params, batch)
        new_p, new_s = jax.vmap(optimizer.update)(stacked_params, grads,
                                                  stacked_opt_state)
        return new_p, new_s, losses

    return step


def ensemble_predict(forward: Callable):
    """hat f(x) = (1/n) sum_i nn_{theta_i}(x) — one fused program."""

    def f(stacked_params, batch):
        outs = jax.vmap(forward, in_axes=(0, None))(stacked_params, batch)
        return jax.tree.map(lambda o: jnp.mean(o, axis=0), outs)

    return f
