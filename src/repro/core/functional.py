"""Compiled functional view of a Push distribution (beyond-paper fast path).

The host-side NEL (nel.py) reproduces the paper's runtime faithfully. This
module restates the same particle programs over a *stacked particle axis*:
params of all n particles live in one pytree with leading axis n, every
particle-local computation is vmapped, and every particle-to-particle
communication pattern becomes an array op (all-to-all gather = the stacked
matrix itself; on a sharded mesh, XLA's all-gather over the particle axis).

Mesh-aware compilation (`compile_*`) delegates to the runtime layer
(``repro.runtime``, DESIGN.md §8): a ProgramSpec names the step and the
role of each argument; `runtime.program.lower` jits it with explicit
``in_shardings``/``out_shardings`` derived from ``sharding/rules``
(particle axis leading, within-particle rules on the trailing dims),
``donate_argnums`` on the stacked state so multi-epoch training never
leaves the device (XLA reuses the buffers in place), and ``vmap(...,
spmd_axis_name=particle_axis)`` so GSPMD distributes particles across the
mesh. With ``Placement(mesh=None)`` the same specs degrade to plain
single-device jit — one code path, placement decided by shardings; the
process-wide ProgramCache dedupes compiles across train/predict/serve.
EXPERIMENTS.md §Perf quantifies NEL vs compiled on identical SVGD
workloads.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .store import Placement


def init_stacked(module, n: int, rng):
    """n independent inits, stacked on a leading particle axis."""
    return jax.vmap(module.init)(jax.random.split(rng, n))


def stack_pytrees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def unstack_pytree(stacked, n: int):
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(n)]


def flatten_stacked(stacked):
    """(pytree with leading n) -> (n, D) matrix + unravel for one particle."""
    one = jax.tree.map(lambda x: x[0], stacked)
    _, unravel = ravel_pytree(one)
    flat = jax.vmap(lambda t: ravel_pytree(t)[0])(stacked)
    return flat, unravel


# ---------------------------------------------------------------------------
# active-mask helpers (elastic lifecycle, DESIGN.md §9): stacked trees are
# capacity-padded; ``mask`` is the store's (capacity,) active mask. Every
# masked op uses ``where`` (not multiply) so garbage in dead slots — even
# NaN — can never leak into live results.
# ---------------------------------------------------------------------------

def expand_mask(mask, ndim: int):
    """(P,) mask broadcast-shaped against a (P, ...) array of `ndim`."""
    return mask.reshape(mask.shape + (1,) * (ndim - 1))


def masked_select(mask, new_tree, old_tree):
    """Per-slot select: live slots take `new`, dead slots keep `old`
    (the frozen padding row) — the update rule of every masked train
    step, so dead slots never accumulate garbage."""
    return jax.tree.map(
        lambda nw, od: jnp.where(expand_mask(mask, nw.ndim) > 0, nw, od),
        new_tree, old_tree)


def masked_mean(tree, mask):
    """Mean over the leading particle axis restricted to live slots."""
    live = jnp.maximum(jnp.sum(mask), 1.0)
    return jax.tree.map(
        lambda o: jnp.sum(
            jnp.where(expand_mask(mask, o.ndim) > 0, o, 0.0), axis=0) / live,
        tree)


def ensemble_value_and_grad(loss_fn: Callable,
                            spmd_axis_name: Optional[str] = None):
    """vmap over particles; each particle sees the same batch (deep-ensemble
    semantics, paper §3.1) unless the batch itself has a particle axis."""
    vag = jax.value_and_grad(lambda p, b: loss_fn(p, b)[0])

    def f(stacked_params, batch):
        return jax.vmap(vag, in_axes=(0, None),
                        spmd_axis_name=spmd_axis_name)(stacked_params, batch)

    return f


def ensemble_step(loss_fn: Callable, optimizer,
                  spmd_axis_name: Optional[str] = None,
                  compute_dtype=None):
    """One compiled train step for all particles: grads + optimizer update.

    ``mask=None`` is the dense form; with a (capacity,) active mask, dead
    slots keep their params/opt state bit-for-bit (frozen padding rows)
    and report loss 0.0.

    ``compute_dtype`` is the mixed-precision split (DESIGN.md §13): the
    loss/grad pass runs on a *traced* cast of the masters (and of the
    batch's float leaves), gradients are cast back per-leaf to each
    master's dtype, and the optimizer update applies against the fp32
    masters — all inside this one donated program, so the compute copy
    never exists as a store key, never costs an H2D, and never bumps the
    generation. ``None`` keeps the default path bit-identical to the
    pre-policy code."""
    vag = ensemble_value_and_grad(loss_fn, spmd_axis_name)

    def step(stacked_params, stacked_opt_state, batch, mask=None):
        if compute_dtype is not None:
            from .precision import cast_floats
            losses, grads = vag(cast_floats(stacked_params, compute_dtype),
                                cast_floats(batch, compute_dtype))
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype),
                                 grads, stacked_params)
            losses = losses.astype(jnp.float32)
        else:
            losses, grads = vag(stacked_params, batch)
        new_p, new_s = jax.vmap(optimizer.update,
                                spmd_axis_name=spmd_axis_name)(
            stacked_params, grads, stacked_opt_state)
        if mask is not None:
            new_p = masked_select(mask, new_p, stacked_params)
            new_s = masked_select(mask, new_s, stacked_opt_state)
            losses = jnp.where(mask > 0, losses, 0.0)
        return new_p, new_s, losses

    return step


def ensemble_predict(forward: Callable,
                     spmd_axis_name: Optional[str] = None):
    """hat f(x) = (1/n) sum_i nn_{theta_i}(x) — one fused program; with a
    mask, the BMA averages live slots only."""

    def f(stacked_params, batch, mask=None):
        outs = jax.vmap(forward, in_axes=(0, None),
                        spmd_axis_name=spmd_axis_name)(stacked_params, batch)
        if mask is None:
            return jax.tree.map(lambda o: jnp.mean(o, axis=0), outs)
        return masked_mean(outs, mask)

    return f


# ---------------------------------------------------------------------------
# mesh-aware compilation — kept as thin entry points that delegate to the
# runtime layer (repro.runtime): the spec builders below name the program,
# the ProgramCache lowers/jits/caches it. These helpers return the cached
# Program for the given example arguments; repeated calls with the same
# shapes are cache hits, not recompiles. (Lazy imports: core must not
# import repro.runtime at module load — runtime imports core.store.)
# ---------------------------------------------------------------------------

def compile_ensemble_step(loss_fn: Callable, optimizer,
                          placement: Optional[Placement],
                          stacked, opt_state, batch, mask=None, *,
                          state_token=None):
    """One ensemble train step against a placement plan.

    State shardings come from the placement (particle axis + rules); the
    batch is replicated (every particle sees the same data). The stacked
    params/opt buffers are donated: across a multi-epoch loop the state
    never leaves the device — write-back happens once, at commit time.

    Pass ``mask=store.active_mask()`` to get the capacity-padded masked
    program the fused epoch loops run, and
    ``state_token=store.generation()`` to share the cache entry with
    programs the Runtime lowered against that store."""
    from ..runtime import global_cache, specs
    args = (stacked, opt_state, batch)
    if mask is not None:
        args += (mask,)
    return global_cache().program(specs.ensemble_step(loss_fn, optimizer),
                                  placement, args, state_token)


def compile_ensemble_predict(forward: Callable,
                             placement: Optional[Placement], stacked, batch,
                             mask=None, *, state_token=None):
    """The fused posterior-predictive program against a placement."""
    from ..runtime import global_cache, specs
    args = (stacked, batch) + (() if mask is None else (mask,))
    return global_cache().program(specs.ensemble_predict(forward),
                                  placement, args, state_token)


def compile_map_step(fn: Callable, placement: Optional[Placement],
                     *stacked_args, state_token=None):
    """A per-particle map (e.g. SWAG moment collection) over stacked
    state trees, sharded and donated like the train step.

    NOTE: the cache keys on ``fn``'s identity — pass a module-level (or
    otherwise long-lived) function; a fresh lambda per call defeats the
    cache and cold-compiles every time (bounded only by the cache's LRU)."""
    from ..runtime import global_cache, ident, specs
    spec = specs.map_step(fn, key=(ident(fn),), n_state=len(stacked_args))
    return global_cache().program(spec, placement, stacked_args, state_token)
