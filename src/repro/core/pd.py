"""Push distribution (paper §3.3): P(nn_Theta) = (1/n) sum_i delta_{nn_theta_i}.

A PD wraps an input NN (a ParticleModule) and encapsulates a set of
particles created from it (the particle pushforward of Appendix A:
p_create creates a particle via ppush). The PD owns the NEL and the
ParticleStore.

API mirrors the paper's Fig. 2:

    pd = PushDistribution(module, num_devices=4, cache_size=4)
    pids = [pd.p_create(optimizer=..., receive={"GATHER": _gather})
            for _ in range(n)]
    pd.p_wait([pd.p_launch(pids[0], "GATHER")])

Runtime backends (DESIGN.md §3, §8): ``backend="nel"|"compiled"`` selects
a Runtime *object* (repro.runtime.backends) once, at construction — there
is no string branching on the hot paths.
  * ``NelRuntime`` (default) — every message runs through the actor
    runtime (persistent per-device event loops, executor.py).
  * ``CompiledRuntime`` — Infer algorithms with a fused stacked-axis
    form (ensemble/SWAG/SVGD) run as ProgramSpecs through the shared
    ProgramCache instead: one XLA program over all particles, placed on
    the PD's mesh (``placement``). Particles still exist — their
    ``state`` is a lazy per-particle view of the store's stacked pytrees
    — so views, messaging and ``p_predict`` behave identically. (One
    deliberate gap: ``gradients()`` stays None after a fused run —
    intermediate grads live inside the XLA program and are not
    materialized per step the way the NEL path's ``grad()`` dispatches
    are.) Algorithms without a fused form transparently fall back to the
    NEL path.

State model (DESIGN.md §6): ``self.store`` (core/store.py) is the single
source of truth for all per-particle state under either backend. The NEL
backend reads/writes through per-particle views (``particle.state``);
the compiled backend checks out the stacked form, trains with donated
buffers, and commits once per fused run.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax

from ..runtime import BACKENDS, make_runtime
from .messages import PFuture
from .nel import NodeEventLoop
from .particle import Particle, ParticleModule
from .precision import cast_floats
from .precision import get as resolve_precision
from .store import ParticleStore, Placement


class PushDistribution:
    def __init__(self, module: ParticleModule, *, num_devices: Optional[int] = None,
                 cache_size: int = 4, view_size: int = 4, seed: int = 0,
                 offload: bool = False, backend: str = "nel",
                 max_pending: int = 4096,
                 placement: Optional[Placement] = None,
                 capacity: int = 0, precision=None):
        if backend not in BACKENDS:
            # validate BEFORE spawning executor threads: a bad backend
            # must not leak a running NodeEventLoop (nothing would ever
            # shut it down)
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {backend!r}")
        self.module = module
        # precision ladder (DESIGN.md §13): explicit arg > module config
        # > fp32. The resolved policy decides the store's master dtype
        # (init params are cast once, at creation), the compute dtype of
        # fused train programs, and the serve copy dtype/quantization.
        if precision is None:
            precision = getattr(getattr(module, "cfg", None),
                                "precision", None)
        self.precision = resolve_precision(precision)
        self.nel = NodeEventLoop(num_devices=num_devices, cache_size=cache_size,
                                 offload=offload, max_pending=max_pending)
        self.view_size = view_size
        self._rng = jax.random.PRNGKey(seed)
        self.particles: Dict[int, Particle] = {}
        # capacity preallocates store slots (power-of-two) so the first
        # `capacity` p_create calls never bump the compile generation
        self.store = ParticleStore(placement, capacity=capacity,
                                   precision=self.precision)
        self.lifecycle = {"clones": 0, "kills": 0, "rebalances": 0}
        self.runtime = make_runtime(backend, self)

    @property
    def backend(self) -> str:
        return self.runtime.name

    @property
    def placement(self) -> Placement:
        return self.store.placement

    # ------------------------------------------------------------------
    def _next_rng(self):
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def p_create(self, optimizer=None, *, device: Optional[int] = None,
                 receive: Optional[Dict[str, Callable]] = None,
                 state: Optional[dict] = None, params=None) -> int:
        """Create one particle (replicate the input NN with fresh init)."""
        if params is None:
            params = self.module.init(self._next_rng())
        # the store's canonical (master) dtype: a "bf16" policy halves
        # params+opt HBM per particle; opt state inits AFTER the cast so
        # its moments follow the master dtype automatically
        if self.precision.master != jax.numpy.dtype("float32"):
            params = cast_floats(params, self.precision.master)
        opt_state = optimizer.init(params) if optimizer is not None else None
        pid = self.nel.register(None, device=device)
        self.store.register(pid)
        p = Particle(pid, self.nel, self.module, params, optimizer, opt_state,
                     state=state, store=self.store)
        # NEL per-particle steps trace the same master/compute split as
        # the fused path (ParticleModule._value_and_grad)
        p.compute_dtype = self.precision.compute \
            if self.precision.casts_compute else None
        for msg, fn in (receive or {}).items():
            p.on(msg, fn)
        self.nel._particles[pid] = p
        self.particles[pid] = p
        return pid

    # -- elastic lifecycle (DESIGN.md §9) ------------------------------------
    def p_clone(self, pid: int, jitter: float = 0.0, *,
                device: Optional[int] = None) -> int:
        """Replicate a live particle into a free slot: params (plus
        optional Gaussian ``jitter``), optimizer state, message handlers
        and every other state key are copied. Within capacity this is a
        pure slot write — no stacked shape changes, no ``generation()``
        bump, zero recompiles of any train/serve/NEL program."""
        src = self.particles[pid]
        new_pid = self.nel.register(None, device=device)
        self.store.register(new_pid)
        rng = self._next_rng() if jitter else None
        # params FIRST: the slot activates in the serving mask when its
        # first key lands (store._mark_present), and that key must be the
        # one serving reads — keys_for returns set order otherwise
        keys = sorted(self.store.keys_for(pid), key=lambda k: k != "params")
        for key in keys:
            # params: one fused slot-to-slot copy (jitter traced in), so
            # the serving-hot key stays canonical with no pending flush;
            # cold keys (opt state, grads): lazy row copies that flush
            # whenever training next needs them
            hot = key == "params"
            self.store.clone_slot(key, pid, new_pid,
                                  jitter=jitter if hot else 0.0,
                                  rng=rng if hot else None,
                                  prefer_row=not hot)
        p = Particle(new_pid, self.nel, self.module, None, src.optimizer,
                     store=self.store, write_state=False)
        p.receive = dict(src.receive)
        p.compute_dtype = getattr(src, "compute_dtype", None)
        self.nel._particles[new_pid] = p
        self.particles[new_pid] = p
        self.lifecycle["clones"] += 1
        return new_pid

    def p_kill(self, pid: int):
        """Retire a particle: its slot goes on the store's free list (the
        active mask flips to 0 there) and the NEL drops its mailbox and
        active-set entry. In-flight fused calls finish against the mask
        they read; the next request sees the shrunken ensemble. Within
        capacity this never changes ``generation()`` — no recompiles."""
        self.particles.pop(pid)     # KeyError for unknown/dead pid
        self.nel.unregister(pid)
        self.store.unregister(pid)
        self.lifecycle["kills"] += 1

    def p_rebalance(self) -> Dict[int, Any]:
        """Re-place live particles evenly across NEL devices (drains
        first) and re-place the store's stacked state against its
        placement plan. Returns {pid: (old_dev, new_dev)} moves."""
        moves = self.nel.rebalance()
        self.store.rebalance()
        self.lifecycle["rebalances"] += 1
        return moves

    def p_launch(self, pid: int, msg: str, *args, **kwargs) -> PFuture:
        p = self.particles[pid]
        if msg not in p.receive:
            raise KeyError(f"particle {pid} has no handler for {msg!r}")
        return self.nel.dispatch(pid, p.receive[msg], p, *args, **kwargs)

    @staticmethod
    def p_wait(futures: Sequence[PFuture]) -> List[Any]:
        return [f.wait() for f in futures]

    def p_params(self, pid: int):
        return self.particles[pid].parameters()

    def particle_ids(self) -> List[int]:
        return self.nel.particle_ids()

    # -- compiled-backend bridge (DEPRECATED thin delegates) -----------------
    def p_stack(self, pids: Sequence[int], key: str = "params"):
        """Deprecated since the capacity-padded store: use
        ``pd.store.stacked(key)`` (+ ``active_mask()``) for the canonical
        padded form or ``pd.store.dense(key, pids)`` for live rows."""
        warnings.warn(
            "PushDistribution.p_stack is deprecated; use store.stacked/"
            "store.dense with the lifecycle API instead",
            DeprecationWarning, stacklevel=2)
        return self.store.stacked(key, pids)

    def p_unstack(self, pids: Sequence[int], stacked, key: str = "params"):
        """Deprecated: commit through ``pd.store.commit(key, stacked,
        pids)`` — index i of `stacked` becomes pids[i]'s state."""
        warnings.warn(
            "PushDistribution.p_unstack is deprecated; use store.commit "
            "with the lifecycle API instead",
            DeprecationWarning, stacklevel=2)
        self.store.commit(key, stacked, pids)

    # -- ensemble-style prediction over all particles -----------------------
    def p_predict(self, batch):
        """hat f(x) = (1/n) sum_i nn_{theta_i}(x) (paper §3.4).

        Dispatches to the runtime: the CompiledRuntime runs one fused XLA
        program over the store's stacked params (cached process-wide,
        invalidated by the store generation when particles are added);
        the NelRuntime runs n sequential forwards with a host wait each."""
        return self.runtime.predict(self, batch)

    def stats(self) -> Dict[str, Any]:
        """Unified observability: executor wait-vs-run counters, NEL
        dispatch counters, store materialization counts, and the shared
        ProgramCache's hit/miss/cold-compile stats, in one dict."""
        return self.runtime.stats()

    def obs(self):
        """Observability handle (repro.obs): full snapshot (stats +
        device gauges + per-program cost attribution), Chrome/Perfetto
        trace dump, Prometheus text exposition.

            from repro import obs
            obs.trace.enable()           # start recording spans
            ...workload...
            pd.obs().dump_trace("trace.json")   # open in ui.perfetto.dev
        """
        from ..obs import Obs
        return Obs(self)

    def serve(self, **kw):
        """Batched posterior-predictive service over this PD's store
        (repro.serve): fused BMA forward + uncertainty heads + adaptive
        micro-batching. Lazy import — core must not depend on serve."""
        from ..serve import serve as _serve
        return _serve(self, **kw)

    def drain(self, timeout: Optional[float] = None):
        self.nel.drain(timeout)

    def cleanup(self):
        self.nel.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.cleanup()
