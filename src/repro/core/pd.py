"""Push distribution (paper §3.3): P(nn_Theta) = (1/n) sum_i delta_{nn_theta_i}.

A PD wraps an input NN (a ParticleModule) and encapsulates a set of
particles created from it (the particle pushforward of Appendix A:
p_create creates a particle via ppush). The PD owns the NEL.

API mirrors the paper's Fig. 2:

    pd = PushDistribution(module, num_devices=4, cache_size=4)
    pids = [pd.p_create(optimizer=..., receive={"GATHER": _gather})
            for _ in range(n)]
    pd.p_wait([pd.p_launch(pids[0], "GATHER")])
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence

import jax

from .messages import PFuture
from .nel import NodeEventLoop
from .particle import Particle, ParticleModule


class PushDistribution:
    def __init__(self, module: ParticleModule, *, num_devices: Optional[int] = None,
                 cache_size: int = 4, view_size: int = 4, seed: int = 0,
                 offload: bool = False):
        self.module = module
        self.nel = NodeEventLoop(num_devices=num_devices, cache_size=cache_size,
                                 offload=offload)
        self.view_size = view_size
        self._rng = jax.random.PRNGKey(seed)
        self.particles: Dict[int, Particle] = {}

    # ------------------------------------------------------------------
    def _next_rng(self):
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def p_create(self, optimizer=None, *, device: Optional[int] = None,
                 receive: Optional[Dict[str, Callable]] = None,
                 state: Optional[dict] = None, params=None) -> int:
        """Create one particle (replicate the input NN with fresh init)."""
        if params is None:
            params = self.module.init(self._next_rng())
        opt_state = optimizer.init(params) if optimizer is not None else None
        pid = self.nel.register(None, device=device)
        p = Particle(pid, self.nel, self.module, params, optimizer, opt_state,
                     state=state)
        for msg, fn in (receive or {}).items():
            p.on(msg, fn)
        self.nel._particles[pid] = p
        self.particles[pid] = p
        return pid

    def p_launch(self, pid: int, msg: str, *args, **kwargs) -> PFuture:
        p = self.particles[pid]
        if msg not in p.receive:
            raise KeyError(f"particle {pid} has no handler for {msg!r}")
        return self.nel.dispatch(pid, p.receive[msg], p, *args, **kwargs)

    @staticmethod
    def p_wait(futures: Sequence[PFuture]) -> List[Any]:
        return [f.wait() for f in futures]

    def p_params(self, pid: int):
        return self.particles[pid].parameters()

    def particle_ids(self) -> List[int]:
        return self.nel.particle_ids()

    # -- ensemble-style prediction over all particles -----------------------
    def p_predict(self, batch):
        """hat f(x) = (1/n) sum_i nn_{theta_i}(x) (paper §3.4)."""
        futs = [self.particles[pid].forward(batch) for pid in self.particle_ids()]
        outs = [f.wait() for f in futs]
        return jax.tree.map(lambda *xs: sum(xs) / len(xs), *outs)

    def cleanup(self):
        self.nel.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.cleanup()
