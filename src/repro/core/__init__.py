# The paper's primary contribution: the particle abstraction for BDL.
# nel.py       — node event loop (particle->device table, active-set cache)
# particle.py  — Particle (local state + messaging), ParticleModule
# pd.py        — PushDistribution (P(nn_Theta) as a set of particles)
# messages.py  — PFuture / ParticleView (async-await + read-only views)
# functional.py— compiled stacked-particle fast path (beyond-paper)
from .messages import PFuture, ParticleView, resolved, snapshot
from .nel import NodeEventLoop
from .particle import Particle, ParticleModule
from .pd import PushDistribution
from . import functional
