# The paper's primary contribution: the particle abstraction for BDL.
# executor.py  — persistent per-device event loops + lightweight pool
# nel.py       — node event loop (particle->device table, active-set cache)
# particle.py  — Particle (local state + messaging), ParticleModule
# pd.py        — PushDistribution (P(nn_Theta) as a set of particles)
# messages.py  — PFuture / ParticleView (async-await + read-only views)
# store.py     — ParticleStore: mesh-sharded stacked state, lazy views
# functional.py— compiled stacked-particle fast path (the "compiled" backend)
# precision.py — Precision policy: fp32 masters / bf16 compute / int8 serve
from .executor import Executor
from .messages import PFuture, ParticleView, resolved, snapshot
from .nel import NodeEventLoop
from .particle import Particle, ParticleModule
from .pd import BACKENDS, PushDistribution
from .precision import Precision
from .store import ParticleStore, Placement, StoreState
from . import functional
from . import precision
