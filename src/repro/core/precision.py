"""Mixed-precision policy for particle ensembles (DESIGN.md §13).

One frozen ``Precision`` value is threaded from model/infer configs
through the store, the runtime and serving:

  master_dtype   what the ``ParticleStore`` holds as the canonical
                 stacked trees (params + optimizer state). fp32 masters
                 are the default; a pure-bf16 store halves params+opt
                 HBM per particle (the big-model headline number).
  compute_dtype  what fused train programs trace in. When it differs
                 from the master dtype the cast is a *traced value*
                 inside the donated step program — gradients come back
                 cast to the master dtype and the optimizer update runs
                 against the fp32 masters. No extra H2D, no store key,
                 no generation bump.
  serve_dtype    what ``PredictiveEngine`` / paged decode forward in
                 (defaults to compute_dtype). The serve copy is a
                 version-memoized on-device cast, compiled once through
                 the shared ProgramCache.
  serve_quant    ``"int8"`` further quantizes large weight leaves
                 per-output-channel for the BMA forward; dequantization
                 is traced inside the fused predict program.
  kv_dtype       storage dtype for paged KV pools (``kv_pages``);
                 None defers to the model config's cache dtype.

The policy is identity for program caching: every spec built under a
non-default policy carries ``Precision.key()`` in its ``ProgramSpec``,
which the process-wide ``ProgramCache`` folds into the cache key —
changing precision is a cold compile, re-running the same precision is
a warm hit (tests/test_precision.py pins this).

Also here: the named ``jax.checkpoint`` policy menu replacing the old
boolean ``cfg.remat`` (SNIPPETS.md §1) — selected per model config via
``cfg.remat_policy`` and applied to the scanned transformer unit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Precision", "PRESETS", "get", "cast_floats", "tree_bytes",
    "quantize_int8", "dequantize", "is_quantized_leaf", "cast_for_serve",
    "checkpoint_policy", "CHECKPOINT_POLICIES",
]


@dataclasses.dataclass(frozen=True)
class Precision:
    """Master/compute/serve dtype split for one particle ensemble."""

    master_dtype: str = "float32"
    compute_dtype: str = "float32"
    serve_dtype: Optional[str] = None      # None -> compute_dtype
    serve_quant: Optional[str] = None      # None | "int8"
    kv_dtype: Optional[str] = None         # None -> model cache default

    def __post_init__(self):
        jnp.dtype(self.master_dtype)       # fail fast on typos
        jnp.dtype(self.compute_dtype)
        if self.serve_dtype is not None:
            jnp.dtype(self.serve_dtype)
        if self.kv_dtype is not None:
            jnp.dtype(self.kv_dtype)
        if self.serve_quant not in (None, "int8"):
            raise ValueError(
                f"serve_quant must be None or 'int8', got {self.serve_quant!r}")

    # -- resolved dtypes -----------------------------------------------------
    @property
    def master(self):
        return jnp.dtype(self.master_dtype)

    @property
    def compute(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def serve(self):
        return jnp.dtype(self.serve_dtype or self.compute_dtype)

    @property
    def casts_compute(self) -> bool:
        """True iff train programs trace a cast copy of the masters."""
        return self.compute != self.master

    @property
    def casts_serve(self) -> bool:
        """True iff serving needs a transformed (cast/quantized) copy."""
        return self.serve != self.master or self.serve_quant is not None

    def key(self) -> tuple:
        """Hashable identity for ProgramSpec / ProgramCache keys."""
        return (str(self.master), str(self.compute), str(self.serve),
                self.serve_quant, self.kv_dtype)

    def describe(self) -> dict:
        return {"master": str(self.master), "compute": str(self.compute),
                "serve": str(self.serve), "serve_quant": self.serve_quant,
                "kv": self.kv_dtype}


#: The precision ladder. ``fp32`` is the no-op default (bit-identical
#: programs to the pre-policy code); ``mixed`` keeps fp32 masters and
#: traces bf16 compute; ``bf16`` stores pure-bf16 masters (2x params+opt
#: memory win, the bench_precision headline); ``mixed_int8`` adds
#: per-channel int8 weight quantization for the BMA serve path.
PRESETS = {
    "fp32": Precision(),
    "mixed": Precision(compute_dtype="bfloat16"),
    "bf16": Precision(master_dtype="bfloat16", compute_dtype="bfloat16"),
    "mixed_int8": Precision(compute_dtype="bfloat16", serve_quant="int8"),
}


def get(p: Any = None) -> Precision:
    """Resolve ``None`` | preset name | ``Precision`` to a ``Precision``."""
    if p is None:
        return PRESETS["fp32"]
    if isinstance(p, Precision):
        return p
    if isinstance(p, str):
        try:
            return PRESETS[p]
        except KeyError:
            raise ValueError(
                f"unknown precision preset {p!r}; "
                f"options: {sorted(PRESETS)}") from None
    raise TypeError(f"precision must be None, str or Precision, got {type(p)}")


# ---------------------------------------------------------------------------
# tree casts
# ---------------------------------------------------------------------------

def cast_floats(tree, dtype):
    """Cast every floating leaf of ``tree`` to ``dtype``; everything else
    (ints, bools, non-arrays) passes through untouched. Safe inside a
    trace (pure ``astype``) and a no-op per leaf that already matches."""
    dtype = jnp.dtype(dtype)

    def cast(x):
        xd = getattr(x, "dtype", None)
        if xd is not None and jnp.issubdtype(xd, jnp.floating) \
                and jnp.dtype(xd) != dtype:
            return x.astype(dtype)
        return x

    return jax.tree.map(cast, tree)


def tree_bytes(tree, precision: Any = None) -> int:
    """Policy-aware per-particle byte estimate: float leaves counted at
    the policy's *master* itemsize, non-float leaves at their own.
    Accepts real arrays or ``jax.eval_shape`` structs."""
    prec = get(precision)
    fsize = prec.master.itemsize
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = int(np.prod(np.shape(leaf), dtype=np.int64)) if np.shape(leaf) \
            else 1
        ld = getattr(leaf, "dtype", None)
        if ld is None or jnp.issubdtype(ld, jnp.floating):
            total += n * fsize
        else:
            total += n * np.dtype(ld).itemsize
    return total


# ---------------------------------------------------------------------------
# int8 per-channel weight quantization (serve-side, BMA forward)
# ---------------------------------------------------------------------------

_QKEYS = frozenset(("q", "s"))


def is_quantized_leaf(x) -> bool:
    return isinstance(x, dict) and set(x) == _QKEYS


def quantize_int8(tree, *, min_ndim: int = 3):
    """Per-output-channel symmetric int8 quantization of a *stacked*
    param tree (leading particle axis). Float leaves with
    ``ndim >= min_ndim`` (the matmul weights: (P, d_in, d_out), embeds
    (P, V, D)) become ``{"q": int8, "s": fp32 scale}`` with the scale
    reduced over every axis except the particle axis (0) and the output
    channel (-1), keepdims so the scale broadcasts back. Small leaves
    (biases, norm scales) are left for the plain dtype cast."""

    def quant(w):
        wd = getattr(w, "dtype", None)
        if wd is None or not jnp.issubdtype(wd, jnp.floating) \
                or w.ndim < min_ndim:
            return w
        wf = w.astype(jnp.float32)
        axes = tuple(range(1, wf.ndim - 1))
        amax = jnp.max(jnp.abs(wf), axis=axes, keepdims=True)
        s = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(wf / s), -127, 127).astype(jnp.int8)
        return {"q": q, "s": s}

    return jax.tree.map(quant, tree)


def dequantize(tree, dtype):
    """Inverse of :func:`quantize_int8`, traced inside the fused forward:
    ``{"q", "s"}`` leaves expand to ``q * s`` in fp32 then cast to the
    serve ``dtype``; everything else is cast via :func:`cast_floats`."""
    dtype = jnp.dtype(dtype)

    def dq(x):
        if is_quantized_leaf(x):
            return (x["q"].astype(x["s"].dtype) * x["s"]).astype(dtype)
        xd = getattr(x, "dtype", None)
        if xd is not None and jnp.issubdtype(xd, jnp.floating) \
                and jnp.dtype(xd) != dtype:
            return x.astype(dtype)
        return x

    return jax.tree.map(dq, tree, is_leaf=is_quantized_leaf)


def cast_for_serve(tree, precision: Any):
    """The serve-copy transform of a stacked master tree under a policy:
    ``serve_quant="int8"`` packs large weight leaves to ``{"q", "s"}``
    (scales stay fp32) and casts the un-quantized remainder to the serve
    dtype; otherwise a plain float cast. This is the body of the
    engine's ``serve_cast`` program — run once per store commit, never
    per request."""
    prec = get(precision)
    if prec.serve_quant != "int8":
        return cast_floats(tree, prec.serve)
    serve = prec.serve
    tree = quantize_int8(tree)

    def leaf(x):
        if is_quantized_leaf(x):
            return x
        xd = getattr(x, "dtype", None)
        if xd is not None and jnp.issubdtype(xd, jnp.floating) \
                and jnp.dtype(xd) != serve:
            return x.astype(serve)
        return x

    return jax.tree.map(leaf, tree, is_leaf=is_quantized_leaf)


# ---------------------------------------------------------------------------
# named jax.checkpoint policies (replaces boolean cfg.remat)
# ---------------------------------------------------------------------------

CHECKPOINT_POLICIES = {
    "everything_saveable": jax.checkpoint_policies.everything_saveable,
    "nothing_saveable": jax.checkpoint_policies.nothing_saveable,
    "dots_saveable": jax.checkpoint_policies.dots_saveable,
    "dots_with_no_batch_dims":
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    "dots_with_no_batch_dims_saveable":
        jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def checkpoint_policy(name: str):
    """Named ``jax.checkpoint`` rematerialization policy (SNIPPETS.md §1).

    ``nothing_saveable`` recomputes everything (minimum activation HBM),
    ``dots_saveable`` keeps matmul outputs (recompute the cheap
    elementwise ops only), ``dots_with_no_batch_dims`` keeps only
    non-batch contractions (weight-gradient matmuls)."""
    try:
        return CHECKPOINT_POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown checkpoint policy {name!r}; "
            f"options: {sorted(CHECKPOINT_POLICIES)}") from None
