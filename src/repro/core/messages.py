"""Futures and message plumbing for the particle runtime (paper §3.2).

``PFuture`` is the handle a particle receives when it ``send``s a message:
the receiver executes the handler on its own timeline; the sender may
``wait()`` (async-await side of the paper's blended concurrency model).

``ParticleView`` is the result of ``particle.get(pid)...wait().view()``:
a *read-only* snapshot of another particle's parameters (paper §3.2 —
"view the result to obtain a read-only copy of a particle's parameters").
Snapshots are decoupled from the owner's live state, so owners can keep
updating concurrently (this is exactly why the paper's SVGD beats its
monolithic baseline on 1 device, §5.1).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Optional

import jax


class PFuture:
    """Future for an asynchronously dispatched particle computation."""

    def __init__(self):
        self._event = threading.Event()
        self._value: Any = None
        self._exc: Optional[BaseException] = None

    def _resolve(self, value: Any):
        self._value = value
        self._event.set()

    def _reject(self, exc: BaseException):
        self._exc = exc
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError("PFuture.wait timed out")
        if self._exc is not None:
            raise self._exc
        return self._value


def resolved(value: Any) -> PFuture:
    f = PFuture()
    f._resolve(value)
    return f


class ParticleView:
    """Read-only snapshot of a particle's parameters (+ optional grads)."""

    __slots__ = ("_params", "_grads", "pid")

    def __init__(self, pid: int, params, grads=None):
        self.pid = pid
        self._params = params
        self._grads = grads

    def view(self) -> "ParticleView":
        return self

    def parameters(self):
        return self._params

    def gradients(self):
        return self._grads


def snapshot(tree):
    """Copy-on-read: jax arrays are immutable, so a structural copy suffices."""
    return jax.tree.map(lambda x: x, tree)
