"""Futures and message plumbing for the particle runtime (paper §3.2).

``PFuture`` is the handle a particle receives when it ``send``s a message:
the receiver executes the handler on its own timeline; the sender may
``wait()`` (async-await side of the paper's blended concurrency model).

``PFuture.wait`` is runtime-aware: executor worker threads (executor.py)
install a thread-local *wait hook*, so a handler that blocks on another
particle's future context-switches into servicing its device's queue
instead of parking the worker — the paper's §4.2 call-stack context
switch. Threads outside the runtime (the user's main thread) fall back
to a plain event wait. Done-callbacks let the executor wake a waiting
worker the moment a cross-device future resolves.

``ParticleView`` is the result of ``particle.get(pid)...wait().view()``:
a *read-only* snapshot of another particle's parameters (paper §3.2 —
"view the result to obtain a read-only copy of a particle's parameters").
Snapshots are decoupled from the owner's live state, so owners can keep
updating concurrently (this is exactly why the paper's SVGD beats its
monolithic baseline on 1 device, §5.1).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional

import jax

# Thread-local runtime state. Executor worker threads set
# ``_tls.wait_hook`` to a callable ``hook(future, timeout) -> bool``
# (True = future completed, False = timed out) that runs queued work
# while waiting. See executor.py.
_tls = threading.local()


def current_wait_hook() -> Optional[Callable]:
    return getattr(_tls, "wait_hook", None)


class PFuture:
    """Future for an asynchronously dispatched particle computation."""

    __slots__ = ("_event", "_value", "_exc", "_lock", "_callbacks")

    def __init__(self):
        self._event = threading.Event()
        self._value: Any = None
        self._exc: Optional[BaseException] = None
        self._lock = threading.Lock()
        self._callbacks: List[Callable[[], None]] = []

    def _fire(self):
        with self._lock:
            self._event.set()
            cbs, self._callbacks = self._callbacks, []
        for cb in cbs:
            cb()

    def _resolve(self, value: Any):
        self._value = value
        self._fire()

    def _reject(self, exc: BaseException):
        self._exc = exc
        self._fire()

    def _on_done(self, cb: Callable[[], None]):
        """Run ``cb`` once the future completes (immediately if done)."""
        with self._lock:
            if not self._event.is_set():
                self._callbacks.append(cb)
                return
        cb()

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> Any:
        if not self._event.is_set():
            hook = current_wait_hook()
            if hook is None:
                if not self._event.wait(timeout):
                    raise TimeoutError("PFuture.wait timed out")
            elif not hook(self, timeout):
                raise TimeoutError("PFuture.wait timed out")
        if self._exc is not None:
            raise self._exc
        return self._value


def resolved(value: Any) -> PFuture:
    f = PFuture()
    f._resolve(value)
    return f


class ParticleView:
    """Read-only snapshot of a particle's parameters (+ optional grads)."""

    __slots__ = ("_params", "_grads", "pid")

    def __init__(self, pid: int, params, grads=None):
        self.pid = pid
        self._params = params
        self._grads = grads

    def view(self) -> "ParticleView":
        return self

    def parameters(self):
        return self._params

    def gradients(self):
        return self._grads


def snapshot(tree):
    """Copy-on-read: jax arrays are immutable, so a structural copy suffices."""
    return jax.tree.map(lambda x: x, tree)
