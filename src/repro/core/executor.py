"""Persistent per-device event loops (paper §4.2) — the dispatch engine.

The seed runtime spawned one Python thread per dispatched message:
unbounded thread churn, GIL thrash, and device-lock convoying. This
module replaces it with the paper's actual scheduler shape:

  * one long-lived **worker loop per device**. All device-bound work for
    a particle flows through the particle's **FIFO mailbox**; the worker
    round-robins over mailboxes that have pending messages, so messages
    to one particle execute in send order while distinct particles on
    the same device interleave fairly. A single worker per device also
    *is* the device serialization — the seed's per-device locks are
    subsumed by construction.
  * a small **shared pool** for lightweight lock-free state reads
    (``get``/views, paper §4.2's "same-device communication can be
    eliminated") that must not queue behind device compute.
  * **context switching on wait**: a handler that blocks on another
    particle's ``PFuture`` does not park its worker — ``PFuture.wait``
    calls back into the executor (via a thread-local hook, see
    messages.py) and the worker services its queue until the future
    resolves. This is the paper's call-stack context switch and is what
    lets nested send-and-wait chains run on a fixed thread count.
  * **bounded queues**: each device queue admits at most
    ``max_pending`` outstanding messages; submitters outside the
    runtime block (backpressure) instead of growing memory without
    bound. Executor threads are exempt so helping can never deadlock.
  * **drain / graceful shutdown**: ``drain()`` waits for quiescence;
    ``shutdown()`` finishes in-flight work, stops the loops, and
    rejects anything left so no waiter hangs.

Dispatch statistics record counts, queue depths and wait-vs-run time —
the quantities §5's scaling discussion reasons about. When ``obs.trace``
is enabled, every run item additionally lands as an ``executor.run``
span (with its mailbox-wait time) and every context-switched wait as
``executor.mailbox_wait`` — timeline views of the same quantities. All
timing goes through ``obs.clock`` (one timebase with the serving layer;
benchmarks/bench_obs.py gates the overhead of the disabled path).

The executor is deliberately jax-free: device residency is injected by
the NEL as a ``device_prep(dev_idx, pid)`` callback, so the scheduler
is testable without accelerator state (tests/test_executor.py).
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional

from ..obs import clock
from ..obs import trace as _trace
from . import messages
from .messages import PFuture


class _WorkItem:
    __slots__ = ("pid", "fn", "args", "kwargs", "future", "needs_device",
                 "t_enqueue")

    def __init__(self, pid, fn, args, kwargs, future, needs_device):
        self.pid = pid
        self.fn = fn
        self.args = args
        self.kwargs = kwargs
        self.future = future
        self.needs_device = needs_device
        self.t_enqueue = clock.now()


class _Mailbox:
    """FIFO message buffer for one particle (or one anonymous pool item)."""

    __slots__ = ("pid", "items", "scheduled")

    def __init__(self, pid: Optional[int] = None):
        self.pid = pid
        self.items: deque = deque()
        self.scheduled = False  # currently linked into its queue's ready list


class _Queue:
    """Run queue for one worker group: mailboxes with pending messages."""

    __slots__ = ("index", "cond", "ready", "pending", "max_depth")

    def __init__(self, index: int):
        self.index = index
        self.cond = threading.Condition()
        self.ready: deque = deque()     # mailboxes with >= 1 message
        self.pending = 0                # messages enqueued or running
        self.max_depth = 0


_POOL_QUEUE = -1  # queue index for the shared lightweight pool


class Executor:
    def __init__(self, num_devices: int, *,
                 device_prep: Optional[Callable[[int, int], None]] = None,
                 pool_size: Optional[int] = None,
                 max_pending: int = 4096,
                 instrument: bool = True):
        if num_devices < 1:
            raise ValueError("need at least one device worker")
        self.num_devices = num_devices
        self.max_pending = max_pending
        # instrument=False removes even the tracer's enabled-check from
        # the run loop — the true baseline bench_obs measures against
        self._trace = _trace.TRACER if instrument else None
        self._device_prep = device_prep
        self._queues = [_Queue(i) for i in range(num_devices)]
        self._pool_queue = _Queue(_POOL_QUEUE)
        self._mailboxes: Dict[int, _Mailbox] = {}
        self._device_of: Dict[int, int] = {}
        self._closed = False
        self._stop = False
        self._idle = threading.Condition()
        self._inflight = 0
        self._stats_lock = threading.Lock()
        self._tlocal = threading.local()  # nested-run accounting per thread
        self._dispatched = 0
        self._completed = 0
        self._pool_dispatched = 0
        self._wait_s = 0.0
        self._run_s = 0.0

        self._threads: List[threading.Thread] = []
        for i, q in enumerate(self._queues):
            t = threading.Thread(target=self._worker, args=(q,),
                                 name=f"push-dev{i}", daemon=True)
            self._threads.append(t)
        if pool_size is None:
            pool_size = max(2, min(8, 2 * num_devices))
        for i in range(pool_size):
            t = threading.Thread(target=self._worker, args=(self._pool_queue,),
                                 name=f"push-pool{i}", daemon=True)
            self._threads.append(t)
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------------
    # registration
    # ------------------------------------------------------------------
    def add_particle(self, pid: int, device_idx: int):
        q = self._queues[device_idx]
        with q.cond:
            self._mailboxes[pid] = _Mailbox(pid)
            self._device_of[pid] = device_idx

    def remove_particle(self, pid: int):
        """Drop a retired particle's mailbox and device entry. Messages
        already scheduled keep running (the ready list holds the mailbox
        reference), but no new work can be submitted for the pid."""
        dev = self._device_of.pop(pid, None)
        if dev is None:
            return
        q = self._queues[dev]
        with q.cond:
            self._mailboxes.pop(pid, None)

    def move_particle(self, pid: int, device_idx: int):
        """Reassign a particle's mailbox to another device worker. The
        caller must have drained the runtime first (NodeEventLoop
        .rebalance does) — a scheduled mailbox cannot be moved."""
        old = self._device_of.get(pid)
        if old is None or old == device_idx:
            self._device_of[pid] = device_idx
            return
        with self._queues[old].cond:
            mb = self._mailboxes.get(pid)
            if mb is not None and (mb.scheduled or mb.items):
                raise RuntimeError(
                    f"cannot move particle {pid}: mailbox busy (drain first)")
            self._device_of[pid] = device_idx

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------
    def submit(self, pid: int, fn: Callable, args=(), kwargs=None, *,
               needs_device: bool = False, lightweight: bool = False) -> PFuture:
        fut = PFuture()
        item = _WorkItem(pid, fn, args, kwargs or {}, fut, needs_device)
        if lightweight:
            q, mb = self._pool_queue, _Mailbox(pid)
        else:
            q, mb = self._queues[self._device_of[pid]], self._mailboxes[pid]
        in_runtime = messages.current_wait_hook() is not None
        with q.cond:
            # After close, external submitters are rejected; runtime threads
            # may still enqueue nested work so in-flight handlers can finish
            # during the drain phase. Once loops stop, everyone is rejected.
            if self._stop or (self._closed and not in_runtime):
                raise RuntimeError("executor is shut down")
            # Backpressure: external submitters block while the device queue
            # is full. Runtime threads are exempt — blocking a worker on its
            # own (or a sibling's) full queue could deadlock the loop.
            while (not in_runtime and self.max_pending
                   and q.pending >= self.max_pending):
                q.cond.wait(0.05)
                if self._closed:
                    raise RuntimeError("executor is shut down")
            with self._idle:
                self._inflight += 1
            q.pending += 1
            if q.pending > q.max_depth:
                q.max_depth = q.pending
            mb.items.append(item)
            if not mb.scheduled:
                mb.scheduled = True
                q.ready.append(mb)
            q.cond.notify()
        with self._stats_lock:
            self._dispatched += 1
            if lightweight:
                self._pool_dispatched += 1
        return fut

    # ------------------------------------------------------------------
    # worker machinery
    # ------------------------------------------------------------------
    def _pop(self, q: _Queue, timeout: float) -> Optional[_WorkItem]:
        with q.cond:
            if not q.ready:
                q.cond.wait(timeout)
            if not q.ready:
                return None
            mb = q.ready.popleft()
            item = mb.items.popleft()
            if mb.items:
                q.ready.append(mb)   # round-robin across particles
            else:
                mb.scheduled = False
            return item

    def _run_item(self, q: _Queue, item: _WorkItem):
        t0 = clock.now()
        # nested accounting: items run by the wait hook *inside* this item's
        # span charge their wall time to our `nested_s`, and we subtract it,
        # so run_time_s never double-counts context-switched work
        outer_nested = getattr(self._tlocal, "nested_s", 0.0)
        self._tlocal.nested_s = 0.0
        try:
            if item.needs_device and self._device_prep is not None:
                self._device_prep(q.index, item.pid)
            item.future._resolve(item.fn(*item.args, **item.kwargs))
        except BaseException as e:  # surfaced on wait()
            item.future._reject(e)
        t1 = clock.now()
        span = t1 - t0
        inner = self._tlocal.nested_s
        self._tlocal.nested_s = outer_nested + span
        tr = self._trace
        if tr is not None and tr.enabled:
            # inlined Tracer.record (the ~10µs work items of the dispatch
            # bench make a method call + get_ident measurable; the tid is
            # cached per worker thread, the deque append is GIL-atomic)
            tid = getattr(self._tlocal, "tid", None)
            if tid is None:
                tid = self._tlocal.tid = threading.get_ident()
            tr._buf.append(("executor.run", "executor", t0, t1, tid,
                            {"pid": item.pid, "queue": q.index,
                             "wait_ms": (t0 - item.t_enqueue) * 1e3}))
            tr._recorded += 1
        with q.cond:
            q.pending -= 1
            q.cond.notify_all()
        with self._stats_lock:
            self._completed += 1
            self._wait_s += t0 - item.t_enqueue
            self._run_s += span - inner
        with self._idle:
            self._inflight -= 1
            if self._inflight == 0:
                self._idle.notify_all()

    def _make_wait_hook(self, q: _Queue):
        """Context switch: run queued work while a future is outstanding."""

        def wait_loop(fut: PFuture, timeout: Optional[float]) -> bool:
            deadline = None if timeout is None else clock.now() + timeout

            def wake():
                with q.cond:
                    q.cond.notify_all()

            fut._on_done(wake)
            while not fut.done():
                if self._stop:
                    rem = (None if deadline is None
                           else max(0.0, deadline - clock.now()))
                    return fut._event.wait(rem)
                # deadline is re-checked every iteration — including right
                # after running an item — so a busy queue cannot starve the
                # caller's timeout indefinitely
                if deadline is not None and clock.now() >= deadline:
                    return fut.done()
                rem = 0.1
                if deadline is not None:
                    rem = min(rem, max(0.0, deadline - clock.now()))
                item = self._pop(q, rem)
                if item is not None:
                    self._run_item(q, item)
            return True

        def hook(fut: PFuture, timeout: Optional[float]) -> bool:
            tr = self._trace
            if tr is None or not tr.enabled:
                return wait_loop(fut, timeout)
            t0 = clock.now()
            try:
                return wait_loop(fut, timeout)
            finally:
                tr.record("executor.mailbox_wait", "executor", t0,
                          clock.now(), {"queue": q.index})

        return hook

    def _worker(self, q: _Queue):
        if self._trace is not None:
            self._trace.name_track(threading.current_thread().name)
        messages._tls.wait_hook = self._make_wait_hook(q)
        while True:
            item = self._pop(q, 0.1)
            if item is not None:
                self._run_item(q, item)
            elif self._stop:
                return

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def drain(self, timeout: Optional[float] = None):
        """Block until every submitted message has finished running."""
        deadline = None if timeout is None else clock.now() + timeout
        with self._idle:
            while self._inflight > 0:
                rem = 1.0
                if deadline is not None:
                    rem = deadline - clock.now()
                    if rem <= 0:
                        raise TimeoutError(
                            f"drain timed out with {self._inflight} in flight")
                self._idle.wait(rem)

    def shutdown(self, drain: bool = True, timeout: Optional[float] = 30.0):
        """Stop accepting work; finish (or reject) the rest; join workers."""
        for q in self._all_queues():
            with q.cond:
                self._closed = True
                q.cond.notify_all()
        if drain:
            try:
                self.drain(timeout)
            except TimeoutError:
                pass
        self._stop = True
        for q in self._all_queues():
            with q.cond:
                q.cond.notify_all()
        for t in self._threads:
            t.join(timeout=timeout)
        # reject whatever is left so no waiter hangs forever
        for q in self._all_queues():
            leftovers = []
            with q.cond:
                while q.ready:
                    mb = q.ready.popleft()
                    leftovers.extend(mb.items)
                    mb.items.clear()
                    mb.scheduled = False
                q.pending -= len(leftovers)
            for item in leftovers:
                item.future._reject(RuntimeError("executor shut down"))
                with self._idle:
                    self._inflight -= 1
                    if self._inflight == 0:
                        self._idle.notify_all()

    def _all_queues(self):
        return self._queues + [self._pool_queue]

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    @property
    def num_threads(self) -> int:
        return len(self._threads)

    def queue_depths(self) -> List[int]:
        return [q.pending for q in self._queues]

    def stats(self) -> Dict[str, Any]:
        with self._stats_lock:
            return {
                "dispatched": self._dispatched,
                "completed": self._completed,
                "pool_dispatched": self._pool_dispatched,
                "queue_depths": self.queue_depths(),
                "pool_depth": self._pool_queue.pending,
                "max_queue_depth": max(q.max_depth for q in self._all_queues()),
                "wait_time_s": self._wait_s,
                "run_time_s": self._run_s,
                "threads": len(self._threads),
            }
