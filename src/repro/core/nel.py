"""Node Event Loop (paper §4.2) — the particle runtime.

A NEL owns (1) a particle-to-device lookup table, (2) a per-device
*active set* (the particle cache): at most ``cache_size`` particles are
resident per device; others are swapped off the accelerator and paged
back in on demand (LRU), and (3) a persistent ``Executor`` — one
long-lived worker loop per device plus a shared lightweight pool
(executor.py). ``dispatch`` *enqueues* one hop of a particle's logical
timeline onto its device's loop; no threads are ever created per
message.

Faithful-to-paper mechanics, adapted to JAX:
  * "device" = a jax.Device. On this CPU container there is one physical
    device; benchmarks fork subprocesses with
    ``--xla_force_host_platform_device_count=N`` to emulate N devices, and
    on a real TPU node the same code addresses the local TPU chips.
  * *device* work (forward / backward / parameter updates) runs on the
    target device's single worker loop, which serializes compute per
    device while letting different devices progress concurrently (the
    paper's Fig. 3b: T4a/4b/4c overlap; the worker loop plays the role
    of the lock held from label 3 to label 8).
  * messages to one particle execute in FIFO send order (per-particle
    mailboxes); distinct particles on a device round-robin.
  * lightweight state reads (``get``/views) run on the shared pool and
    never queue behind device compute — the paper's observation that
    same-device communication "can be eliminated".
  * ``send`` returns immediately with a PFuture (async-await).

Handlers may freely send-and-wait on other particles: a blocked handler
context-switches its worker into servicing the device queue (the
paper's call-stack context switch — see Executor._make_wait_hook), so a
waiting handler never starves the particle it is waiting on.

Instrumentation (`stats` + `executor.stats()`) counts dispatches, swaps,
cross-device transfers, queue depths and wait-vs-run time — the
quantities the paper's §5 scaling discussion reasons about.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional

import jax

from .executor import Executor
from .messages import PFuture


class NodeEventLoop:
    def __init__(self, num_devices: Optional[int] = None, cache_size: int = 4,
                 offload: bool = False, max_pending: int = 4096,
                 pool_size: Optional[int] = None):
        all_devices = jax.devices()
        if num_devices is None:
            num_devices = len(all_devices)
        if num_devices > len(all_devices):
            raise ValueError(
                f"requested {num_devices} devices but only {len(all_devices)} present; "
                "run under XLA_FLAGS=--xla_force_host_platform_device_count=N to emulate")
        self.devices = all_devices[:num_devices]
        self.cache_size = cache_size
        self.offload = offload
        # particle-to-device lookup table
        self._device_of: Dict[int, int] = {}
        self._particles: Dict[int, Any] = {}
        # per-device active set (LRU particle cache)
        self._active: List[OrderedDict] = [OrderedDict() for _ in range(num_devices)]
        self._cache_locks = [threading.Lock() for _ in range(num_devices)]
        self._next_pid = 0
        self.stats = {"dispatches": 0, "swaps_in": 0, "swaps_out": 0,
                      "xdev_transfers": 0}
        self._stats_lock = threading.Lock()
        # persistent per-device worker loops + shared lightweight pool
        self.executor = Executor(num_devices, device_prep=self._device_prep,
                                 pool_size=pool_size, max_pending=max_pending)

    # ------------------------------------------------------------------
    def register(self, particle, device: Optional[int] = None) -> int:
        pid = self._next_pid
        self._next_pid += 1
        dev = device if device is not None else pid % len(self.devices)
        self._device_of[pid] = dev
        self._particles[pid] = particle
        self.executor.add_particle(pid, dev)
        return pid

    def unregister(self, pid: int):
        """Retire a particle: drop it from the device table and registry,
        evict it from its device's LRU active set, and remove its
        executor mailbox. Raises KeyError for unknown/dead pids — dead
        particles must not leak in ``_particles``/``_active`` forever,
        and a late ``dispatch`` to one must fail loudly."""
        dev = self._device_of.pop(pid)      # KeyError for unknown pid
        self._particles.pop(pid, None)
        with self._cache_locks[dev]:
            self._active[dev].pop(pid, None)
        self.executor.remove_particle(pid)

    def rebalance(self) -> Dict[int, tuple]:
        """Re-place live particles evenly across devices (round-robin in
        pid order). Drains in-flight messages first so no mailbox is
        moved while scheduled; returns {pid: (old_dev, new_dev)} for the
        particles that moved."""
        self.drain()
        moves: Dict[int, tuple] = {}
        for i, pid in enumerate(sorted(self._particles)):
            dev = i % len(self.devices)
            old = self._device_of[pid]
            if old == dev:
                continue
            with self._cache_locks[old]:
                self._active[old].pop(pid, None)
            self._device_of[pid] = dev
            self.executor.move_particle(pid, dev)
            moves[pid] = (old, dev)
        return moves

    def device_of(self, pid: int) -> jax.Device:
        return self.devices[self._device_of[pid]]

    def particle_ids(self) -> List[int]:
        return sorted(self._particles)

    def particle(self, pid: int):
        return self._particles[pid]

    def _bump(self, key: str, n: int = 1):
        with self._stats_lock:
            self.stats[key] += n

    # ------------------------------------------------------------------
    # active-set / particle-cache management (paper's context switching)
    # ------------------------------------------------------------------
    def _device_prep(self, dev_idx: int, pid: int):
        # pool items (dev_idx == -1) never prep a device
        if dev_idx >= 0:
            self.ensure_resident(pid)

    def ensure_resident(self, pid: int):
        dev_idx = self._device_of[pid]
        dev = self.devices[dev_idx]
        with self._cache_locks[dev_idx]:
            active = self._active[dev_idx]
            if pid in active:
                active.move_to_end(pid)
                return
            if len(active) >= self.cache_size:
                victim, _ = active.popitem(last=False)      # LRU evict
                self._bump("swaps_out")
                if self.offload:
                    vp = self._particles[victim]
                    vp.state["params"] = jax.device_get(vp.state["params"])
            p = self._particles[pid]
            if self.offload or len(self.devices) > 1:
                p.state["params"] = jax.device_put(p.state["params"], dev)
            active[pid] = True
            self._bump("swaps_in")

    # ------------------------------------------------------------------
    # dispatch: one hop of particle `pid`'s timeline
    # ------------------------------------------------------------------
    def dispatch(self, pid: int, fn: Callable, *args,
                 needs_device: bool = False, lightweight: bool = False,
                 **kwargs) -> PFuture:
        if pid not in self._particles:
            # a dead pid must fail loudly, not silently queue (the
            # lightweight pool would otherwise accept it forever)
            raise KeyError(f"particle {pid} is not registered")
        self._bump("dispatches")
        return self.executor.submit(pid, fn, args, kwargs,
                                    needs_device=needs_device,
                                    lightweight=lightweight)

    def drain(self, timeout: Optional[float] = None):
        """Block until every dispatched message has run to completion."""
        self.executor.drain(timeout)

    def shutdown(self):
        self.executor.shutdown(drain=True)
