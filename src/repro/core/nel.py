"""Node Event Loop (paper §4.2) — the particle runtime.

A NEL owns (1) a particle-to-device lookup table and (2) a context-
switching dispatch mechanism with a per-device *active set* (the particle
cache): at most ``cache_size`` particles are resident per device; others
are swapped off the accelerator and paged back in on demand (LRU).

Faithful-to-paper mechanics, adapted to JAX:
  * "device" = a jax.Device. On this CPU container there is one physical
    device; benchmarks fork subprocesses with
    ``--xla_force_host_platform_device_count=N`` to emulate N devices, and
    on a real TPU node the same code addresses the local TPU chips.
  * message handlers run on a shared pool — each dispatch is one hop of a
    particle's logical timeline (actor model). *Device* work (forward /
    backward / parameter updates) additionally takes the target device's
    lock, which serializes compute per device while letting different
    devices progress concurrently (the paper's Fig. 3b: T4a/4b/4c overlap,
    the device is locked at label 3 and freed at label 8).
  * lightweight state reads (``get``/views) skip the device lock — the
    paper's observation that same-device communication "can be eliminated".
  * ``send`` returns immediately with a PFuture (async-await).

Handlers may freely send-and-wait on other particles: nested dispatches
run on their own pool threads, so a blocked handler never starves the
particle it is waiting on (the paper gets the same property from its
call-stack context switch).

Instrumentation (`stats`) counts dispatches, swaps and cross-device
transfers — the quantities the paper's §5 scaling discussion reasons about.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional

import jax

from .messages import PFuture


class NodeEventLoop:
    def __init__(self, num_devices: Optional[int] = None, cache_size: int = 4,
                 offload: bool = False):
        all_devices = jax.devices()
        if num_devices is None:
            num_devices = len(all_devices)
        if num_devices > len(all_devices):
            raise ValueError(
                f"requested {num_devices} devices but only {len(all_devices)} present; "
                "run under XLA_FLAGS=--xla_force_host_platform_device_count=N to emulate")
        self.devices = all_devices[:num_devices]
        self.cache_size = cache_size
        self.offload = offload
        # particle-to-device lookup table
        self._device_of: Dict[int, int] = {}
        self._particles: Dict[int, Any] = {}
        # per-device active set (LRU particle cache) + device locks
        self._active: List[OrderedDict] = [OrderedDict() for _ in range(num_devices)]
        self._cache_locks = [threading.Lock() for _ in range(num_devices)]
        self.device_locks = [threading.Lock() for _ in range(num_devices)]
        self._next_pid = 0
        self._threads: List[threading.Thread] = []
        self._threads_lock = threading.Lock()
        self.stats = {"dispatches": 0, "swaps_in": 0, "swaps_out": 0,
                      "xdev_transfers": 0}
        self._stats_lock = threading.Lock()

    # ------------------------------------------------------------------
    def register(self, particle, device: Optional[int] = None) -> int:
        pid = self._next_pid
        self._next_pid += 1
        dev = device if device is not None else pid % len(self.devices)
        self._device_of[pid] = dev
        self._particles[pid] = particle
        return pid

    def device_of(self, pid: int) -> jax.Device:
        return self.devices[self._device_of[pid]]

    def particle_ids(self) -> List[int]:
        return sorted(self._particles)

    def particle(self, pid: int):
        return self._particles[pid]

    def _bump(self, key: str, n: int = 1):
        with self._stats_lock:
            self.stats[key] += n

    # ------------------------------------------------------------------
    # active-set / particle-cache management (paper's context switching)
    # ------------------------------------------------------------------
    def ensure_resident(self, pid: int):
        dev_idx = self._device_of[pid]
        dev = self.devices[dev_idx]
        with self._cache_locks[dev_idx]:
            active = self._active[dev_idx]
            if pid in active:
                active.move_to_end(pid)
                return
            if len(active) >= self.cache_size:
                victim, _ = active.popitem(last=False)      # LRU evict
                self._bump("swaps_out")
                if self.offload:
                    vp = self._particles[victim]
                    vp.state["params"] = jax.device_get(vp.state["params"])
            p = self._particles[pid]
            if self.offload or len(self.devices) > 1:
                p.state["params"] = jax.device_put(p.state["params"], dev)
            active[pid] = True
            self._bump("swaps_in")

    # ------------------------------------------------------------------
    # dispatch: one hop of particle `pid`'s timeline
    # ------------------------------------------------------------------
    def dispatch(self, pid: int, fn: Callable, *args,
                 needs_device: bool = False, **kwargs) -> PFuture:
        fut = PFuture()
        dev_idx = self._device_of[pid]
        self._bump("dispatches")

        def run():
            try:
                if needs_device:
                    with self.device_locks[dev_idx]:        # paper label 3/8
                        self.ensure_resident(pid)
                        fut._resolve(fn(*args, **kwargs))
                else:
                    fut._resolve(fn(*args, **kwargs))
            except BaseException as e:  # surfaced on wait()
                fut._reject(e)

        t = threading.Thread(target=run, daemon=True)
        with self._threads_lock:
            self._threads = [th for th in self._threads if th.is_alive()]
            self._threads.append(t)
        t.start()
        return fut

    def shutdown(self):
        with self._threads_lock:
            threads = list(self._threads)
        for t in threads:
            t.join(timeout=30)
