"""ParticleStore: mesh-sharded particle state as the single source of truth.

Both runtime backends read and write the same state through this store
(Tran et al. 2018's design point: one program representation, placement
decided by shardings):

  * canonical form — one *stacked* pytree per state key ("params",
    "opt_state", "swag", ...) with a leading particle axis, placed on a
    device mesh via ``NamedSharding`` derived from
    ``sharding/rules.tree_shardings(..., particle_axis=...)``;
  * derived form — lazy per-particle *views* (unstack-on-read: a view is
    just ``leaf[slot]``, staying on device until consumed) with
    dirty-tracked write-back, which is what the NEL backend's
    ``Particle.state`` maps onto.

Elastic lifecycle (DESIGN.md §9): the store allocates by **capacity, not
count**. Stacked trees are padded to a power-of-two ``capacity``; each
live particle owns one *slot* (``slot_of``), freed slots go on a
free-slot list, and a device-resident ``active_mask()`` (shape
``(capacity,)``, 1.0 at live slots) tells fused programs which rows are
real. Creating, cloning, or killing a particle **within capacity** is a
slot write / mask flip that never changes the stacked shapes — so
``generation()`` (the ProgramCache invalidation token) bumps ONLY on
capacity growth or key-schema changes (a state key seen for the first
time), never on churn. Serving and training keep their compiled
programs across arbitrary clone/kill traffic.

Consistency protocol (all transitions under one lock):

  write_view(pid)  -> row cached + marked dirty; the stale stacked row is
                      shadowed (view reads hit the row cache first)
  stacked()        -> flush: dirty rows written into the stacked tree
                      (slot-wise ``.at[s].set``), or a full restack padded
                      to capacity (free slots filled with zeros) when no
                      canonical stacked exists
  checkout()       -> flush + *move* ownership to the caller: the fused
                      epoch loop donates these buffers to XLA every step
                      (``donate_argnums``), so the store must not retain a
                      reference to memory that is about to be invalidated
  commit(stacked)  -> the fused result becomes canonical; view caches are
                      invalidated and re-derived lazily on next read

``stats`` counts every materialization (stacks, unstacks, row flushes,
commits, device placements) plus the lifecycle counters (mask
invalidations, capacity growths) so tests can assert that churn within
capacity touches neither the compiler nor the host.
"""
from __future__ import annotations

import heapq
import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Set

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..obs import trace as _trace
from ..sharding import rules


# ---------------------------------------------------------------------------
# placement plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True, eq=False)
class Placement:
    """The 2D placement plan: a ``(particle x model)`` mesh plus which
    mesh axis carries which role. ``particle_axis`` shards the leading
    stacked-particle dimension; ``model_axis`` shards *within* one
    particle (tensor-parallel trailing dims via ``sharding/rules`` plus
    the activation constraints of ``sharding/policy``), so a single
    particle that does not fit on one chip spreads over the model axis.
    ``mesh=None`` (the default) keeps state wherever jax puts it — the
    single-device fast path with no resharding cost.

    Equality / hashing are by *plan*, not object identity: two
    placements over separately-built but identical meshes (same axis
    names, sizes, and device order) compare equal, so re-placement onto
    the same 2D plan is a 100% warm hit in the ProgramCache, while any
    mesh-shape or mode change invalidates (the cache key embeds this
    object directly)."""
    mesh: Any = None
    particle_axis: Optional[str] = "data"
    mode: str = "tp"  # within-particle sharding rules mode (sharding/rules)
    model_axis: Optional[str] = "model"

    # -- plan identity -------------------------------------------------------
    def plan_key(self) -> tuple:
        """Hashable value identity of the plan (what cache keys see)."""
        if self.mesh is None:
            mesh_key = None
        else:
            mesh_key = (tuple(self.mesh.axis_names),
                        tuple(int(self.mesh.shape[a])
                              for a in self.mesh.axis_names),
                        tuple(int(d.id) for d in
                              np.asarray(self.mesh.devices).flat))
        return (mesh_key, self.particle_axis, self.model_axis, self.mode)

    def __eq__(self, other) -> bool:
        if not isinstance(other, Placement):
            return NotImplemented
        return self.plan_key() == other.plan_key()

    def __hash__(self) -> int:
        return hash(self.plan_key())

    @staticmethod
    def auto(particle_axis: str = "data", mode: str = "tp",
             model: Any = 1, *, params_bytes: Optional[int] = None,
             param_tree: Any = None, precision: Any = None,
             device_memory_bytes: Optional[int] = None) -> "Placement":
        """Mesh over all local devices. ``model`` sets the model-axis
        size (particles get the remaining ``n_devices // model`` ways);
        ``model="auto"`` picks the smallest model-axis size whose
        per-device parameter shard fits the device memory budget, from
        ``params_bytes`` (per-particle parameter bytes) vs the local
        device's reported memory (``launch.mesh.pick_model_axis``) —
        multi-host launches call this after ``launch.distributed
        .initialize()`` so ``jax.devices()`` spans every process.

        The estimate is *precision-aware*: instead of raw
        ``params_bytes`` you can hand over a ``param_tree`` (real arrays
        or ``jax.eval_shape`` structs) plus the ensemble's ``precision``
        policy; the bytes are then counted at the policy's MASTER
        itemsize (``core.precision.tree_bytes``), so a bf16 store stops
        oversizing the model axis by 2x. An explicit ``params_bytes``
        with a ``precision`` is rescaled from the fp32 baseline the
        callers historically passed."""
        from ..launch.mesh import make_bench_mesh, pick_model_axis
        n = len(jax.devices())
        if model == "auto":
            from .precision import get as _get_prec, tree_bytes
            if params_bytes is None and param_tree is not None:
                params_bytes = tree_bytes(param_tree, precision)
            elif params_bytes is not None and precision is not None:
                prec = _get_prec(precision)
                params_bytes = int(params_bytes
                                   * prec.master.itemsize / 4)
            model = pick_model_axis(params_bytes or 0, n,
                                    device_memory_bytes=device_memory_bytes)
        model = int(model)
        if n <= 1 and model <= 1:
            return Placement(mesh=None)
        return Placement(mesh=make_bench_mesh(n, model=model),
                         particle_axis=particle_axis, mode=mode)

    # -- axis sizes ----------------------------------------------------------
    def _axis_size(self, axis: Optional[str]) -> int:
        if self.mesh is None or axis is None:
            return 1
        return int(dict(self.mesh.shape).get(axis, 1))

    def particle_axis_size(self) -> int:
        return self._axis_size(self.particle_axis)

    def model_axis_size(self) -> int:
        return self._axis_size(self.model_axis)

    # -- sharding derivation -------------------------------------------------
    def shardings(self, stacked_tree):
        """NamedSharding tree for a stacked state pytree (leading particle
        axis -> particle_axis, trailing dims -> sharding/rules over the
        model axis)."""
        if self.mesh is None:
            return None
        return rules.tree_shardings(self.mesh, stacked_tree, self.mode,
                                    self.particle_axis,
                                    model_axis=self.model_axis)

    def activation_policy(self) -> Optional[Dict[str, Any]]:
        """The ``sharding/policy`` name -> PartitionSpec map fused
        programs trace under (None when the plan has no model axis to
        shard over — never constrain intermediates on particle-only
        placements, where forcing replication would be pure overhead)."""
        if self.mesh is None or self.model_axis_size() <= 1:
            return None
        from ..sharding.policy import tp_activation_policy
        return tp_activation_policy(dict(self.mesh.shape), self.model_axis)

    def replicated(self, tree):
        """Fully-replicated shardings (batches: every particle sees the
        same data under deep-ensemble semantics)."""
        if self.mesh is None:
            return None
        sh = NamedSharding(self.mesh, P())
        return jax.tree.map(lambda _: sh, tree)

    def _axis_fits(self, n: int, axis: Optional[str]) -> Optional[str]:
        if self.mesh is None or axis is None:
            return None
        size = dict(self.mesh.shape).get(axis)
        return axis if size and n % size == 0 else None

    def vector(self, n: int):
        """Sharding for per-particle scalars stacked to (n,) (losses)."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh,
                             P(self._axis_fits(n, self.particle_axis)))

    def matrix(self, n: int, d: int):
        """Sharding for the flattened (n, D) particle-parameter matrix
        (SVGD): particles over the particle axis, D over the model axis."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh,
                             P(self._axis_fits(n, self.particle_axis),
                               self._axis_fits(d, self.model_axis)))

    def gathered_matrix(self, d: int):
        """Sharding of the (n, D) matrix *after* the all-gather over the
        particle axis ONLY: every device holds all particles' rows (the
        SVGD kernel matrix needs all-to-all), D still sharded over the
        model axis — the collective never widens past the particle axis."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh,
                             P(None, self._axis_fits(d, self.model_axis)))

    def spmd_axis(self, n: int) -> Optional[str]:
        """vmap spmd_axis_name when the particle count divides the mesh
        axis — this is what lets GSPMD distribute particles."""
        return self._axis_fits(n, self.particle_axis)


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

def _stack_rows(rows):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *rows)


def _leading_dim(tree) -> int:
    return jax.tree.leaves(tree)[0].shape[0]


def _leading_or_none(tree) -> Optional[int]:
    leaves = jax.tree.leaves(tree)
    return leaves[0].shape[0] if leaves else None


def _pow2_at_least(n: int) -> int:
    cap = 1
    while cap < n:
        cap *= 2
    return cap


@jax.jit
def _ROW_WRITE(st, row, slot):
    return jax.tree.map(
        lambda a, r: jax.lax.dynamic_update_slice_in_dim(
            a, r.astype(a.dtype)[None], slot, 0), st, row)


@jax.jit
def _COPY_SLOT(st, src, dst):
    return jax.tree.map(
        lambda a: jax.lax.dynamic_update_slice_in_dim(
            a, jax.lax.dynamic_slice_in_dim(a, src, 1, 0), dst, 0), st)


@jax.jit
def _COPY_SLOT_JITTER(st, src, dst, eps, key):
    leaves, tdef = jax.tree.flatten(st)
    keys = jax.random.split(key, len(leaves))
    out = []
    for a, k in zip(leaves, keys):
        row = jax.lax.dynamic_slice_in_dim(a, src, 1, 0)
        if jnp.issubdtype(a.dtype, jnp.floating):
            row = row + eps.astype(a.dtype) * jax.random.normal(
                k, row.shape, a.dtype)
        out.append(jax.lax.dynamic_update_slice_in_dim(a, row, dst, 0))
    return tdef.unflatten(out)


class ParticleStore:
    """Canonical holder of all per-particle state of one PushDistribution.

    ``capacity`` preallocates slots (rounded up to a power of two) so the
    first ``capacity`` registrations never bump ``generation()``; the
    default 0 grows on demand (1, 2, 4, 8, ... — one generation bump per
    doubling)."""

    def __init__(self, placement: Optional[Placement] = None,
                 capacity: int = 0, precision=None):
        self.placement = placement or Placement()
        # the ensemble's Precision policy (core.precision). The store
        # itself is dtype-agnostic — leaves keep whatever dtype is
        # committed — but it carries the policy so checkpointing can
        # persist it and obs/serve can report/derive from it.
        from .precision import get as _resolve_precision
        self.precision = _resolve_precision(precision)
        self.capacity = _pow2_at_least(capacity) if capacity > 0 else 0
        self._slot_of: Dict[int, int] = {}          # pid -> slot
        self._free: List[int] = list(range(self.capacity))  # min-heap
        self._activated: Set[int] = set()           # slots with data landed
        # in-flight full checkouts: key -> (capacity, slots) at checkout
        self._checkout_cohort: Dict[str, Any] = {}
        self._stacked: Dict[str, Any] = {}          # key -> padded pytree
        self._rows: Dict[str, Dict[int, Any]] = {}  # key -> {slot: row tree}
        self._dirty: Dict[str, Set[int]] = {}       # key -> slots newer
        self._present: Dict[str, Set[int]] = {}     # key -> slots holding it
        self._lock = threading.RLock()
        # (generation, per-key edit count): serving engines cache the
        # flushed stacked tree against this and only re-read after a
        # write/commit/registration (engine.py's param_refreshes stat)
        self._gen = 0
        self._versions: Dict[str, int] = {}
        self._mask_cache: Any = None
        self.stats = {"stacks": 0, "unstacks": 0, "row_flushes": 0,
                      "commits": 0, "device_puts": 0, "checkouts": 0,
                      "mask_invalidations": 0, "capacity_growths": 0,
                      "slot_clones": 0}

    # -- registry / slot allocation ------------------------------------------
    @property
    def pids(self) -> List[int]:
        """Live pids in slot order (row s of a padded stacked tree belongs
        to ``pids``'s entry with slot s)."""
        with self._lock:
            return [pid for pid, _ in
                    sorted(self._slot_of.items(), key=lambda kv: kv[1])]

    def register(self, pid: int) -> int:
        """Allocate a slot for ``pid``. Reuses a freed slot when one
        exists (no shape change, no generation bump); grows capacity to
        the next power of two — and bumps the generation — only when
        full.

        The slot is allocated but NOT yet live in ``active_mask()``:
        activation happens on the first state write/clone into the slot,
        so a concurrent serve between register and the data landing
        still masks the slot off (it would otherwise read the previous
        occupant's stale row, or zeros)."""
        with self._lock:
            if pid in self._slot_of:
                raise ValueError(f"pid {pid} already registered")
            if not self._free:
                self._grow(_pow2_at_least(self.capacity + 1))
            slot = heapq.heappop(self._free)
            self._slot_of[pid] = slot
            # a reused slot's data in any in-flight full checkout now
            # belongs to the PREVIOUS occupant: the new owner's writes
            # must survive that checkout's commit
            for _, cohort_slots in self._checkout_cohort.values():
                cohort_slots.discard(slot)
            return slot

    def unregister(self, pid: int) -> int:
        """Free ``pid``'s slot: its rows are dropped, the slot goes on the
        free list, and the active mask flips to 0 there. The stale row
        inside any stacked tree stays (masked out) until a clone reuses
        the slot — so unregister never restacks, re-places, or changes
        ``generation()``."""
        with self._lock:
            slot = self._slot_of.pop(pid)   # KeyError for unknown pid
            heapq.heappush(self._free, slot)
            self._activated.discard(slot)
            for present in self._present.values():
                present.discard(slot)
            for rows in self._rows.values():
                rows.pop(slot, None)
            for dirty in self._dirty.values():
                dirty.discard(slot)
            self._invalidate_mask()
            return slot

    def _grow(self, new_capacity: int):
        """Capacity growth (lock held): pad every stacked tree with zero
        rows to the new power-of-two capacity. This is the ONE lifecycle
        operation that changes stacked shapes, so it bumps the
        generation (compiled programs over the old capacity are stale)."""
        old = self.capacity
        self.capacity = new_capacity
        for s in range(old, new_capacity):
            heapq.heappush(self._free, s)
        pad_n = new_capacity - old
        for key, st in list(self._stacked.items()):
            if not jax.tree.leaves(st):
                continue
            st = jax.tree.map(
                lambda x: jnp.concatenate(
                    [x, jnp.zeros((pad_n,) + x.shape[1:], x.dtype)]), st)
            self._stacked[key] = self._place(st)
        self._gen += 1
        self.stats["capacity_growths"] += 1
        _trace.instant("store.generation_bump", "store",
                       capacity=new_capacity, generation=self._gen)
        self._invalidate_mask()

    def slot_of(self, pid: int) -> int:
        with self._lock:
            return self._slot_of[pid]

    def live_count(self) -> int:
        with self._lock:
            return len(self._slot_of)

    def free_slots(self) -> int:
        with self._lock:
            return len(self._free)

    # -- active mask ---------------------------------------------------------
    def _invalidate_mask(self):
        self._mask_cache = None
        self.stats["mask_invalidations"] += 1

    def active_mask(self):
        """Device-resident ``(capacity,)`` float32 mask — 1.0 at
        *activated* slots (registered AND first data landed). Cached
        between lifecycle events (engines re-read it per request for
        free); replicated on the mesh so fused programs can reduce over
        live rows without a host round trip."""
        with self._lock:
            if self._mask_cache is None:
                m = np.zeros((self.capacity,), np.float32)
                for slot in self._activated:
                    m[slot] = 1.0
                arr = jnp.asarray(m)
                if self.placement.mesh is not None:
                    arr = jax.device_put(
                        arr, NamedSharding(self.placement.mesh, P()))
                self._mask_cache = arr
            return self._mask_cache

    def snapshot(self, key: str):
        """(version, active mask, canonical padded stacked tree) read
        atomically under the store lock — THE way for a concurrent
        consumer (serving, fused predict) to pair a mask with params:
        separate reads can interleave with churn and serve a slot whose
        data has not landed yet."""
        with self._lock:
            return ((self._gen, self._versions.get(key, 0)),
                    self.active_mask(), self._flush(key))

    def live_slots(self) -> List[int]:
        """Sorted activated slots (host-side; no device sync)."""
        with self._lock:
            return sorted(self._activated)

    def version(self, key: str):
        """Monotone token that changes whenever `key`'s canonical content
        could have (write/commit/discard/registration). Serving engines
        compare tokens instead of re-flushing per request."""
        with self._lock:
            return (self._gen, self._versions.get(key, 0))

    def generation(self) -> int:
        """The compile-invalidation component of ``version``: bumps ONLY
        on capacity growth or key-schema changes (a state key created for
        the first time). Within-capacity register/clone/kill are slot
        writes that leave it — and therefore every cached Program —
        untouched. ProgramCache keys carry this (not the per-key edit
        count: content edits must reuse compiled programs)."""
        with self._lock:
            return self._gen

    def _bump(self, key: str):
        self._versions[key] = self._versions.get(key, 0) + 1

    def keys(self) -> List[str]:
        """Every state key any particle holds (stacked or row form)."""
        with self._lock:
            return sorted(set(self._present) | set(self._stacked))

    def __len__(self) -> int:
        return len(self._slot_of)

    def _subset(self, pids: Optional[Sequence[int]]) -> Optional[List[int]]:
        """None -> the canonical capacity-padded path. An explicit pid
        list keeps the dense "index i <-> pids[i]" contract — it only
        collapses onto the canonical path when that is the same thing
        (full live set, slot order, no free slots)."""
        if pids is None:
            return None
        pids = list(pids)
        if pids == self.pids and len(pids) == self.capacity:
            return None
        missing = [p for p in pids if p not in self._slot_of]
        if missing:
            raise KeyError(f"unregistered pids {missing}")
        return pids

    def _mark_present(self, key: str, slot: int):
        present = self._present.get(key)
        if present is None:
            # key-schema change: a state key the store has never seen —
            # fused programs traced without it are stale in principle
            present = self._present[key] = set()
            if key not in self._stacked:
                self._gen += 1
        present.add(slot)
        if slot not in self._activated:
            # first data landing activates the slot in the mask
            self._activated.add(slot)
            self._invalidate_mask()

    def _demote_to_rows(self, key: str):
        """Replace the stacked form with per-particle rows (lock held).
        Needed before subset checkout/commit: a stale stacked tree must
        not shadow rows that are about to diverge from it."""
        st = self._stacked.get(key)
        if st is None:
            return
        rows = self._rows.setdefault(key, {})
        for slot in self._present.get(key, set()):
            if slot not in rows:
                rows[slot] = jax.tree.map(lambda x, s=slot: x[s], st)
                self.stats["unstacks"] += 1
        self._stacked.pop(key, None)
        self._dirty.pop(key, None)

    # -- per-particle views (unstack-on-read, dirty-tracked write-back) ------
    def has(self, key: str, pid: int) -> bool:
        with self._lock:
            slot = self._slot_of[pid]
            return slot in self._present.get(key, ())

    def _read_slot(self, key: str, slot: int):
        """Lazy view of one slot's entry (lock held): cached row if
        present, else sliced out of the canonical stacked tree."""
        rows = self._rows.setdefault(key, {})
        if slot in rows:
            return rows[slot]
        if slot not in self._present.get(key, ()):
            raise KeyError(f"store has no {key!r} in slot {slot}")
        st = self._stacked.get(key)
        if st is None:
            raise KeyError(f"store has no {key!r} in slot {slot}")
        row = jax.tree.map(lambda x: x[slot], st)
        rows[slot] = row
        self.stats["unstacks"] += 1
        return row

    def read(self, key: str, pid: int):
        """Lazy view of one particle's entry (stays on device)."""
        with self._lock:
            try:
                return self._read_slot(key, self._slot_of[pid])
            except KeyError:
                raise KeyError(f"store has no {key!r} for particle {pid}")

    def write(self, key: str, pid: int, tree):
        """Write-back from a view: the row shadows the stacked entry until
        the next flush."""
        with self._lock:
            slot = self._slot_of[pid]
            self._mark_present(key, slot)
            self._rows.setdefault(key, {})[slot] = tree
            self._dirty.setdefault(key, set()).add(slot)
            self._bump(key)

    def discard(self, key: str, pid: int):
        with self._lock:
            if key in self._stacked:   # stacked would no longer cover this pid
                raise ValueError(
                    f"cannot delete {key!r} of particle {pid}: the key is "
                    "stacked; delete is only supported for row-only keys")
            slot = self._slot_of[pid]
            rows = self._rows.get(key, {})
            if slot not in rows:
                raise KeyError(key)
            del rows[slot]
            self._present.get(key, set()).discard(slot)
            self._dirty.get(key, set()).discard(slot)
            self._bump(key)

    def keys_for(self, pid: int) -> List[str]:
        with self._lock:
            return [k for k in set(self._present) | set(self._stacked)
                    if self.has(k, pid)]

    # -- canonical stacked form ---------------------------------------------
    def _flush(self, key: str):
        """Make the capacity-padded stacked tree canonical for `key`
        (lock held). Free / absent slots are zero rows, gated off by the
        active mask inside fused programs."""
        st = self._stacked.get(key)
        dirty = self._dirty.get(key, set())
        cap = self.capacity
        lead = None if st is None else _leading_or_none(st)
        if st is not None and (lead is None or lead == cap) and not dirty:
            return st
        # slot-wise write-back only pays off while few rows are dirty:
        # each row write copies the whole stacked tree, so beyond ~half
        # the rows a single restack moves strictly less data
        if (st is not None and lead == cap
                and len(dirty) <= max(1, cap // 2)):
            on_mesh = self.placement.mesh is not None
            for slot in sorted(dirty):
                row = self._rows[key][slot]
                if on_mesh:
                    # eager per-leaf scatter preserves the NamedSharding
                    st = jax.tree.map(lambda s, r: s.at[slot].set(r),
                                      st, row)
                else:
                    st = self._row_write(st, row, slot)
            self.stats["row_flushes"] += len(dirty)
        else:
            # no canonical stacked (or capacity grew): full padded restack
            present = sorted(self._present.get(key, ()))
            if not present:
                raise KeyError(key)
            rows = {s: self._read_slot(key, s) for s in present}
            template = next((r for r in rows.values()
                             if jax.tree.leaves(r)), None)
            if template is None:      # key holds leafless trees (None)
                st = _stack_rows([rows[s] for s in present])
            else:
                pad = jax.tree.map(jnp.zeros_like, template)
                st = _stack_rows([rows.get(s, pad) for s in range(cap)])
            self.stats["stacks"] += 1
        st = self._place(st)
        self._stacked[key] = st
        self._dirty[key] = set()
        return st

    @staticmethod
    def _row_write(st, row, slot):
        """Write one row into the stacked tree as ONE fused call (the
        slot rides in as a traced scalar, so every slot shares the same
        executable): churn write-back costs one dispatch, not one eager
        scatter per leaf. Pure data movement — deliberately NOT a
        ProgramCache entry, so churn stays invisible to the compile
        stats the zero-recompile gates assert on."""
        return _ROW_WRITE(st, row, jnp.asarray(slot, jnp.int32))

    def _place(self, st):
        pl = self.placement
        if pl.mesh is None:
            return st
        want = pl.shardings(st)
        leaves = jax.tree.leaves(st)
        want_leaves = jax.tree.leaves(want)
        if all(getattr(x, "sharding", None) == s
               for x, s in zip(leaves, want_leaves)):
            return st                          # already placed (commit path)
        self.stats["device_puts"] += 1
        with _trace.span("store.h2d", "store", leaves=len(leaves)):
            return jax.device_put(st, want)

    def stacked(self, key: str, pids: Optional[Sequence[int]] = None):
        """The canonical capacity-padded stacked pytree (flushing any
        dirty views first); consumers combine it with ``active_mask()``.
        With an explicit pid subset, a fresh *dense* stack of those rows
        (index i -> pids[i]) that does not disturb the canonical form."""
        with self._lock:
            sub = self._subset(pids)
            if sub is None:
                return self._flush(key)
            st = _stack_rows([self.read(key, p) for p in sub])
            self.stats["stacks"] += 1
            return st

    def dense(self, key: str, pids: Optional[Sequence[int]] = None):
        """Live rows only, stacked dense in slot order (leading dim =
        live count, no padding, no mask needed) — for consumers that
        replicate rows host-side (SWAG serve-time sampling, checkpoint)."""
        with self._lock:
            pids = self.pids if pids is None else list(pids)
            rows = [self.read(key, p) for p in pids]
            self.stats["stacks"] += 1
            return _stack_rows(rows)

    def checkout(self, key: str, pids: Optional[Sequence[int]] = None):
        """Like ``stacked`` but transfers buffer ownership to the caller:
        the store drops its references so the fused loop may donate them
        to XLA. The caller must ``commit`` a result (or the original) back."""
        with _trace.span("store.checkout", "store", key=key), self._lock:
            sub = self._subset(pids)
            self.stats["checkouts"] += 1
            self._bump(key)
            if sub is None:
                st = self._flush(key)
                # remember which slots this checkout owns (and at what
                # capacity): a particle registered mid-run writes rows —
                # and may grow the store — that the matching commit must
                # not clobber or trip over
                self._checkout_cohort[key] = (
                    self.capacity, set(self._present.get(key, ())))
                self._stacked.pop(key, None)
                self._rows.pop(key, None)
                self._dirty.pop(key, None)
                return st
            # subset checkout: remaining particles keep their rows
            for p in sub:          # materialize before popping anything
                self.read(key, p)
            self._demote_to_rows(key)
            rows = self._rows.setdefault(key, {})
            out = [rows.pop(self._slot_of[p]) for p in sub]
            dirty = self._dirty.get(key, set())
            for p in sub:
                dirty.discard(self._slot_of[p])
            self.stats["stacks"] += 1
            return _stack_rows(out)

    def commit(self, key: str, stacked, pids: Optional[Sequence[int]] = None):
        """A fused program's output becomes canonical; views re-derive
        lazily (this is the *only* write-back of a multi-epoch fused run).
        Full commits carry the capacity-padded shape; with a pid subset,
        row i of `stacked` becomes pids[i]'s state."""
        with _trace.span("store.commit", "store", key=key), self._lock:
            sub = self._subset(pids)
            cohort = None if sub is not None \
                else self._checkout_cohort.pop(key, None)
            if sub is not None:
                n = len(sub)
            elif cohort is not None:
                n = cohort[0]      # capacity at checkout time: the store
                #                    may have grown under the fused run
            else:
                n = self.capacity
            if _leading_dim(stacked) != n:
                raise ValueError(
                    f"stacked {key!r} has leading dim "
                    f"{_leading_dim(stacked)}, expected {n}")
            self.stats["commits"] += 1
            self._bump(key)
            if sub is None:
                if key not in self._present and key not in self._stacked:
                    self._gen += 1     # key-schema change
                if cohort is None:
                    # direct commit (no prior checkout): the tree speaks
                    # for every live slot, with data landing now
                    self._stacked[key] = stacked
                    for slot in self._slot_of.values():
                        self._mark_present(key, slot)
                    self._rows.pop(key, None)
                    self._dirty.pop(key, None)
                    return
                # checkout/commit round trip: the committed tree covers
                # the cohort checked out — pad it up if the store grew
                # mid-run; rows written since (a particle created
                # mid-run) stay as dirty shadows over it
                co_cap, co_slots = cohort
                if co_cap < self.capacity:
                    pad_n = self.capacity - co_cap
                    stacked = jax.tree.map(
                        lambda x: jnp.concatenate(
                            [x, jnp.zeros((pad_n,) + x.shape[1:],
                                          x.dtype)]), stacked)
                self._stacked[key] = stacked
                present = self._present.setdefault(key, set())
                live = set(self._slot_of.values())
                present |= co_slots & live
                rows = self._rows.get(key, {})
                dirty = self._dirty.get(key, set())
                for slot in co_slots:
                    rows.pop(slot, None)
                    dirty.discard(slot)
                return
            self._demote_to_rows(key)
            rows = self._rows.setdefault(key, {})
            for j, p in enumerate(sub):
                slot = self._slot_of[p]
                self._mark_present(key, slot)
                rows[slot] = jax.tree.map(lambda x, j=j: x[j], stacked)
            self.stats["unstacks"] += len(sub)

    # -- fused slot cloning (the p_clone fast path) --------------------------
    def clone_slot(self, key: str, src_pid: int, dst_pid: int,
                   jitter: float = 0.0, rng=None, prefer_row: bool = False):
        """Copy one state key from ``src_pid``'s slot into ``dst_pid``'s,
        entirely inside the canonical stacked tree: ONE fused
        slice+update dispatch (slots ride in as traced scalars, params
        optionally jittered in the same program) instead of a per-leaf
        unstack/restack round trip. The updated tree stays canonical —
        the next serving flush is a no-op.

        ``prefer_row=True`` takes the lazy row-copy path instead (a dirty
        row flushed on next use): right for cold keys like opt state,
        whose fused copy would pay a full stacked-tree copy that nothing
        is about to read. Leafless trees (``grads=None``) and mesh
        placements also use the row path — under a mesh the eager
        per-leaf flush preserves NamedShardings exactly."""
        with self._lock:
            src = self._slot_of[src_pid]
            dst = self._slot_of[dst_pid]
            if key in self._checkout_cohort:
                # the source data was moved out (and likely donated) by
                # a fused run — fail with the real reason, not a
                # missing-data KeyError
                raise RuntimeError(
                    f"{key!r} is checked out by an in-flight fused run; "
                    "commit it back before cloning")
            if src not in self._present.get(key, ()):
                raise KeyError(f"store has no {key!r} for particle "
                               f"{src_pid}")
            fused = self.placement.mesh is None and not prefer_row
            st = None
            if fused:
                try:
                    st = self._flush(key)
                except KeyError:
                    st = None
            if st is None or not jax.tree.leaves(st):
                # row-reference copy (still lazy: no device work here
                # beyond the jitter, which only params paths request)
                row = self._read_slot(key, src)
                if jitter and rng is not None and jax.tree.leaves(row):
                    leaves, tdef = jax.tree.flatten(row)
                    keys = jax.random.split(rng, len(leaves))
                    row = tdef.unflatten([
                        l + jitter * jax.random.normal(k, l.shape, l.dtype)
                        if jnp.issubdtype(l.dtype, jnp.floating) else l
                        for l, k in zip(leaves, keys)])
                self._mark_present(key, dst)
                self._rows.setdefault(key, {})[dst] = row
                self._dirty.setdefault(key, set()).add(dst)
            else:
                if jitter and rng is not None:
                    st = _COPY_SLOT_JITTER(st, src, dst,
                                           jnp.float32(jitter), rng)
                else:
                    st = _COPY_SLOT(st, src, dst)
                self._stacked[key] = st
                self._mark_present(key, dst)
                rows = self._rows.get(key)
                if rows:
                    rows.pop(dst, None)
                self._dirty.get(key, set()).discard(dst)
                self.stats["slot_clones"] += 1
            self._bump(key)

    # -- lifecycle introspection / re-placement ------------------------------
    def rebalance(self):
        """Re-place every key's canonical stacked form against the current
        placement plan (flush, then an explicit ``_place`` even for clean
        trees — ``_flush`` alone skips placement on its early return) and
        rebuild the mask — the store half of ``pd.p_rebalance()``."""
        with self._lock:
            for key in self.keys():
                try:
                    st = self._flush(key)
                except (KeyError, ValueError):
                    continue
                if jax.tree.leaves(st):
                    self._stacked[key] = self._place(st)
            self._invalidate_mask()

    def per_device_bytes(self, key: str = "params") -> int:
        """Bytes of ``key``'s canonical state resident on ONE device
        under the current placement — the headline number 2D placement
        moves (a model axis of size m divides a replicated particle's
        footprint by ~m, modulo non-divisible leaves). Reads whatever
        form exists without flushing, placing, or bumping stats
        counters; 0 when the store holds nothing for the key."""
        def leaf_bytes(x):
            sh = getattr(x, "sharding", None)
            if sh is not None and hasattr(sh, "shard_shape"):
                try:
                    shard = sh.shard_shape(x.shape)
                except Exception:
                    return int(x.nbytes)
                return int(np.prod(shard, dtype=np.int64)
                           * np.dtype(x.dtype).itemsize)
            return int(getattr(x, "nbytes", 0))
        with self._lock:
            tree = self._stacked.get(key)
            if tree is None:
                rows = self._rows.get(key, {})
                return int(sum(leaf_bytes(l) for row in rows.values()
                               for l in jax.tree.leaves(row)))
            return int(sum(leaf_bytes(l) for l in jax.tree.leaves(tree)))

    def per_particle_bytes(self, key: str = "params") -> int:
        """Whole-ensemble bytes of ``key`` (all devices, actual leaf
        dtypes) divided by capacity — the per-particle HBM footprint the
        precision ladder moves (bench_precision's headline). 0 when the
        store holds nothing for the key."""
        with self._lock:
            tree = self._stacked.get(key)
            if tree is None:
                rows = self._rows.get(key, {})
                if not rows:
                    return 0
                row = next(iter(rows.values()))
                return int(sum(int(getattr(l, "nbytes", 0))
                               for l in jax.tree.leaves(row)))
            total = sum(int(getattr(l, "nbytes", 0))
                        for l in jax.tree.leaves(tree))
            return int(total // max(self.capacity, 1))

    def key_dtypes(self, key: str = "params") -> Dict[str, int]:
        """{dtype name: leaf count} of ``key``'s resident state — the
        dtype surface obs gauges and checkpoints record (a bf16 store
        reports {'bfloat16': ...}, masters-only fp32 {'float32': ...})."""
        out: Dict[str, int] = {}
        with self._lock:
            tree = self._stacked.get(key)
            if tree is None:
                rows = self._rows.get(key, {})
                tree = next(iter(rows.values())) if rows else None
            for leaf in jax.tree.leaves(tree) if tree is not None else ():
                name = np.dtype(leaf.dtype).name if hasattr(leaf, "dtype") \
                    else type(leaf).__name__
                out[name] = out.get(name, 0) + 1
        return out

    def lifecycle_stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "capacity": self.capacity,
                "live": len(self._slot_of),
                "free_slots": len(self._free),
                "generation": self._gen,
                "mask_invalidations": self.stats["mask_invalidations"],
                "capacity_growths": self.stats["capacity_growths"],
            }

    def snapshot_stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.stats)


# ---------------------------------------------------------------------------
# per-particle mapping facade (what Particle.state is)
# ---------------------------------------------------------------------------

class StoreState:
    """Mutable-mapping view of one particle's slice of a ParticleStore.

    ``particle.state["params"]`` reads/writes route through the store's
    view protocol, so the NEL backend and the fused backend observe one
    source of truth — there is no duplicated per-particle state dict."""

    def __init__(self, store: ParticleStore, pid: int):
        self.store = store
        self.pid = pid

    def __getitem__(self, key: str):
        return self.store.read(key, self.pid)

    def __setitem__(self, key: str, value):
        self.store.write(key, self.pid, value)

    def __delitem__(self, key: str):
        self.store.discard(key, self.pid)

    def __contains__(self, key: str) -> bool:
        return self.store.has(key, self.pid)

    def get(self, key: str, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def keys(self):
        return self.store.keys_for(self.pid)

    def __iter__(self):
        return iter(self.keys())

    def __len__(self) -> int:
        return len(self.keys())

    def __repr__(self) -> str:
        return f"StoreState(pid={self.pid}, keys={sorted(self.keys())})"
