"""ParticleStore: mesh-sharded particle state as the single source of truth.

Both runtime backends read and write the same state through this store
(Tran et al. 2018's design point: one program representation, placement
decided by shardings):

  * canonical form — one *stacked* pytree per state key ("params",
    "opt_state", "swag", ...) with a leading particle axis, placed on a
    device mesh via ``NamedSharding`` derived from
    ``sharding/rules.tree_shardings(..., particle_axis=...)``;
  * derived form — lazy per-particle *views* (unstack-on-read: a view is
    just ``leaf[i]``, staying on device until consumed) with dirty-tracked
    write-back, which is what the NEL backend's ``Particle.state`` maps
    onto.

Consistency protocol (all transitions under one lock):

  write_view(pid)  -> row cached + marked dirty; the stale stacked row is
                      shadowed (view reads hit the row cache first)
  stacked()        -> flush: dirty rows written into the stacked tree
                      (row-wise ``.at[i].set``), or a full restack when no
                      canonical stacked exists / the particle set grew
  checkout()       -> flush + *move* ownership to the caller: the fused
                      epoch loop donates these buffers to XLA every step
                      (``donate_argnums``), so the store must not retain a
                      reference to memory that is about to be invalidated
  commit(stacked)  -> the fused result becomes canonical; view caches are
                      invalidated and re-derived lazily on next read

``stats`` counts every materialization (stacks, unstacks, row flushes,
commits, device placements) so tests can assert that a multi-epoch fused
run touches the host exactly zero times per epoch: one checkout before the
loop, one commit after, nothing in between.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..sharding import rules


# ---------------------------------------------------------------------------
# placement plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Placement:
    """Where particle state lives: a mesh + which mesh axis carries the
    particle dimension. ``mesh=None`` (the default) keeps state wherever
    jax puts it — the single-device fast path with no resharding cost."""
    mesh: Any = None
    particle_axis: Optional[str] = "data"
    mode: str = "tp"  # within-particle sharding rules mode (sharding/rules)

    @staticmethod
    def auto(particle_axis: str = "data", mode: str = "tp") -> "Placement":
        """Mesh over all local devices, model axis 1 (particle-parallel)."""
        from ..launch.mesh import make_bench_mesh
        n = len(jax.devices())
        if n <= 1:
            return Placement(mesh=None)
        return Placement(mesh=make_bench_mesh(n), particle_axis=particle_axis,
                         mode=mode)

    # -- sharding derivation -------------------------------------------------
    def shardings(self, stacked_tree):
        """NamedSharding tree for a stacked state pytree (leading particle
        axis -> particle_axis, trailing dims -> sharding/rules)."""
        if self.mesh is None:
            return None
        return rules.tree_shardings(self.mesh, stacked_tree, self.mode,
                                    self.particle_axis)

    def replicated(self, tree):
        """Fully-replicated shardings (batches: every particle sees the
        same data under deep-ensemble semantics)."""
        if self.mesh is None:
            return None
        sh = NamedSharding(self.mesh, P())
        return jax.tree.map(lambda _: sh, tree)

    def _axis_fits(self, n: int, axis: Optional[str]) -> Optional[str]:
        if self.mesh is None or axis is None:
            return None
        return axis if n % self.mesh.shape[axis] == 0 else None

    def vector(self, n: int):
        """Sharding for per-particle scalars stacked to (n,) (losses)."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh,
                             P(self._axis_fits(n, self.particle_axis)))

    def matrix(self, n: int, d: int):
        """Sharding for the flattened (n, D) particle-parameter matrix
        (SVGD): particles over the particle axis, D over `model`."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh,
                             P(self._axis_fits(n, self.particle_axis),
                               self._axis_fits(d, "model")))

    def gathered_matrix(self, d: int):
        """Sharding of the (n, D) matrix *after* the all-gather over the
        particle axis: every device holds all particles' rows (the SVGD
        kernel matrix needs all-to-all), D still sharded over `model`."""
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(None, self._axis_fits(d, "model")))

    def spmd_axis(self, n: int) -> Optional[str]:
        """vmap spmd_axis_name when the particle count divides the mesh
        axis — this is what lets GSPMD distribute particles."""
        return self._axis_fits(n, self.particle_axis)


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

def _stack_rows(rows):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *rows)


def _leading_dim(tree) -> int:
    return jax.tree.leaves(tree)[0].shape[0]


class ParticleStore:
    """Canonical holder of all per-particle state of one PushDistribution."""

    def __init__(self, placement: Optional[Placement] = None):
        self.placement = placement or Placement()
        self.pids: List[int] = []
        self._index: Dict[int, int] = {}
        self._stacked: Dict[str, Any] = {}        # key -> stacked pytree
        self._rows: Dict[str, Dict[int, Any]] = {}  # key -> {idx: row tree}
        self._dirty: Dict[str, Set[int]] = {}     # key -> idx newer than stacked
        self._lock = threading.RLock()
        # (generation, per-key edit count): serving engines cache the
        # flushed stacked tree against this and only re-read after a
        # write/commit/registration (engine.py's param_refreshes stat)
        self._gen = 0
        self._versions: Dict[str, int] = {}
        self.stats = {"stacks": 0, "unstacks": 0, "row_flushes": 0,
                      "commits": 0, "device_puts": 0, "checkouts": 0}

    # -- registry ------------------------------------------------------------
    def register(self, pid: int) -> int:
        with self._lock:
            if pid in self._index:
                raise ValueError(f"pid {pid} already registered")
            self._index[pid] = len(self.pids)
            self.pids.append(pid)
            self._gen += 1          # particle set changed: all keys stale
            return self._index[pid]

    def version(self, key: str):
        """Monotone token that changes whenever `key`'s canonical content
        could have (write/commit/discard/registration). Serving engines
        compare tokens instead of re-flushing per request."""
        with self._lock:
            return (self._gen, self._versions.get(key, 0))

    def generation(self) -> int:
        """The particle-set generation alone: the component of ``version``
        that ONLY changes when particles are registered. ProgramCache keys
        carry this (not the per-key edit count — content edits must reuse
        compiled programs, shape-changing registrations must not)."""
        with self._lock:
            return self._gen

    def _bump(self, key: str):
        self._versions[key] = self._versions.get(key, 0) + 1

    def keys(self) -> List[str]:
        """Every state key any particle holds (stacked or row form)."""
        with self._lock:
            return sorted(set(self._rows) | set(self._stacked))

    def __len__(self) -> int:
        return len(self.pids)

    def _subset(self, pids: Optional[Sequence[int]]) -> Optional[List[int]]:
        """None/full set -> None (canonical path); otherwise the explicit
        subset (any order), validated against the registry."""
        if pids is None:
            return None
        pids = list(pids)
        if pids == self.pids:
            return None
        missing = [p for p in pids if p not in self._index]
        if missing:
            raise KeyError(f"unregistered pids {missing}")
        return pids

    def _demote_to_rows(self, key: str):
        """Replace the stacked form with per-particle rows (lock held).
        Needed before subset checkout/commit: a stale stacked tree must
        not shadow rows that are about to diverge from it."""
        st = self._stacked.get(key)
        if st is None:
            return
        rows = self._rows.setdefault(key, {})
        for i in range(_leading_dim(st)):
            if i not in rows:
                rows[i] = jax.tree.map(lambda x, i=i: x[i], st)
                self.stats["unstacks"] += 1
        self._stacked.pop(key, None)
        self._dirty.pop(key, None)

    # -- per-particle views (unstack-on-read, dirty-tracked write-back) ------
    def has(self, key: str, pid: int) -> bool:
        with self._lock:
            idx = self._index[pid]
            if idx in self._rows.get(key, ()):
                return True
            st = self._stacked.get(key)
            return st is not None and idx < _leading_dim(st)

    def read(self, key: str, pid: int):
        """Lazy view of one particle's entry: cached row if present, else
        sliced out of the canonical stacked tree (stays on device)."""
        with self._lock:
            idx = self._index[pid]
            rows = self._rows.setdefault(key, {})
            if idx in rows:
                return rows[idx]
            st = self._stacked.get(key)
            if st is None or idx >= _leading_dim(st):
                raise KeyError(f"store has no {key!r} for particle {pid}")
            row = jax.tree.map(lambda x: x[idx], st)
            rows[idx] = row
            self.stats["unstacks"] += 1
            return row

    def write(self, key: str, pid: int, tree):
        """Write-back from a view: the row shadows the stacked entry until
        the next flush."""
        with self._lock:
            idx = self._index[pid]
            self._rows.setdefault(key, {})[idx] = tree
            self._dirty.setdefault(key, set()).add(idx)
            self._bump(key)

    def discard(self, key: str, pid: int):
        with self._lock:
            if key in self._stacked:   # stacked would no longer cover this pid
                raise ValueError(
                    f"cannot delete {key!r} of particle {pid}: the key is "
                    "stacked; delete is only supported for row-only keys")
            idx = self._index[pid]
            rows = self._rows.get(key, {})
            if idx not in rows:
                raise KeyError(key)
            del rows[idx]
            self._dirty.get(key, set()).discard(idx)
            self._bump(key)

    def keys_for(self, pid: int) -> List[str]:
        with self._lock:
            return [k for k in set(self._rows) | set(self._stacked)
                    if self.has(k, pid)]

    # -- canonical stacked form ---------------------------------------------
    def _flush(self, key: str):
        """Make the stacked tree canonical for `key` (lock held)."""
        st = self._stacked.get(key)
        dirty = self._dirty.get(key, set())
        n = len(self.pids)
        if st is not None and _leading_dim(st) == n and not dirty:
            return st
        # row-wise write-back only pays off while few rows are dirty: each
        # .at[i].set copies the whole stacked tree, so beyond ~half the
        # rows a single restack moves strictly less data
        if (st is not None and _leading_dim(st) == n
                and len(dirty) <= max(1, n // 2)):
            for idx in sorted(dirty):
                row = self._rows[key][idx]
                st = jax.tree.map(lambda s, r: s.at[idx].set(r), st, row)
            self.stats["row_flushes"] += len(dirty)
        else:
            # no canonical stacked (or the particle set grew): full restack
            rows = [self.read(key, pid) for pid in self.pids]
            st = _stack_rows(rows)
            self.stats["stacks"] += 1
        st = self._place(st)
        self._stacked[key] = st
        self._dirty[key] = set()
        return st

    def _place(self, st):
        pl = self.placement
        if pl.mesh is None:
            return st
        want = pl.shardings(st)
        leaves = jax.tree.leaves(st)
        want_leaves = jax.tree.leaves(want)
        if all(getattr(x, "sharding", None) == s
               for x, s in zip(leaves, want_leaves)):
            return st                          # already placed (commit path)
        self.stats["device_puts"] += 1
        return jax.device_put(st, want)

    def stacked(self, key: str, pids: Optional[Sequence[int]] = None):
        """The canonical stacked pytree (flushing any dirty views first).
        With an explicit pid subset, a fresh stack of those rows (index
        i -> pids[i]) that does not disturb the canonical form."""
        with self._lock:
            sub = self._subset(pids)
            if sub is None:
                return self._flush(key)
            st = _stack_rows([self.read(key, p) for p in sub])
            self.stats["stacks"] += 1
            return st

    def checkout(self, key: str, pids: Optional[Sequence[int]] = None):
        """Like ``stacked`` but transfers buffer ownership to the caller:
        the store drops its references so the fused loop may donate them
        to XLA. The caller must ``commit`` a result (or the original) back."""
        with self._lock:
            sub = self._subset(pids)
            self.stats["checkouts"] += 1
            self._bump(key)
            if sub is None:
                st = self._flush(key)
                self._stacked.pop(key, None)
                self._rows.pop(key, None)
                self._dirty.pop(key, None)
                return st
            # subset checkout: remaining particles keep their rows
            for p in sub:          # materialize before popping anything
                self.read(key, p)
            self._demote_to_rows(key)
            rows = self._rows.setdefault(key, {})
            out = [rows.pop(self._index[p]) for p in sub]
            dirty = self._dirty.get(key, set())
            for p in sub:
                dirty.discard(self._index[p])
            self.stats["stacks"] += 1
            return _stack_rows(out)

    def commit(self, key: str, stacked, pids: Optional[Sequence[int]] = None):
        """A fused program's output becomes canonical; views re-derive
        lazily (this is the *only* write-back of a multi-epoch fused run).
        With a pid subset, row i of `stacked` becomes pids[i]'s state."""
        with self._lock:
            sub = self._subset(pids)
            n = len(self.pids) if sub is None else len(sub)
            if _leading_dim(stacked) != n:
                raise ValueError(
                    f"stacked {key!r} has leading dim "
                    f"{_leading_dim(stacked)}, expected {n}")
            self.stats["commits"] += 1
            self._bump(key)
            if sub is None:
                self._stacked[key] = stacked
                self._rows.pop(key, None)
                self._dirty.pop(key, None)
                return
            self._demote_to_rows(key)
            rows = self._rows.setdefault(key, {})
            for j, p in enumerate(sub):
                rows[self._index[p]] = jax.tree.map(
                    lambda x, j=j: x[j], stacked)
            self.stats["unstacks"] += len(sub)

    def snapshot_stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.stats)


# ---------------------------------------------------------------------------
# per-particle mapping facade (what Particle.state is)
# ---------------------------------------------------------------------------

class StoreState:
    """Mutable-mapping view of one particle's slice of a ParticleStore.

    ``particle.state["params"]`` reads/writes route through the store's
    view protocol, so the NEL backend and the fused backend observe one
    source of truth — there is no duplicated per-particle state dict."""

    def __init__(self, store: ParticleStore, pid: int):
        self.store = store
        self.pid = pid

    def __getitem__(self, key: str):
        return self.store.read(key, self.pid)

    def __setitem__(self, key: str, value):
        self.store.write(key, self.pid, value)

    def __delitem__(self, key: str):
        self.store.discard(key, self.pid)

    def __contains__(self, key: str) -> bool:
        return self.store.has(key, self.pid)

    def get(self, key: str, default=None):
        try:
            return self[key]
        except KeyError:
            return default

    def keys(self):
        return self.store.keys_for(self.pid)

    def __iter__(self):
        return iter(self.keys())

    def __len__(self) -> int:
        return len(self.keys())

    def __repr__(self) -> str:
        return f"StoreState(pid={self.pid}, keys={sorted(self.keys())})"
