"""The particle abstraction (paper §3.2).

A particle wraps a NN with (1) local state — parameters, optimizer state,
user state —, (2) its own logical thread of execution (dispatches run on
its device's NEL worker), and (3) message passing: a receive dictionary
mapping messages to locally-defined functions, plus send/get primitives
returning PFutures.

The paper's Fig. 1 `_gather` runs on this API verbatim (modulo torch->jax):

    futures  = {pid: particle.get(pid) for pid in other_particles}
    views    = {pid: fut.wait() for pid, fut in futures.items()}
    views[other].view()
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from .messages import ParticleView, PFuture, snapshot
from .store import ParticleStore, StoreState


class ParticleModule:
    """Bundle of pure functions defining the NN a particle wraps.

    init(rng) -> params ; loss(params, batch) -> (scalar, metrics) ;
    forward(params, batch) -> outputs.

    The per-particle step/forward programs compile through the shared
    runtime layer (``repro.runtime.jit_program``), keyed on the loss /
    forward function identity — so every particle of a PD (and every PD
    over the same module) shares ONE compiled program, and NEL compiles
    show up in the same ProgramCache stats as fused and serving ones
    (``PushDistribution.stats()``). The Program is fetched from the
    cache once and memoized on the module: its plain-jit wrapper is
    shape-polymorphic (no shardings, no donation), so the per-dispatch
    hot path after the first call is a single attribute check — no
    process-wide lock, no key construction (the PR-1 executor's
    dispatch-throughput bar depends on this staying cheap).
    """

    def __init__(self, init: Callable, loss: Callable, forward: Callable,
                 cfg: Any = None):
        self.init = init
        self.loss = loss
        self.forward = forward
        self.cfg = cfg
        self._vag = lambda p, b: jax.value_and_grad(
            lambda pp: loss(pp, b)[0])(p)
        self._vag_progs: Dict[Optional[str], Any] = {}
        self._fwd_prog = None
        self._loss_prog = None

    def _value_and_grad(self, params, batch, compute_dtype=None):
        """Jitted value_and_grad; ``compute_dtype`` applies the same
        master/compute split as the fused path (core.functional) so NEL
        and compiled backends stay in numerical agreement under one
        precision policy: cast params+batch to the compute dtype inside
        the trace, cast grads back per-leaf, surface the loss fp32. The
        dtype is part of the program key (None keeps the original key)."""
        tok = str(jnp.dtype(compute_dtype)) if compute_dtype is not None \
            else None
        prog = self._vag_progs.get(tok)
        if prog is None:
            from ..runtime import ident, jit_program
            if compute_dtype is None:
                fn = self._vag
                key = ("nel_value_and_grad", ident(self.loss))
            else:
                from .precision import cast_floats

                def fn(p, b):
                    l, g = self._vag(cast_floats(p, compute_dtype),
                                     cast_floats(b, compute_dtype))
                    g = jax.tree.map(
                        lambda gg, pp: gg.astype(pp.dtype), g, p)
                    return l.astype(jnp.float32), g
                key = ("nel_value_and_grad", ident(self.loss), tok)
            prog = jit_program("nel_value_and_grad", key, fn,
                               (params, batch))
            self._vag_progs[tok] = prog
        return prog(params, batch)

    def _forward(self, params, batch):
        if self._fwd_prog is None:
            from ..runtime import ident, jit_program
            self._fwd_prog = jit_program(
                "nel_forward", ("nel_forward", ident(self.forward)),
                self.forward, (params, batch))
        return self._fwd_prog(params, batch)

    def _loss_value(self, params, batch):
        """Jitted scalar loss (no grads) through the shared cache — one
        compiled program for all particles; lifecycle weight policies
        evaluate this per member without retracing the forward."""
        if self._loss_prog is None:
            from ..runtime import ident, jit_program
            self._loss_prog = jit_program(
                "nel_loss", ("nel_loss", ident(self.loss)),
                lambda p, b: self.loss(p, b)[0], (params, batch))
        return self._loss_prog(params, batch)


class Particle:
    def __init__(self, pid: int, nel, module: ParticleModule, params,
                 optimizer=None, opt_state=None, state: Optional[dict] = None,
                 store: Optional[ParticleStore] = None,
                 write_state: bool = True):
        self.pid = pid
        self.nel = nel
        self.module = module
        self.optimizer = optimizer
        # All per-particle state lives in the (possibly shared) ParticleStore;
        # ``state`` is this particle's mapping view of it (store.py). A
        # standalone particle gets a private store so the API is unchanged.
        # ``write_state=False`` attaches to state the caller already put
        # in the store (p_clone's fused slot copy).
        if store is None:
            store = ParticleStore()
            store.register(pid)
        self.store = store
        self.state: StoreState = StoreState(store, pid)
        if write_state:
            for k, v in (state or {}).items():
                self.state[k] = v
            self.state["params"] = params
            self.state["opt_state"] = opt_state
            self.state["grads"] = None
        self.receive: Dict[str, Callable] = {}

    # -- local state access ------------------------------------------------
    def parameters(self):
        return self.state["params"]

    def gradients(self):
        return self.state["grads"]

    # -- registry ------------------------------------------------------------
    def particle_ids(self) -> List[int]:
        return self.nel.particle_ids()

    def on(self, msg: str, fn: Callable):
        self.receive[msg] = fn

    # -- messaging (actor + async-await) ------------------------------------
    def send(self, pid: int, msg: str, *args, **kwargs) -> PFuture:
        """Trigger `msg`'s handler on particle `pid` (its own timeline)."""
        target = self.nel.particle(pid)
        if msg not in target.receive:
            raise KeyError(f"particle {pid} has no handler for {msg!r}")
        if self.nel._device_of[pid] != self.nel._device_of[self.pid]:
            self.nel._bump("xdev_transfers")
        fn = target.receive[msg]
        return self.nel.dispatch(pid, fn, target, *args, **kwargs)

    def get(self, pid: int) -> PFuture:
        """Asynchronously snapshot particle `pid`'s parameters (read-only)."""
        target = self.nel.particle(pid)
        if self.nel._device_of[pid] != self.nel._device_of[self.pid]:
            self.nel._bump("xdev_transfers")

        def grab(_t):
            return ParticleView(pid, snapshot(_t.state["params"]),
                                None if _t.state["grads"] is None
                                else snapshot(_t.state["grads"]))

        # lock-free read: runs on the shared pool, never queues behind the
        # target device's compute (paper §4.2 — same-device communication
        # "can be eliminated")
        return self.nel.dispatch(pid, grab, target, lightweight=True)

    # -- local NN computations (dispatched to this particle's device) -------
    def step(self, batch) -> PFuture:
        """Forward+backward+optimizer update on this particle's device."""

        def do(_self):
            loss, grads = _self.module._value_and_grad(
                _self.state["params"], batch,
                getattr(_self, "compute_dtype", None))
            _self.state["grads"] = grads
            if _self.optimizer is not None:
                p, s = _self.optimizer.update(_self.state["params"], grads,
                                              _self.state["opt_state"])
                _self.state["params"], _self.state["opt_state"] = p, s
            return loss

        return self.nel.dispatch(self.pid, do, self, needs_device=True)

    def grad(self, batch) -> PFuture:
        """Backward only: stash grads, do not update params (SVGD phase 1)."""

        def do(_self):
            loss, grads = _self.module._value_and_grad(
                _self.state["params"], batch,
                getattr(_self, "compute_dtype", None))
            _self.state["grads"] = grads
            return loss

        return self.nel.dispatch(self.pid, do, self, needs_device=True)

    def forward(self, batch) -> PFuture:
        def do(_self):
            return _self.module._forward(_self.state["params"], batch)

        return self.nel.dispatch(self.pid, do, self, needs_device=True)

    def apply_update(self, update, lr: float) -> PFuture:
        """theta <- theta - lr * update (SVGD follow; paper Fig. 6)."""

        def do(_self):
            _self.state["params"] = jax.tree.map(
                lambda p, u: p - lr * u.astype(p.dtype),
                _self.state["params"], update)
            return None

        return self.nel.dispatch(self.pid, do, self, needs_device=True)
