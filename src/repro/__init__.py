"""repro: Push (concurrent probabilistic programming for BDL) in JAX.

Layers: core (particle abstraction) / runtime (plan/compile/execute
layer: ProgramSpec + process-wide ProgramCache + NelRuntime/
CompiledRuntime) / bdl (inference algorithms) / serve (batched
posterior-predictive serving) / models+configs (architecture zoo) /
optim / data / checkpoint / kernels (Pallas TPU) / sharding+launch
(multi-pod distribution) / obs (tracing, metrics, cost profiling,
Perfetto + Prometheus export).
"""
__version__ = "1.0.0"
