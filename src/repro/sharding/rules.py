"""Parameter-sharding rules: path patterns -> PartitionSpec tails.

A rule maps the *trailing* dims of a parameter (the dims the layer math
sees); leading stacking dims (lax.scan unit axis, particle axis) are
padded with None / the particle axis automatically.

Two modes:
  "tp"      tensor-parallel only (particle-parallel archs: the `data` mesh
            axis carries particles, so within-particle sharding uses only
            `model`)
  "fsdp_tp" fully-sharded + tensor-parallel (P=1 giants: weights sharded
            over `data` *and* `model` — the paper's "single particle
            across devices" future-work item)
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# (regex on normalized path, tp tail, fsdp_tp tail)
_RULES = [
    (r"embed$",                      ("model", None),        ("model", "data")),
    (r"lm_head/w$",                  (None, "model"),        ("data", "model")),
    (r"(attn|xattn)/(wq|wk|wv)/w$",  (None, "model"),        ("data", "model")),
    (r"(attn|xattn)/(wq|wk|wv)/b$",  ("model",),             ("model",)),
    (r"(attn|xattn)/wo/w$",          ("model", None),        ("model", "data")),
    (r"mlp/(wi|wg|w1)/w$",           (None, "model"),        ("data", "model")),
    (r"mlp/w1/b$",                   ("model",),             ("model",)),
    (r"mlp/(wo|w2)/w$",              ("model", None),        ("model", "data")),
    (r"moe/router/w$",               (None, None),           (None, None)),
    (r"moe/(wi|wg)$",                ("model", None, None),  ("model", "data", None)),
    (r"moe/wo$",                     ("model", None, None),  ("model", None, "data")),
    (r"moe/shared/(wi|wg)/w$",       (None, "model"),        ("data", "model")),
    (r"moe/shared/wo/w$",            ("model", None),        ("model", "data")),
    (r"time_mix/(wr|wk|wv|wg)/w$",   (None, "model"),        ("data", "model")),
    (r"time_mix/wo/w$",              ("model", None),        ("model", "data")),
    (r"channel_mix/wk/w$",           (None, "model"),        ("data", "model")),
    (r"channel_mix/wv/w$",           ("model", None),        ("model", "data")),
    (r"channel_mix/wr/w$",           (None, None),           ("data", None)),
    (r"in_proj/w$",                  (None, "model"),        ("data", "model")),
    (r"out_proj/w$",                 (None, None),           (None, "data")),
    (r"patch/w$",                    (None, None),           (None, None)),
    (r"head/w$",                     (None, None),           (None, None)),
    # KV caches / paged KV pools (store keys "kv", "kv_pages"): leaves are
    # literally "k" / "v" (param leaves end in /w, /b — no collision) with
    # trailing dims (..., seq-or-page, n_kv_heads, head_dim). Heads ride
    # the model axis alongside the wk/wv column split, so the paged-decode
    # block-table gathers never cross the model axis.
    (r"(^|/)(k|v)$",                 (None, None, "model", None),
     (None, None, "model", None)),
]
_COMPILED = [(re.compile(pat), tp, ftp) for pat, tp, ftp in _RULES]


def normalize_path(path) -> str:
    """jax key path -> 'units/0/attn/wq/w'."""
    s = jax.tree_util.keystr(path)
    s = re.sub(r"\]\[", "/", s)
    s = s.strip("[]").replace("'", "")
    return s


# int8 serve copies (core.precision.quantize_int8) expand a weight leaf
# ".../w" into a {"q", "s"} pack — paths ".../w/q" and ".../w/s". Both
# carry the weight's rule: q has the weight's shape exactly; s is the
# keepdims per-channel scale (same ndim, inner dims 1 — param_spec's
# divisibility drop nulls the collapsed axes, the channel axis shards).
_QUANT_SUFFIX = re.compile(r"/(q|s)$")


def spec_tail(path_str: str, mode: str) -> Optional[Tuple]:
    for rx, tp, ftp in _COMPILED:
        if rx.search(path_str):
            return tp if mode == "tp" else ftp
    base = _QUANT_SUFFIX.sub("", path_str)
    if base != path_str:
        return spec_tail(base, mode)
    return None


def _remap_tail(tail: Tuple, model_axis: Optional[str]) -> Tuple:
    """Rule tails name the within-particle axis literally `"model"`;
    remap to the placement's actual model-axis name (or drop to None
    when the plan has no model axis at all)."""
    if model_axis == "model":
        return tail
    return tuple(model_axis if a == "model" else a for a in tail)


def param_spec(path, ndim: int, mode: str, particle_axis: Optional[str],
               shape=None, mesh_shape=None,
               model_axis: Optional[str] = "model") -> P:
    """Full PartitionSpec for one parameter leaf. When `shape`/`mesh_shape`
    are given, any axis whose dim is not divisible by its mesh-axis size is
    dropped to None (e.g. whisper's vocab 51865 on a 16-way model axis)."""
    tail = spec_tail(normalize_path(path), mode)
    if tail is None or len(tail) > ndim:
        tail = ()
    tail = _remap_tail(tail, model_axis)
    lead_n = ndim - len(tail)
    lead = [None] * lead_n
    if particle_axis is not None and lead_n >= 1:
        lead[0] = particle_axis
    spec = list(lead) + list(tail)
    if shape is not None and mesh_shape is not None:
        for i, ax in enumerate(spec):
            if ax is not None and shape[i] % mesh_shape.get(ax, 1) != 0:
                spec[i] = None
    return P(*spec)


def tree_param_specs(tree, mode: str, particle_axis: Optional[str] = None,
                     mesh=None, model_axis: Optional[str] = "model"):
    """Pytree of PartitionSpecs matching `tree` (arrays or ShapeDtypeStructs)."""
    mesh_shape = dict(mesh.shape) if mesh is not None else None
    flat, tdef = jax.tree_util.tree_flatten_with_path(tree)
    specs = [param_spec(path, len(leaf.shape), mode, particle_axis,
                        shape=leaf.shape if mesh is not None else None,
                        mesh_shape=mesh_shape, model_axis=model_axis)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(tdef, specs)


def tree_shardings(mesh, tree, mode: str, particle_axis: Optional[str] = None,
                   model_axis: Optional[str] = "model"):
    specs = tree_param_specs(tree, mode, particle_axis, mesh=mesh,
                             model_axis=model_axis)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
