from . import policy, rules
from .policy import activation_policy, maybe_shard
