"""Activation-sharding policy: a context-scoped map name -> PartitionSpec.

Model code calls ``maybe_shard(x, "residual")`` at layer boundaries; with
no active policy (CPU smokes, unit tests) it is a no-op, under the launch
code's ``activation_policy(...)`` context it becomes a GSPMD sharding
constraint. This keeps the model zoo mesh-agnostic while letting the
dry-run pin the Megatron-SP style layout (sequence-sharded residuals,
head-sharded attention) that the roofline analysis assumes.

Under ``vmap(..., spmd_axis_name=...)`` (the particle axis) JAX prepends
the particle mesh axis to these specs automatically.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec

_tls = threading.local()


def current_policy() -> Optional[Dict[str, PartitionSpec]]:
    return getattr(_tls, "policy", None)


@contextmanager
def activation_policy(policy: Dict[str, PartitionSpec]):
    prev = current_policy()
    _tls.policy = policy
    try:
        yield
    finally:
        _tls.policy = prev


def tp_activation_policy(mesh_shape: Dict[str, int],
                         model_axis: str = "model"
                         ) -> Dict[str, PartitionSpec]:
    """The Megatron-TP activation layout over a 2D (particle x model)
    placement: attention/MLP/SSM intermediates stay sharded over the
    model axis between the column-parallel (wq/wk/wv, wi/wg) and
    row-parallel (wo, w2) matmuls instead of round-tripping replicated;
    residuals are pinned replicated so the row-parallel psum happens at
    the block boundary, exactly once.

    Specs are per-particle ranks — under ``vmap(spmd_axis_name=...)``
    JAX prepends the particle mesh axis automatically. ``__mesh__``
    enables ``maybe_shard``'s divisibility drop, so head counts that do
    not divide the model axis degrade to replicated instead of erroring.
    """
    m = model_axis
    return {
        "attn_heads": PartitionSpec(None, None, m, None),   # (B, S, H, hd)
        "attn_kv":    PartitionSpec(None, None, m, None),   # (B, S, KVH, hd)
        "ssm_heads":  PartitionSpec(None, None, m, None),   # (B, S, H, hd)
        "mlp_hidden": PartitionSpec(None, None, m),         # (B, S, F)
        "logits":     PartitionSpec(None, None, m),         # (B, S, V)
        "moe_buffer": PartitionSpec(m, None, None),         # (E, C, D)
        "residual":   PartitionSpec(None, None, None),      # (B, S, D)
        "__mesh__":   dict(mesh_shape),
    }


def maybe_shard(x, name: str):
    pol = current_policy()
    if pol is None or name not in pol:
        return x
    spec = pol[name]
    if len(spec) != x.ndim:  # rank mismatch (e.g. smoke path) -> skip
        return x
    mesh_shape = pol.get("__mesh__")
    if mesh_shape:  # drop axes whose dim is not divisible by the mesh axis
        fixed = []
        for dim, ax in zip(x.shape, spec):
            axes = ax if isinstance(ax, tuple) else (ax,) if ax else ()
            size = 1
            for a in axes:
                size *= mesh_shape.get(a, 1)
            fixed.append(ax if size > 1 and dim % size == 0 else None)
        # NOTE: even an all-None spec is applied — forcing replication
        # hoists gathers out of surrounding scans (EXPERIMENTS.md §Perf)
        spec = PartitionSpec(*fixed)
    return jax.lax.with_sharding_constraint(x, spec)
