"""Activation-sharding policy: a context-scoped map name -> PartitionSpec.

Model code calls ``maybe_shard(x, "residual")`` at layer boundaries; with
no active policy (CPU smokes, unit tests) it is a no-op, under the launch
code's ``activation_policy(...)`` context it becomes a GSPMD sharding
constraint. This keeps the model zoo mesh-agnostic while letting the
dry-run pin the Megatron-SP style layout (sequence-sharded residuals,
head-sharded attention) that the roofline analysis assumes.

Under ``vmap(..., spmd_axis_name=...)`` (the particle axis) JAX prepends
the particle mesh axis to these specs automatically.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec

_tls = threading.local()


def current_policy() -> Optional[Dict[str, PartitionSpec]]:
    return getattr(_tls, "policy", None)


@contextmanager
def activation_policy(policy: Dict[str, PartitionSpec]):
    prev = current_policy()
    _tls.policy = policy
    try:
        yield
    finally:
        _tls.policy = prev


def maybe_shard(x, name: str):
    pol = current_policy()
    if pol is None or name not in pol:
        return x
    spec = pol[name]
    if len(spec) != x.ndim:  # rank mismatch (e.g. smoke path) -> skip
        return x
    mesh_shape = pol.get("__mesh__")
    if mesh_shape:  # drop axes whose dim is not divisible by the mesh axis
        fixed = []
        for dim, ax in zip(x.shape, spec):
            axes = ax if isinstance(ax, tuple) else (ax,) if ax else ()
            size = 1
            for a in axes:
                size *= mesh_shape.get(a, 1)
            fixed.append(ax if size > 1 and dim % size == 0 else None)
        # NOTE: even an all-None spec is applied — forcing replication
        # hoists gathers out of surrounding scans (EXPERIMENTS.md §Perf)
        spec = PartitionSpec(*fixed)
    return jax.lax.with_sharding_constraint(x, spec)
