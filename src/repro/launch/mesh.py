"""Production mesh factory (functions only — importing never touches jax
device state; the dry-run sets XLA_FLAGS before any jax import).

``AxisType`` (explicit-sharding axis annotations) only exists in newer jax
releases; ``make_mesh`` shims it so the same call sites work on any
installed version — older jax simply builds the mesh without axis types
(every axis behaves as Auto there anyway).

All factories validate axis sizes against the visible device count up
front and raise a clear ``ValueError`` — a bad ``model=`` used to surface
as a cryptic reshape/XLA error from deep inside ``jax.make_mesh``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax

try:  # newer jax: explicit-sharding axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: all axes are implicitly Auto
    AxisType = None

HAS_AXIS_TYPES = AxisType is not None


def _validate(shape, axes) -> None:
    if len(shape) != len(axes):
        raise ValueError(
            f"mesh shape {tuple(shape)} and axes {tuple(axes)} disagree: "
            f"{len(shape)} sizes for {len(axes)} axis names")
    if any(int(s) <= 0 for s in shape):
        raise ValueError(f"mesh shape {tuple(shape)} has a non-positive "
                         "axis size")
    want = math.prod(int(s) for s in shape)
    have = len(jax.devices())
    if want > have:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {want} devices but only "
            f"{have} are visible (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={want} to "
            "emulate, or shrink an axis)")
    if have % want != 0:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} covers {want} of {have} visible "
            f"devices; {have} is not a multiple of {want}, so no axis size "
            "can be grown to use them all — pick axis sizes whose product "
            f"divides {have}")


def make_mesh(shape, axes):
    """Version-portable ``jax.make_mesh`` with Auto axis types when available."""
    _validate(shape, axes)
    if HAS_AXIS_TYPES:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_bench_mesh(n_devices: int, model: int = 1):
    """2D ``(data=particle, model)`` mesh over ``n_devices`` (forced host
    devices on CPU benchmarks, real chips elsewhere). ``model`` must
    divide the device count — the particle axis gets the rest."""
    if model <= 0:
        raise ValueError(f"model axis size must be positive, got {model}")
    if n_devices % model != 0:
        raise ValueError(
            f"model axis size {model} does not divide the device count "
            f"{n_devices}: the particle axis would get {n_devices}/{model} "
            "devices — pick a model-axis size that divides the device count")
    data = n_devices // model
    return make_mesh((data, model), ("data", "model"))


def pick_model_axis(params_bytes: int, n_devices: int, *,
                    device_memory_bytes: Optional[int] = None,
                    fraction: float = 0.6) -> int:
    """Smallest model-axis size (a divisor of ``n_devices``) such that one
    particle's parameter shard, ``params_bytes / model``, fits within
    ``fraction`` of a device's memory — what ``Placement.auto(model=
    "auto")`` uses. When the backend reports no memory budget (CPU
    ``memory_stats()`` is often absent) or ``params_bytes`` is unknown,
    returns 1 (particle-parallel, today's behavior); when even
    ``model=n_devices`` does not fit, returns ``n_devices`` (best
    effort — the caller sees the OOM with the largest possible split).
    """
    if device_memory_bytes is None:
        try:
            stats = jax.local_devices()[0].memory_stats()
            device_memory_bytes = (stats or {}).get("bytes_limit")
        except Exception:
            device_memory_bytes = None
    if not device_memory_bytes or not params_bytes or n_devices <= 1:
        return 1
    budget = fraction * device_memory_bytes
    divisors = sorted(d for d in range(1, n_devices + 1)
                      if n_devices % d == 0)
    for m in divisors:
        if params_bytes / m <= budget:
            return m
    return n_devices
