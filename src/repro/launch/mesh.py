"""Production mesh factory (functions only — importing never touches jax
device state; the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_bench_mesh(n_devices: int, model: int = 1):
    """Small mesh for CPU benchmarks (forced host devices)."""
    data = n_devices // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
