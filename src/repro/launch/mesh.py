"""Production mesh factory (functions only — importing never touches jax
device state; the dry-run sets XLA_FLAGS before any jax import).

``AxisType`` (explicit-sharding axis annotations) only exists in newer jax
releases; ``make_mesh`` shims it so the same call sites work on any
installed version — older jax simply builds the mesh without axis types
(every axis behaves as Auto there anyway).
"""
from __future__ import annotations

import jax

try:  # newer jax: explicit-sharding axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: all axes are implicitly Auto
    AxisType = None

HAS_AXIS_TYPES = AxisType is not None


def make_mesh(shape, axes):
    """Version-portable ``jax.make_mesh`` with Auto axis types when available."""
    if HAS_AXIS_TYPES:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_bench_mesh(n_devices: int, model: int = 1):
    """Small mesh for CPU benchmarks (forced host devices)."""
    data = n_devices // model
    return make_mesh((data, model), ("data", "model"))
