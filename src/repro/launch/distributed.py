"""Multi-host bring-up for 2D (particle x model) placement.

``initialize()`` is the one call a multi-host launcher makes before
building a ``Placement``: it wires ``jax.distributed`` from explicit
arguments or the standard environment (JAX_COORDINATOR_ADDRESS /
JAX_NUM_PROCESSES / JAX_PROCESS_ID, falling back to cluster
auto-detection when jax supports it), after which ``jax.devices()``
spans every process and the same ``Placement.auto(model=...)`` code
path that runs single-process multi-device runs multi-host — the mesh
factories and sharding rules never special-case the host count.

Single-process launches (tests, benchmarks, CPU smokes) call this too:
with no coordinator configured it is a documented no-op returning
False, so library code can call it unconditionally. The call is
idempotent — a second ``initialize()`` in the same process returns
True without re-contacting the coordinator.
"""
from __future__ import annotations

import os
import threading
from typing import Optional

import jax

_lock = threading.Lock()
_initialized = False


def is_initialized() -> bool:
    """Whether this process already joined a jax.distributed cluster
    (via this module; out-of-band initialization is also detected)."""
    with _lock:
        if _initialized:
            return True
    state = getattr(getattr(jax, "_src", None), "distributed", None)
    client = getattr(getattr(state, "global_state", None), "client", None)
    return client is not None


def initialize(coordinator_address: Optional[str] = None,
               num_processes: Optional[int] = None,
               process_id: Optional[int] = None,
               local_device_ids=None) -> bool:
    """Join (or skip joining) a multi-host jax cluster. Returns True when
    the process is part of a multi-process cluster afterwards, False for
    the single-process no-op path. Arguments default to the standard
    environment variables so launchers can configure placement without
    code changes."""
    global _initialized
    with _lock:
        if _initialized:
            return True
    coordinator_address = coordinator_address or os.environ.get(
        "JAX_COORDINATOR_ADDRESS")
    if num_processes is None and os.environ.get("JAX_NUM_PROCESSES"):
        num_processes = int(os.environ["JAX_NUM_PROCESSES"])
    if process_id is None and os.environ.get("JAX_PROCESS_ID"):
        process_id = int(os.environ["JAX_PROCESS_ID"])
    if coordinator_address is None and num_processes is None:
        # no cluster configured: the single-process fast path
        return False
    kwargs = {}
    if coordinator_address is not None:
        kwargs["coordinator_address"] = coordinator_address
    if num_processes is not None:
        kwargs["num_processes"] = num_processes
    if process_id is not None:
        kwargs["process_id"] = process_id
    if local_device_ids is not None:
        kwargs["local_device_ids"] = local_device_ids
    try:
        jax.distributed.initialize(**kwargs)
    except RuntimeError as e:
        # already initialized out-of-band counts as success; anything
        # else (bad address, size mismatch) must surface to the launcher
        if "already initialized" not in str(e).lower():
            raise
    with _lock:
        _initialized = True
    return True
