"""Run plans: how each (arch x input shape) maps onto the production mesh.

Encodes the DESIGN.md §4 policy:
  * particle-parallel archs (P=16): particle axis -> `data`, TP within a
    particle over `model`, batch replicated (multi-pod: batch -> `pod`).
  * P=1 giants (llama3-405b, qwen3-moe-235b): FSDP over `data` + TP over
    `model`, batch -> (`pod`, `data`).
  * decode shapes: KV caches batch->`data`, sequence->`model`
    (sequence-sharded KV), small replicated serve-ensemble (P_serve).
  * microbatching (gradient accumulation) bounds activation HBM on train_4k.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from ..configs import ModelConfig, InputShape

# particle counts used in the dry run (DESIGN.md §5 table)
PARTICLES = {
    "deepseek-moe-16b": 16, "llama3-8b": 16, "rwkv6-7b": 16,
    "whisper-medium": 16, "gemma3-4b": 16, "paligemma-3b": 16,
    "zamba2-1.2b": 16, "qwen1.5-0.5b": 16,
    "llama3-405b": 1, "qwen3-moe-235b-a22b": 1,
    "vit-mnist": 16, "unet-advection": 16,
}
# gradient-accumulation microbatches for train_4k (activation HBM bound)
MICROBATCHES = {
    "llama3-405b": 32, "qwen3-moe-235b-a22b": 32, "deepseek-moe-16b": 32,
    "llama3-8b": 16, "rwkv6-7b": 32, "gemma3-4b": 16, "paligemma-3b": 16,
    "whisper-medium": 16, "zamba2-1.2b": 32, "qwen1.5-0.5b": 8,
}
# replicated serve-ensemble size for prefill/decode shapes (small models
# serve a 4-way posterior-predictive ensemble; giants serve P=1)
SERVE_PARTICLES = {
    "qwen1.5-0.5b": 4, "zamba2-1.2b": 2, "whisper-medium": 4,
    "gemma3-4b": 2, "paligemma-3b": 2, "rwkv6-7b": 2, "llama3-8b": 2,
    "deepseek-moe-16b": 1, "llama3-405b": 1, "qwen3-moe-235b-a22b": 1,
}
# bf16 parameters in the dry run for the largest models (HBM budget)
BF16_PARAMS = {"llama3-405b", "qwen3-moe-235b-a22b", "deepseek-moe-16b",
               "llama3-8b", "rwkv6-7b", "gemma3-4b", "paligemma-3b"}


@dataclass(frozen=True)
class RunPlan:
    arch: str
    shape: str
    particles: int            # particle axis length (1 = squeezed)
    serve_particles: int      # replicated serve ensemble for decode shapes
    microbatches: int
    mode: str                 # "tp" (particle-parallel) | "fsdp_tp"
    particle_axis: Optional[str]  # mesh axis carrying particles
    param_dtype: str


def plan_for(cfg: ModelConfig, shape: InputShape) -> RunPlan:
    P = PARTICLES.get(cfg.name, cfg.default_particles)
    mode = "fsdp_tp" if P == 1 else "tp"
    particle_axis = "data" if P > 1 else None
    serve_p = SERVE_PARTICLES.get(cfg.name, 1)
    if shape.name == "long_500k":
        serve_p = 1
    micro = MICROBATCHES.get(cfg.name, 1) if shape.kind == "train" else 1
    pdt = "bfloat16" if (cfg.name in BF16_PARAMS or
                         cfg.param_dtype == "bfloat16") else "float32"
    if shape.kind in ("decode", "prefill"):
        # serving: small replicated posterior-predictive ensemble; the batch
        # (not the particle axis) shards over `data`
        P, particle_axis = serve_p, None
    return RunPlan(cfg.name, shape.name, P, serve_p, micro, mode,
                   particle_axis, pdt)
