"""Step builders + abstract input specs for the multi-pod dry run.

Everything here works on ShapeDtypeStructs (no allocation): abstract
params/optimizer/cache trees via jax.eval_shape, sharding trees via
sharding.rules, and jit-able step functions:

  train_step(params, opt, batch)   -> (params, opt, loss)     [train_4k]
  prefill_step(params, batch)      -> (logits, caches)        [prefill_32k]
  serve_step(params, token, caches, pos) -> (logits, caches)  [decode_*]

Particle axis (the paper's technique): vmap with spmd_axis_name so the
particle axis shards over `data` and all internal sharding constraints
compose (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs as configs_mod
from ..configs import ModelConfig, InputShape
from ..models import api
from ..optim import make_optimizer
from ..sharding import rules
from ..sharding.policy import activation_policy
from .plans import RunPlan

CACHE_DTYPE = jnp.bfloat16


# --------------------------------------------------------------------------
# abstract state builders
# --------------------------------------------------------------------------

def _cast_tree(tree, dtype):
    def cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, dtype) \
                if isinstance(x, jax.ShapeDtypeStruct) else x.astype(dtype)
        return x
    return jax.tree.map(cast, tree)


def abstract_params(cfg: ModelConfig, plan: RunPlan):
    rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
    if plan.particles > 1:
        def initp(r):
            return jax.vmap(lambda k: api.init_params(k, cfg))(
                jax.random.split(r, plan.particles))
    else:
        def initp(r):
            return api.init_params(r, cfg)
    tree = jax.eval_shape(initp, rng)
    return _cast_tree(tree, jnp.dtype(plan.param_dtype))


def abstract_opt_state(cfg: ModelConfig, plan: RunPlan, params_abs):
    opt = make_optimizer(cfg.optimizer, 1e-3)
    if plan.particles > 1:
        return jax.eval_shape(jax.vmap(opt.init), params_abs)
    return jax.eval_shape(opt.init, params_abs)


def abstract_cache(cfg: ModelConfig, plan: RunPlan, batch: int, seq_len: int):
    def mk():
        return api.init_cache(cfg, batch, seq_len, dtype=CACHE_DTYPE)
    tree = jax.eval_shape(mk)
    if plan.particles > 1:
        tree = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct((plan.particles,) + x.shape, x.dtype),
            tree)
    return tree


def abstract_batch(cfg: ModelConfig, shape: InputShape):
    B, S = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
           "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct((B, cfg.n_frames, cfg.d_model),
                                             jnp.bfloat16)
    if cfg.family == "vlm":
        out["patches"] = jax.ShapeDtypeStruct((B, cfg.n_prefix_tokens, cfg.d_model),
                                              jnp.bfloat16)
    return out


# --------------------------------------------------------------------------
# sharding spec builders
# --------------------------------------------------------------------------

def _div(n: int, mesh, axis: str) -> bool:
    return n % mesh.shape[axis] == 0 and n >= mesh.shape[axis]


def batch_specs(cfg, plan, mesh, batch_abs):
    multi = "pod" in mesh.shape
    if plan.particle_axis is None:  # batch owns the data axis
        bspec = ("pod", "data") if multi else ("data",)
    else:                           # particle-parallel: data carries particles
        bspec = ("pod",) if multi else (None,)
    def fit(n, axes):
        """Longest prefix of mesh axes whose size product divides n."""
        out, prod = [], 1
        for ax in axes:
            if ax is None:
                continue
            if n % (prod * mesh.shape[ax]) == 0:
                out.append(ax)
                prod *= mesh.shape[ax]
        if not out:
            return None
        return tuple(out) if len(out) > 1 else out[0]

    specs = {}
    for k, v in batch_abs.items():
        if k in ("tokens", "labels"):
            specs[k] = P(fit(v.shape[0], bspec))
        else:  # frames/patches: big float inputs — also try the model axis
            specs[k] = P(fit(v.shape[0], tuple(bspec) + ("model",)), None, None)
    return specs


def cache_specs(cfg, plan, mesh, cache_abs, batch: int):
    """Sequence-sharded KV caches: B->data (if divisible), S->model."""
    b_ax = "data" if _div(batch, mesh, "data") else None
    flat, tdef = jax.tree_util.tree_flatten_with_path(cache_abs)
    specs = []
    for path, leaf in flat:
        pstr = rules.normalize_path(path)
        nd = len(leaf.shape)
        name = pstr.rsplit("/", 1)[-1]
        if name in ("k", "v"):
            C = leaf.shape[-3]
            tail = (b_ax, "model" if _div(C, mesh, "model") else None, None, None)
        elif name == "pos":
            C = leaf.shape[-1]
            tail = (b_ax, "model" if _div(C, mesh, "model") else None)
        elif name in ("xk", "xv"):
            tail = (b_ax, None, None, None)
        elif name == "ssm":
            H = leaf.shape[-3]
            tail = (b_ax, "model" if _div(H, mesh, "model") else None, None, None)
        elif name == "state":
            H = leaf.shape[-3]
            tail = (b_ax, "model" if _div(H, mesh, "model") else None, None, None)
        elif name == "conv":
            tail = (b_ax, None, None)
        elif name.startswith("x_last"):
            tail = (b_ax, None)
        else:
            tail = (None,) * nd
        lead = (None,) * (nd - len(tail))
        specs.append(P(*lead, *tail))
    return jax.tree_util.tree_unflatten(tdef, specs)


def residual_policy(cfg, plan, mesh):
    """Activation policy for full-seq passes (Megatron-SP style)."""
    multi = "pod" in mesh.shape
    if plan.particle_axis is None:
        b = ("pod", "data") if multi else "data"
        moe_c = "data"      # MoE capacity axis can use the data axis
    else:
        b = "pod" if multi else None
        moe_c = None        # data axis carries particles (spmd vmap)
    return {
        "__mesh__": dict(mesh.shape),
        "residual": P(b, "model", None),        # (B, S, D): sequence-sharded
        "logits": P(b, None, "model"),          # (B, chunk, V): vocab-sharded
        "moe_buffer": P("model", moe_c, None),  # (E, C, *): expert-parallel
        "moe_tokens": P(moe_c, None),            # (T*k, D) combine path
        # flash-attention layout: heads on `model`, sequence local — one
        # resharding per layer instead of one gather per flash block
        "attn_heads": P(b, None, "model", None),
        "attn_kv": P(b, None, "model", None),
        "ssm_heads": P(b, None, "model", None),  # rwkv/mamba chunk streams
    }


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------

def _tree_add(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def make_train_step(cfg: ModelConfig, plan: RunPlan, mesh):
    opt = make_optimizer(cfg.optimizer, 1e-3)
    loss_fn = functools.partial(api.loss_fn, cfg=cfg)
    policy = residual_policy(cfg, plan, mesh)

    def single(params, opt_state, batch):
        with activation_policy(policy):
            if plan.microbatches == 1:
                (loss, _), grads = jax.value_and_grad(
                    lambda p: loss_fn(p, batch), has_aux=True)(params)
            else:
                mb = plan.microbatches
                split = jax.tree.map(
                    lambda a: a.reshape((mb, a.shape[0] // mb) + a.shape[1:]),
                    batch)

                def body(acc, b):
                    (l, _), g = jax.value_and_grad(
                        lambda p: loss_fn(p, b), has_aux=True)(params)
                    return _tree_add(acc, jax.tree.map(
                        lambda x: x.astype(jnp.float32), g)), l

                g0 = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), params)
                grads, losses = lax.scan(body, g0, split)
                grads = jax.tree.map(lambda g: (g / mb), grads)
                loss = losses.mean()
            new_p, new_s = opt.update(params, jax.tree.map(
                lambda g, p: g.astype(p.dtype), grads, params), opt_state)
            return new_p, new_s, loss

    if plan.particles > 1:
        step = jax.vmap(single, in_axes=(0, 0, None),
                        spmd_axis_name=plan.particle_axis)
    else:
        step = single
    return step


def make_svgd_train_step(cfg: ModelConfig, plan: RunPlan, mesh,
                         lr: float = 1e-3, lengthscale: float = 1.0):
    """SVGD over the particle axis at production scale (the paper's own
    algorithm as a launch mode): per-particle grads (vmap over the particle
    mesh axis), then the RBF kernel force over the flattened (P, D)
    parameter matrix — the all-to-all the paper identifies as SVGD's
    bottleneck (§5.1), visible as collective bytes in the dry run."""
    from jax.flatten_util import ravel_pytree
    from ..bdl.svgd import svgd_force
    loss_fn = functools.partial(api.loss_fn, cfg=cfg)
    policy = residual_policy(cfg, plan, mesh)

    def single_grad(params, batch):
        with activation_policy(policy):
            if plan.microbatches == 1:
                (loss, _), g = jax.value_and_grad(
                    lambda p: loss_fn(p, batch), has_aux=True)(params)
                return loss, g
            mb = plan.microbatches
            split = jax.tree.map(
                lambda a: a.reshape((mb, a.shape[0] // mb) + a.shape[1:]),
                batch)

            def body(acc, b):
                (l, _), g = jax.value_and_grad(
                    lambda p: loss_fn(p, b), has_aux=True)(params)
                return _tree_add(acc, jax.tree.map(
                    lambda x: x.astype(jnp.float32), g)), l

            g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
            grads, losses = lax.scan(body, g0, split)
            return losses.mean(), jax.tree.map(lambda g: g / mb, grads)

    def step(stacked_params, batch):
        losses, grads = jax.vmap(single_grad, in_axes=(0, None),
                                 spmd_axis_name=plan.particle_axis)(
            stacked_params, batch)
        one = jax.tree.map(lambda x: x[0], stacked_params)
        _, unravel = ravel_pytree(one)
        theta = jax.vmap(lambda t: ravel_pytree(t)[0])(stacked_params)
        g = jax.vmap(lambda t: ravel_pytree(t)[0])(grads)
        # (P, D): particles over `data`, flattened params over `model`
        if theta.shape[1] % mesh.shape["model"] == 0:
            wide = NamedSharding(mesh, P(plan.particle_axis, "model"))
            theta = jax.lax.with_sharding_constraint(theta, wide)
            g = jax.lax.with_sharding_constraint(g, wide)
        phi = svgd_force(theta.astype(jnp.float32), g.astype(jnp.float32),
                         lengthscale)
        new_theta = theta - lr * phi.astype(theta.dtype)
        return jax.vmap(unravel)(new_theta), losses

    return step


def make_multiswag_train_step(cfg: ModelConfig, plan: RunPlan, mesh,
                              max_rank: int = 4):
    """Ensemble step + per-particle SWAG moment collection (multi-SWAG as a
    launch mode; moments are particle-local — the paper's argument for why
    multi-SWAG scales like deep ensembles)."""
    from ..bdl.swag import swag_collect
    base = make_train_step(cfg, plan, mesh)

    def step(params, opt_state, swag_state, batch):
        new_p, new_s, loss = base(params, opt_state, batch)
        new_sw = jax.vmap(lambda st, p: swag_collect(st, p, use_kernel=False)
                          )(swag_state, new_p)
        return new_p, new_s, new_sw, loss

    return step


def make_prefill_step(cfg: ModelConfig, plan: RunPlan, mesh):
    policy = residual_policy(cfg, plan, mesh)

    def single(params, batch):
        with activation_policy(policy):
            return api.prefill(params, batch, cfg)

    if plan.particles > 1:
        vm = jax.vmap(single, in_axes=(0, None),
                      spmd_axis_name=plan.particle_axis)

        def step(params, batch):
            logits, caches = vm(params, batch)
            return jnp.mean(logits.astype(jnp.float32), axis=0), caches
        return step
    return single


def make_serve_step(cfg: ModelConfig, plan: RunPlan, mesh):
    policy = residual_policy(cfg, plan, mesh)

    def single(params, token, caches, cur_pos):
        with activation_policy(policy):
            return api.decode_step(params, token, caches, cur_pos, cfg)

    if plan.particles > 1:  # replicated serve ensemble (logit averaging)
        vm = jax.vmap(single, in_axes=(0, None, 0, None))

        def step(params, token, caches, cur_pos):
            logits, caches = vm(params, token, caches, cur_pos)
            return jnp.mean(logits.astype(jnp.float32), axis=0), caches
        return step
    return single


# --------------------------------------------------------------------------
# top-level: abstract args + shardings per (cfg, shape, plan, mesh)
# --------------------------------------------------------------------------

def build(cfg: ModelConfig, shape: InputShape, plan: RunPlan, mesh,
          bdl: str = "ensemble"):
    """Returns (step_fn, abstract_args tuple, in_shardings tuple).

    bdl selects the train-step algorithm: "ensemble" (independent
    particles), "svgd" (all-to-all kernel force over the particle axis) or
    "multiswag" (ensemble + particle-local SWAG moments)."""
    cfg = cfg.replace(remat=(shape.kind == "train"), dtype="bfloat16")
    params_abs = abstract_params(cfg, plan)
    p_shard = rules.tree_shardings(mesh, params_abs, plan.mode,
                                   plan.particle_axis)

    def ns(spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        batch_abs = abstract_batch(cfg, shape)
        b_shard = ns(batch_specs(cfg, plan, mesh, batch_abs))
        if bdl in ("svgd", "multiswag") and plan.particles < 2:
            raise ValueError(f"{bdl} needs a particle axis (P>1); "
                             f"{cfg.name} runs P={plan.particles}")
        if bdl == "svgd":
            step = make_svgd_train_step(cfg, plan, mesh)
            return step, (params_abs, batch_abs), (p_shard, b_shard)
        opt_abs = abstract_opt_state(cfg, plan, params_abs)
        o_shard = rules.tree_shardings(mesh, opt_abs, plan.mode,
                                       plan.particle_axis)
        if bdl == "multiswag":
            from ..bdl.swag import swag_state_init
            one = jax.tree.map(lambda x: jax.ShapeDtypeStruct(
                x.shape[1:], x.dtype), params_abs) if plan.particles > 1 \
                else params_abs
            sw_abs = jax.eval_shape(
                lambda: jax.vmap(lambda _: swag_state_init(one, 4))(
                    jnp.arange(max(plan.particles, 1))))
            sw_shard = rules.tree_shardings(mesh, sw_abs, plan.mode,
                                            plan.particle_axis)
            step = make_multiswag_train_step(cfg, plan, mesh)
            return step, (params_abs, opt_abs, sw_abs, batch_abs), \
                (p_shard, o_shard, sw_shard, b_shard)
        step = make_train_step(cfg, plan, mesh)
        return step, (params_abs, opt_abs, batch_abs), (p_shard, o_shard, b_shard)

    if shape.kind == "prefill":
        batch_abs = abstract_batch(cfg, shape)
        batch_abs.pop("labels")
        b_shard = ns(batch_specs(cfg, plan, mesh, batch_abs))
        step = make_prefill_step(cfg, plan, mesh)
        return step, (params_abs, batch_abs), (p_shard, b_shard)

    # decode: one new token against a seq_len cache
    B = shape.global_batch
    cache_abs = abstract_cache(cfg, plan, B, shape.seq_len)
    c_shard = ns(cache_specs(cfg, plan, mesh, cache_abs, B))
    token_abs = jax.ShapeDtypeStruct((B,), jnp.int32)
    t_shard = NamedSharding(mesh, P("data" if _div(B, mesh, "data") else None))
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    pos_shard = NamedSharding(mesh, P())
    step = make_serve_step(cfg, plan, mesh)
    return step, (params_abs, token_abs, cache_abs, pos_abs), \
        (p_shard, t_shard, c_shard, pos_shard)
