"""Hillclimb driver (EXPERIMENTS.md §Perf): measure the three roofline
terms for one (arch x shape) with optional plan overrides, and attribute
the top collectives to source ops.

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb --arch llama3-405b \
      --shape train_4k [--microbatches 8] [--top-collectives]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import dataclasses
import json

import jax

from .. import configs as configs_mod
from ..configs import INPUT_SHAPES
from .mesh import make_production_mesh
from .plans import plan_for
from . import hlo_cost as hc
from . import steps as steps_mod
from .roofline import PEAK_FLOPS, HBM_BW, LINK_BW


def measure(arch: str, shape_name: str, *, microbatches=None, particles=None,
            top: bool = False, multi_pod: bool = False, bdl: str = "ensemble"):
    cfg = configs_mod.get(arch)
    shape = INPUT_SHAPES[shape_name]
    plan = plan_for(cfg, shape)
    if microbatches is not None:
        plan = dataclasses.replace(plan, microbatches=microbatches)
    if particles is not None:
        plan = dataclasses.replace(plan, particles=particles)
    mesh = make_production_mesh(multi_pod=multi_pod)
    donate = {"train": (0, 1), "decode": (2,), "prefill": ()}[shape.kind]
    if shape.kind == "train" and bdl == "svgd":
        donate = (0,)
    with jax.set_mesh(mesh):
        step, args, sh = steps_mod.build(cfg, shape, plan, mesh, bdl=bdl)
        c = jax.jit(step, in_shardings=sh,
                    donate_argnums=donate).lower(*args).compile()
    txt = c.as_text()
    cost = hc.cost(txt)
    coll = sum(cost["coll"].values())
    m = c.memory_analysis()
    rec = {
        "arch": arch, "shape": shape_name, "plan": dataclasses.asdict(plan),
        "t_compute_s": cost["flops"] / PEAK_FLOPS,
        "t_memory_s": cost["bytes"] / HBM_BW,
        "t_collective_s": coll / LINK_BW,
        "coll_tb": {k: round(v / 1e12, 3) for k, v in cost["coll"].items()},
        "hbm_temp_gb": m.temp_size_in_bytes / 1e9,
        "hbm_args_gb": m.argument_size_in_bytes / 1e9,
    }
    print(json.dumps({k: v for k, v in rec.items() if k != "plan"}, indent=1))
    if top:
        print("top collectives (bytes x trips):")
        for kind, tot, trips, b, name in hc.top_collectives(txt):
            print(f"  {kind:18s} {tot/1e12:7.2f}TB x{trips:6d} "
                  f"each {b/1e6:9.1f}MB  {name[:100]}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--particles", type=int, default=None)
    ap.add_argument("--top-collectives", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--bdl", default="ensemble")
    a = ap.parse_args()
    measure(a.arch, a.shape, microbatches=a.microbatches,
            particles=a.particles, top=a.top_collectives,
            multi_pod=a.multi_pod, bdl=a.bdl)


if __name__ == "__main__":
    main()
