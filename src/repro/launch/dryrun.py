"""Multi-pod dry run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh and extract roofline inputs.

MUST be the first import side effect: 512 placeholder host devices so
jax.make_mesh can build the production mesh (jax locks the device count on
first init — do not move these lines).
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import re
import time
import traceback

import jax

from .. import configs as configs_mod
from ..configs import INPUT_SHAPES
from .mesh import make_production_mesh
from .plans import plan_for
from . import steps as steps_mod

_DTYPE_BYTES = {"pred": 0.125, "s4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
                "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2,
                "f32": 4, "f64": 8, "c64": 8, "c128": 16, "u1": 0.125}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+)\[([\d,]*)\])\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str):
    """Sum output bytes of every collective op in the optimized HLO,
    bucketed by op kind. (Per-device program -> per-device bytes.)"""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        tuple_part, dtype, dims, kind = m.group(1), m.group(2), m.group(3), m.group(4)
        if tuple_part is not None:
            b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(tuple_part))
        else:
            b = _shape_bytes(dtype, dims)
        out[kind] = out.get(kind, 0.0) + b
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
            bdl: str = "ensemble"):
    cfg = configs_mod.get(arch)
    shape = INPUT_SHAPES[shape_name]
    skip = configs_mod.is_skipped(arch, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skip", "reason": skip}
    plan = plan_for(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "bdl": bdl,
           "mesh": "multi" if multi_pod else "single",
           "particles": plan.particles, "mode": plan.mode,
           "microbatches": plan.microbatches, "param_dtype": plan.param_dtype}
    try:
        with jax.set_mesh(mesh):
            step, args, shardings = steps_mod.build(cfg, shape, plan, mesh,
                                                     bdl=bdl)
            # donate the large persistent state (params/opt for train, the KV
            # cache for decode) — production steps alias these buffers
            donate = {"train": (0, 1), "decode": (2,), "prefill": ()}[shape.kind]
            if shape.kind == "train" and bdl == "svgd":
                donate = (0,)
            lowered = jax.jit(step, in_shardings=shardings,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo_text = compiled.as_text()
        # loop-aware cost model: compiled.cost_analysis() does not multiply
        # while(scan) body costs by trip count -> use hlo_cost (see module)
        from . import hlo_cost as hc
        loopc = hc.cost(hlo_text)
        coll = {k: v for k, v in loopc["coll"].items() if v > 0}
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "flops_per_device": loopc["flops"],
            "bytes_per_device": loopc["bytes"],
            "collective_bytes_per_device": coll,
            "raw_flops_per_device": float(cost.get("flops", -1)) if cost else -1,
            "raw_collective_bytes": collective_bytes(hlo_text),
            "memory": {k: getattr(mem, k, None) for k in
                       ("argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "generated_code_size_in_bytes")}
                      if mem is not None else None,
        })
        if verbose:
            print(f"[{arch} x {shape_name} x {rec['mesh']}] OK "
                  f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
                  f"flops/dev={rec['flops_per_device']:.3e} "
                  f"coll={ {k: f'{v/1e9:.2f}GB' for k, v in coll.items()} }")
            if mem is not None:
                print(f"  memory_analysis: args={mem.argument_size_in_bytes/1e9:.2f}GB "
                      f"out={mem.output_size_in_bytes/1e9:.2f}GB "
                      f"temp={mem.temp_size_in_bytes/1e9:.2f}GB")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        if verbose:
            print(f"[{arch} x {shape_name} x {rec['mesh']}] FAIL: "
                  f"{type(e).__name__}: {str(e)[:500]}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--bdl", default="ensemble",
                    choices=["ensemble", "svgd", "multiswag"])
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    archs = sorted(configs_mod.ARCHS) if (args.all or args.arch is None) \
        else [args.arch]
    shapes = sorted(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                if args.bdl != "ensemble":
                    tag += f"__{args.bdl}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[{tag}] exists, skipping")
                    continue
                rec = run_one(arch, shape, mp, bdl=args.bdl)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
