"""Loop-aware cost model over compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` does NOT multiply costs inside ``while``
bodies by their trip counts, so for scan-over-layers models it
under-counts FLOPs / bytes / collective traffic by the layer count (and by
the microbatch count, and the loss-chunk count). This module parses the
optimized HLO text and accumulates:

  * dot FLOPs          2 * prod(out_dims) * prod(lhs contracting dims)
  * HBM bytes          operand+output bytes of dot / fusion / gather /
                       scatter / dyn-slice / collective call sites
                       (elementwise chains inside a fusion stay in
                       registers/VMEM and are not double-counted)
  * collective bytes   max(in, out) bytes per collective op, by kind

multiplying everything inside a ``while`` by its trip count (read from the
largest integer constant in the loop's condition computation — how XLA
materializes lax.scan limits).

This is the per-device program, so all numbers are per-device.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTB = {"pred": 0.125, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2,
        "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1,
        "f8e5m2": 1, "f16": 2, "bf16": 2, "f32": 4, "f64": 8, "c64": 8,
        "c128": 16, "u1": 0.125, "token": 0}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s+(%[\w\.\-]+) = (.+?) ([\w\-]+)\((.*)$")
_HEADER_RE = re.compile(r"^(?:ENTRY )?(%[\w\.\-]+) \((.*)\) -> ")


def _shape_of(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.match(type_str.strip())
    if not m:
        return None
    dtype, dims = m.group(1), m.group(2)
    return dtype, [int(d) for d in dims.split(",") if d]


def _bytes_of(type_str: str) -> float:
    """Total bytes of a (possibly tuple) type string."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTB.get(dtype, 4)
    return total


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.ops: List[Tuple[str, str, str, List[str], str]] = []
        self.shapes: Dict[str, Tuple[str, List[int]]] = {}

    def add_param(self, decl: str):
        # "x.12: f32[]" or "param_0.1: (f32[2], s32[])"
        if ":" not in decl:
            return
        name, t = decl.split(":", 1)
        sh = _shape_of(t.strip())
        if sh:
            self.shapes["%" + name.strip().lstrip("%")] = sh


def parse(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        h = _HEADER_RE.match(line)
        if h and line.rstrip().endswith("{"):
            cur = Computation(h.group(1))
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            for decl in _split_params(h.group(2)):
                cur.add_param(decl)
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        operands, attrs = _split_call(rest)
        sh = _shape_of(type_str)
        if sh:
            cur.shapes[name] = sh
        cur.ops.append((name, type_str, opcode, operands, attrs))
    return comps, entry


def _split_params(s: str) -> List[str]:
    out, depth, start = [], 0, 0
    for i, ch in enumerate(s):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(s[start:i])
            start = i + 1
    if s[start:].strip():
        out.append(s[start:])
    return out


def _split_call(rest: str) -> Tuple[List[str], str]:
    """rest = 'operands...), attr=..., ...' -> (operand names, attrs str)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
            if depth == 0:
                inner, attrs = rest[:i], rest[i + 1:]
                ops = [t.strip() for t in _split_params(inner)]
                # older XLA prints operand types ("f32[8,8]{1,0} %x"); newer
                # prints bare names ("%x") — the name is the last token
                names = []
                for t in ops:
                    tok = t.split()[-1] if t else ""
                    if tok.startswith("%"):
                        names.append(tok)
                # keep the raw call payload in front of attrs: constant
                # literals (trip counts) live there
                return names, inner + " ## " + attrs
    return [], rest


_TRIP_RE = re.compile(r"constant\((\d+)\)")


def trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Loop limit = the largest integer constant in the condition
    computation (how XLA materializes lax.scan trip counts). Constants
    print as ``%c = s32[] constant(24)`` — the literal lands at the start
    of what _split_call returns as `attrs` (there are no %operands)."""
    comp = comps.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for _name, _type, opcode, _ops, attrs in comp.ops:
        if opcode == "constant":
            m = re.match(r"\s*(\d+)", attrs)
            if m:
                best = max(best, int(m.group(1)))
    return best


# ops whose operand/output streams dominate HBM traffic. Slice-like ops
# (gather/scatter/dyn-slice/dus) move only the slice: XLA updates the big
# aliased buffer in place, so we count 2x the smallest participating
# shape, not the buffer.
_STREAM_OPS = {"dot", "convolution"} | set(COLLECTIVES)
_SLICE_OPS = {"gather", "scatter", "dynamic-slice", "dynamic-update-slice"}


def cost(hlo: str) -> Dict:
    comps, entry = parse(hlo)
    memo: Dict[str, Dict] = {}

    def comp_cost(name: str) -> Dict:
        if name in memo:
            return memo[name]
        memo[name] = {"flops": 0.0, "bytes": 0.0,
                      "coll": {k: 0.0 for k in COLLECTIVES}}  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        total = {"flops": 0.0, "bytes": 0.0,
                 "coll": {k: 0.0 for k in COLLECTIVES}}

        def add(sub: Dict, mult: float = 1.0):
            total["flops"] += sub["flops"] * mult
            total["bytes"] += sub["bytes"] * mult
            for k in COLLECTIVES:
                total["coll"][k] += sub["coll"][k] * mult

        for op_name, type_str, opcode, operands, attrs in comp.ops:
            base = opcode.replace("-start", "")
            if base == "while":
                m_c = re.search(r"condition=(%[\w\.\-]+)", attrs)
                m_b = re.search(r"body=(%[\w\.\-]+)", attrs)
                trips = trip_count(comps, m_c.group(1)) if m_c else 1
                if m_b:
                    add(comp_cost(m_b.group(1)), trips)
                continue
            called = re.findall(r"(?:calls|to_apply|branch_computations)="
                                r"\{?(%[\w\.\-]+)", attrs)
            for c in called:
                add(comp_cost(c))
            if base == "dot":
                out_b = _bytes_of(type_str)
                osh = _shape_of(type_str)
                k = 1
                mlhs = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", attrs)
                lhs_sh = comp.shapes.get(operands[0]) if operands else None
                if mlhs and lhs_sh:
                    for d in mlhs.group(1).split(","):
                        if d:
                            k *= lhs_sh[1][int(d)]
                n_out = 1
                if osh:
                    for d in osh[1]:
                        n_out *= d
                total["flops"] += 2.0 * n_out * k
            if base in COLLECTIVES:
                out_b = _bytes_of(type_str)
                in_b = 0.0
                for o in operands:
                    if o in comp.shapes:
                        dt, dims = comp.shapes[o]
                        n = 1
                        for d in dims:
                            n *= d
                        in_b += n * _DTB.get(dt, 4)
                total["coll"][base] += max(out_b, in_b)
            def _op_bytes(o):
                if o not in comp.shapes:
                    return 0.0
                dt, dims = comp.shapes[o]
                n = 1
                for d in dims:
                    n *= d
                return n * _DTB.get(dt, 4)

            if base in _STREAM_OPS:
                total["bytes"] += _bytes_of(type_str) + sum(
                    _op_bytes(o) for o in operands)
            elif base in _SLICE_OPS:
                sizes = [s for s in ([_bytes_of(type_str)] +
                                     [_op_bytes(o) for o in operands]) if s > 0]
                if sizes:
                    total["bytes"] += 2.0 * min(sizes)
        memo[name] = total
        return total

    if entry is None:
        return {"flops": 0.0, "bytes": 0.0,
                "coll": {k: 0.0 for k in COLLECTIVES}}
    return comp_cost(entry)


def top_collectives(hlo: str, k: int = 12):
    """Largest collective call sites: (kind, bytes*trips, trips, op_name).

    The op_name metadata pinpoints the jaxpr source (e.g. which einsum or
    transpose produced the gather) — the hillclimb's profiling signal.
    """
    comps, entry = parse(hlo)
    # compute loop multiplier of every computation reachable from entry
    mult: Dict[str, float] = {}

    def walk(name: str, m: float):
        if name not in comps:
            return
        mult[name] = mult.get(name, 0.0) + m
        comp = comps[name]
        for _n, _t, opcode, _o, attrs in comp.ops:
            called = re.findall(r"(?:calls|to_apply|branch_computations)="
                                r"\{?(%[\w\.\-]+)", attrs)
            if opcode == "while":
                m_c = re.search(r"condition=(%[\w\.\-]+)", attrs)
                m_b = re.search(r"body=(%[\w\.\-]+)", attrs)
                trips = trip_count(comps, m_c.group(1)) if m_c else 1
                if m_b:
                    walk(m_b.group(1), m * trips)
            else:
                for c in called:
                    walk(c, m)

    if entry:
        walk(entry, 1.0)
    rows = []
    for cname, m in mult.items():
        comp = comps[cname]
        for op_name, type_str, opcode, operands, attrs in comp.ops:
            base = opcode.replace("-start", "")
            if base not in COLLECTIVES:
                continue
            b = _bytes_of(type_str)
            meta = re.search(r'op_name="([^"]+)"', attrs)
            rows.append((base, b * m, int(m), b,
                         meta.group(1) if meta else op_name))
    rows.sort(key=lambda r: -r[1])
    return rows[:k]
