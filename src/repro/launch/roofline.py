"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh, derive the three roofline terms
from the compiled per-device program:

  compute term    = HLO_FLOPs_per_device / PEAK_FLOPS          [s]
  memory term     = HLO_bytes_per_device / HBM_BW              [s]
  collective term = collective_bytes_per_device / LINK_BW      [s]

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
cost_analysis() is taken from the SPMD-partitioned (per-device) module, so
all three terms are per-device quantities; MODEL_FLOPS is scaled to
per-device for the usefulness ratio.

Usage:  PYTHONPATH=src python -m repro.launch.roofline --runs runs/dryrun
        (writes a markdown table to stdout + runs/roofline.json)
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s/link
CHIPS = 256              # single-pod mesh

_COUNT_CACHE = {}


def param_counts(arch: str):
    """(total_params, active_params) for one particle (MoE: top-k active)."""
    if arch in _COUNT_CACHE:
        return _COUNT_CACHE[arch]
    import jax
    from .. import configs
    from ..models import api
    from ..sharding import rules
    cfg = configs.get(arch)
    tree = jax.eval_shape(lambda: api.init_params(jax.random.PRNGKey(0), cfg))
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        p = rules.normalize_path(path)
        if re.search(r"moe/(wi|wg|wo)$", p) and cfg.n_experts:
            active += n * cfg.top_k // cfg.n_experts
        else:
            active += n
    _COUNT_CACHE[arch] = (total, active)
    return total, active


def model_flops(rec, shapes):
    """Theoretical useful FLOPs per device: 6*N_active*tokens (train),
    2*N_active*tokens (prefill), 2*N_active*B (decode), x particles."""
    total, active = param_counts(rec["arch"])
    shp = shapes[rec["shape"]]
    P = rec.get("particles", 1)
    if shp.kind == "train":
        f = 6 * active * shp.global_batch * shp.seq_len
    elif shp.kind == "prefill":
        f = 2 * active * shp.global_batch * shp.seq_len
    else:
        f = 2 * active * shp.global_batch
    return f * P / CHIPS


def analyze(runs_dir: str, mesh: str = "single"):
    from ..configs import INPUT_SHAPES
    rows = []
    for f in sorted(glob.glob(os.path.join(runs_dir, f"*__{mesh}.json"))):
        r = json.load(open(f))
        if r["status"] != "ok":
            rows.append({**r, "dominant": "-"})
            continue
        coll = sum(r["collective_bytes_per_device"].values())
        t_c = r["flops_per_device"] / PEAK_FLOPS
        t_m = r["bytes_per_device"] / HBM_BW
        t_n = coll / LINK_BW
        dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
                  key=lambda kv: kv[1])[0]
        mf = model_flops(r, INPUT_SHAPES)
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "particles": r["particles"], "mode": r["mode"],
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_n,
            "dominant": dom,
            "model_flops_per_device": mf,
            "useful_ratio": mf / max(r["flops_per_device"], 1.0),
            "hbm_args_gb": (r.get("memory") or {}).get(
                "argument_size_in_bytes", 0) / 1e9,
            "hbm_temp_gb": (r.get("memory") or {}).get(
                "temp_size_in_bytes", 0) / 1e9,
            "collectives_gb": {k: v / 1e9 for k, v in
                               r["collective_bytes_per_device"].items()},
        })
    return rows


NOTES = {
    "compute": "at the compute roofline — push MFU via tiling/fusion, or cut "
               "redundant FLOPs (causal block pruning, less remat recompute)",
    "memory": "HBM-bound — raise arithmetic intensity (fuse elementwise "
              "chains, wider tiles, bf16 activations)",
    "collective": "ICI-bound — reshard to cut all-gathers, overlap "
                  "collectives with compute, or change the parallelism axis",
}


def markdown(rows):
    out = ["| arch | shape | P | mode | compute s | memory s | collective s | "
           "dominant | useful FLOP ratio | note |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | "
                       f"skip | - | {r.get('reason', r.get('error', ''))[:60]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['particles']} | {r['mode']} "
            f"| {r['t_compute_s']:.3f} | {r['t_memory_s']:.3f} "
            f"| {r['t_collective_s']:.3f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {NOTES[r['dominant']]} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", default="runs/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--json-out", default="runs/roofline.json")
    a = ap.parse_args()
    rows = analyze(a.runs, a.mesh)
    print(markdown(rows))
    with open(a.json_out, "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
