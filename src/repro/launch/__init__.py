# launch: production-mesh factories, multi-host bring-up, run plans,
# step builders, dry-run CLI.
# NOTE: do not import .dryrun here — it sets XLA_FLAGS at import time.
from . import distributed, mesh, plans
