"""SWAG / multi-SWAG (Maddox et al., 2019; Wilson & Izmailov, 2020).

SWAG assumes the posterior is Normal with moments taken from the SGD
trajectory (paper §3.4's "assumptions that introduce densities"):

    mean     <- running average of theta
    sq_mean  <- running average of theta^2
    dev      <- ring buffer of the last K deviations (low-rank covariance)

sample:  theta = mean + sigma_diag^(1/2) z1 / sqrt(2)
                      + D z2 / sqrt(2 (K - 1))

multi-SWAG = an ensemble of SWAG particles: each particle carries its own
moments in particle.state (particle-local computation only -> scales like
deep ensembles in the paper's Fig. 4). The moment update runs through
repro.kernels.swag_moments (Pallas) when ``use_kernel=True``; interpret
mode is gated on the backend platform (compiled on TPU, interpreted
elsewhere) and the flag threads through ``swag_collect``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .infer import Infer


# ---------------------------------------------------------------------------
# functional SWAG state ops (vmappable / jittable; used by both paths)
# ---------------------------------------------------------------------------

def swag_state_init(params, max_rank: int = 20):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {
        "n": jnp.zeros((), jnp.float32),
        "mean": zeros,
        "sq_mean": jax.tree.map(jnp.zeros_like, params),
        "dev": jax.tree.map(
            lambda p: jnp.zeros((max_rank,) + p.shape, p.dtype), params),
        "rank": jnp.zeros((), jnp.int32),
    }


def swag_collect(state, params, use_kernel: bool = True,
                 interpret: Optional[bool] = None):
    """One moment-collection step (after an SGD epoch in the paper's setup).

    ``interpret`` threads to the Pallas kernel: None (default) resolves to
    interpret-off-TPU-only (kernels/swag_moments), True/False force it."""
    n = state["n"]
    if use_kernel:
        from ..kernels import swag_moments as _k

        def upd(mean, sq, p, n_):
            return _k.update_moments(mean, sq, p, n_, interpret=interpret)
    else:
        upd = _update_moments_ref
    mean, sq = upd(state["mean"], state["sq_mean"], params, n)
    max_rank = jax.tree.leaves(state["dev"])[0].shape[0]
    slot = state["rank"] % max_rank
    dev = jax.tree.map(
        lambda d, p, m: jax.lax.dynamic_update_index_in_dim(
            d, (p - m).astype(d.dtype), slot, 0),
        state["dev"], params, mean)
    return {"n": n + 1, "mean": mean, "sq_mean": sq, "dev": dev,
            "rank": state["rank"] + 1}


def _update_moments_ref(mean, sq_mean, params, n):
    new_mean = jax.tree.map(lambda m, p: (m * n + p) / (n + 1), mean, params)
    new_sq = jax.tree.map(lambda s, p: (s * n + p * p) / (n + 1), sq_mean, params)
    return new_mean, new_sq


def swag_sample(state, rng, scale: float = 1.0, *, use_kernel: bool = False,
                interpret: Optional[bool] = None):
    """Draw one parameter sample from the SWAG Gaussian.

    ``use_kernel=True`` computes the diagonal scale through the fused
    Pallas pass (kernels/swag_moments.diag_std_flat); the interpret
    decision is NOT made here — it reuses the moment kernel's platform
    gating (compiled on TPU, interpreted elsewhere; ``interpret`` only
    forces it). This is the serve-time path of
    ``MultiSWAG.posterior_predictive``."""
    k1, k2 = jax.random.split(rng)
    leaves, tdef = jax.tree.flatten(state["mean"])
    z1_keys = jax.random.split(k1, len(leaves))
    max_rank = jax.tree.leaves(state["dev"])[0].shape[0]
    K_eff = jnp.maximum(jnp.minimum(state["rank"], max_rank).astype(jnp.float32), 2.0)
    z2 = jax.random.normal(k2, (max_rank,))
    rank_mask = (jnp.arange(max_rank) < state["rank"]).astype(jnp.float32)

    if use_kernel:
        from ..kernels import swag_moments as _k

        def diag_std(m, s):
            return _k.diag_std_flat(
                m.reshape(-1).astype(jnp.float32),
                s.reshape(-1).astype(jnp.float32),
                interpret=interpret).reshape(m.shape)
    else:
        def diag_std(m, s):
            return jnp.sqrt(jnp.maximum(s - m * m, 1e-30))

    def one(m, s, d, zk):
        diag = diag_std(m, s) * jax.random.normal(zk, m.shape) / jnp.sqrt(2.0)
        zw = (z2 * rank_mask).astype(d.dtype)
        lowrank = jnp.tensordot(zw, d, axes=(0, 0)) / jnp.sqrt(2.0 * (K_eff - 1.0))
        return m + scale * (diag + lowrank).astype(m.dtype)

    sq_leaves = tdef.flatten_up_to(state["sq_mean"])
    dev_leaves = tdef.flatten_up_to(state["dev"])
    out = [one(m, s, d, zk) for m, s, d, zk in
           zip(leaves, sq_leaves, dev_leaves, z1_keys)]
    return tdef.unflatten(out)


def swag_sample_stacked(stacked_state, rng, samples_per_particle: int,
                        scale: float = 1.0, *, use_kernel: bool = False,
                        interpret: Optional[bool] = None):
    """Serve-time sampling over the store's stacked SWAG moments: draw S
    samples from every particle's Gaussian in one vmapped program,
    returning stacked params with leading axis n*S (sample j of particle
    i at row i*S + j) — exactly the shape a PredictiveEngine serves."""
    n = jax.tree.leaves(stacked_state)[0].shape[0]
    S = samples_per_particle
    rep = jax.tree.map(lambda x: jnp.repeat(x, S, axis=0), stacked_state)
    keys = jax.random.split(rng, n * S)
    return jax.vmap(lambda st, k: swag_sample(
        st, k, scale, use_kernel=use_kernel, interpret=interpret))(rep, keys)


# ---------------------------------------------------------------------------
# particle-based multi-SWAG (the paper's path)
# ---------------------------------------------------------------------------

def _swag_collect_fused(state, params):
    """Module-level (stable identity) body of the fused moment-collection
    map_step — the ProgramCache keys on it, so every MultiSWAG in the
    process shares one compiled collection program per shape."""
    return swag_collect(state, params, use_kernel=False)


def _swag_step(particle, batch):
    return particle.step(batch).wait()


def _swag_collect_msg(particle):
    particle.state["swag"] = swag_collect(particle.state["swag"],
                                          particle.state["params"],
                                          use_kernel=False)
    return None


class MultiSWAG(Infer):
    def _create(self, optimizer, num_particles, max_rank):
        pids = []
        for _ in range(num_particles):
            pid = self.push_dist.p_create(
                optimizer, receive={"SWAG_COLLECT": _swag_collect_msg})
            p = self.push_dist.particles[pid]
            p.state["swag"] = swag_state_init(p.state["params"], max_rank)
            pids.append(pid)
        return pids

    def _nel_infer(self, dataloader, epochs: int, *, optimizer,
                   num_particles: int = 4, pretrain_epochs: int = 0,
                   max_rank: int = 20):
        pids = self._create(optimizer, num_particles, max_rank)
        losses = []
        for e in range(epochs):
            for batch in dataloader:
                futs = [self.push_dist.particles[pid].step(batch) for pid in pids]
                losses = [float(f.wait()) for f in futs]
            if e >= pretrain_epochs:  # collect moments once per epoch
                futs = [self.push_dist.p_launch(pid, "SWAG_COLLECT")
                        for pid in pids]
                self.push_dist.p_wait(futs)
        return pids, losses

    def _fused_infer(self, dataloader, epochs: int, *, optimizer,
                     num_particles: int = 4, pretrain_epochs: int = 0,
                     max_rank: int = 20):
        pids = self._create(optimizer, num_particles, max_rank)
        losses = self._fused_epochs(pids, dataloader, epochs,
                                    optimizer=optimizer,
                                    pretrain_epochs=pretrain_epochs)
        return pids, losses

    def _fused_epochs(self, pids, dataloader, epochs: int, *, optimizer,
                      pretrain_epochs: int = 0):
        """Stacked-axis multi-SWAG on existing particles — two thin
        ProgramSpecs on the runtime layer (vmapped train step + vmapped
        moment collection), all state (params, opt, SWAG moments) checked
        out of the store once, donated across the epoch loop, and
        committed back once at the end."""
        from ..runtime import specs
        rt = self._compiled_runtime()
        # the SWAG moments follow the master dtype automatically
        # (zeros_like of the cast params); only the train step carries
        # the compute-cast split
        step_spec = specs.ensemble_step(self.module.loss, optimizer,
                                        precision=self.precision)
        collect_spec = specs.map_step(_swag_collect_fused,
                                      key=("swag_collect",), n_state=2,
                                      masked=True)
        co_pids, mask, slots = self._fused_plan(pids)
        step, collect, ls = None, None, None
        with self._checked_out(co_pids,
                               ("params", "opt_state", "swag")) as co:
            for e in self._traced_epochs(epochs, "swag"):
                for batch in dataloader:
                    if step is None:  # one cache lookup per fused run
                        step = rt.program(step_spec, co["params"],
                                          co["opt_state"], batch, mask)
                    co["params"], co["opt_state"], ls = step(
                        co["params"], co["opt_state"], batch, mask)
                if e >= pretrain_epochs:
                    if collect is None:
                        collect = rt.program(collect_spec, co["swag"],
                                             co["params"], mask)
                    co["swag"] = collect(co["swag"], co["params"], mask)
        return [] if ls is None else [float(ls[s]) for s in slots]

    def posterior_predictive(self, *, samples_per_particle: int = 0,
                             rng=None, scale: float = 1.0,
                             use_kernel: bool = True, **kw):
        """Serve-time handoff: with ``samples_per_particle=S > 0`` the
        service does BMA over n*S fresh draws from each particle's SWAG
        Gaussian (sampled once, up front, into a static stacked tree —
        the multi-SWAG predictive of Wilson & Izmailov 2020) instead of
        the particle means. S=0 serves the live particle params like any
        other Infer. The diagonal-scale read goes through the Pallas
        moments kernel with its platform gating (``use_kernel=True``)."""
        if samples_per_particle <= 0:
            return super().posterior_predictive(**kw)
        rng = jax.random.PRNGKey(0) if rng is None else rng
        # dense live rows (not the capacity-padded canonical form): a
        # padding slot's zero moments must never be sampled as a member
        stacked_swag = self.store.dense("swag")
        sampled = swag_sample_stacked(stacked_swag, rng,
                                      samples_per_particle, scale,
                                      use_kernel=use_kernel)
        return self.push_dist.serve(params=sampled, **kw)

    def sample_predict(self, batch, *, samples_per_particle: int = 5,
                       rng=None, scale: float = 1.0):
        """multi-SWAG prediction: average over SWAG samples of every particle."""
        rng = jax.random.PRNGKey(0) if rng is None else rng
        outs = []
        for pid in self.push_dist.particle_ids():
            p = self.push_dist.particles[pid]
            for _ in range(samples_per_particle):
                rng, sub = jax.random.split(rng)
                theta = swag_sample(p.state["swag"], sub, scale)
                outs.append(self.module._forward(theta, batch))
        return jax.tree.map(lambda *xs: sum(xs) / len(xs), *outs)
