"""Stein Variational Gradient Descent (Liu & Wang, 2016) on particles.

Two implementations, benchmarked against each other in EXPERIMENTS.md §Perf:

1. ``SteinVGD`` — the paper-faithful message-passing version (paper Fig. 5/6):
   a leader particle drives SVGD_STEP (local backward on every particle),
   gathers every particle's (params, grads) via read-only views (all-to-all,
   paper Fig. 1), computes the kernel update, and sends SVGD_FOLLOW to each
   particle. Updates are applied concurrently from read-only snapshots —
   the property the paper credits for beating its monolithic baseline.

2. ``fused_svgd_step`` — the compiled path (``backend="compiled"``):
   stacked particle axis, flattened (n, D) parameter matrix, RBF kernel +
   driving force in one XLA program (Pallas kernels on TPU; jnp oracle
   elsewhere). ``SteinVGD._fused_infer`` drives it on the same particles
   the NEL path would create.

Update rule (standard SVGD, descent form; see DESIGN.md for the sign
discrepancy in the paper's Fig. 6 listing):

    theta_i <- theta_i - (lr / n) * sum_j [ k(theta_j, theta_i) * g_j
                                            - (theta_i - theta_j)/ell^2 * k_ji ]

with g_j = grad of the loss (= -grad log posterior), k = RBF with
bandwidth ell (fixed, or the median heuristic when lengthscale <= 0).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from ..core import functional
from ..core.store import Placement
from .infer import Infer


# ---------------------------------------------------------------------------
# functional core (used by both paths; Pallas-accelerated when enabled)
# ---------------------------------------------------------------------------

def rbf_lengthscale(theta, lengthscale: float):
    """Median heuristic when lengthscale <= 0 (Liu & Wang §5)."""
    if lengthscale > 0:
        return jnp.asarray(lengthscale, jnp.float32)
    n = theta.shape[0]
    sq = pairwise_sqdist(theta)
    med = jnp.median(sq)
    return jnp.sqrt(0.5 * med / jnp.log(n + 1.0) + 1e-12)


def pairwise_sqdist(theta):
    """theta: (n, D) -> (n, n) squared distances (jnp oracle)."""
    sq = jnp.sum(theta * theta, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * theta @ theta.T
    return jnp.maximum(d2, 0.0)


def svgd_force(theta, grads, lengthscale: float, use_kernel: bool = False,
               mask=None):
    """theta, grads: (n, D) -> phi: (n, D) descent direction.

    phi_i = (1/n) sum_j [ k_ji g_j - k_ji (theta_i - theta_j) / ell^2 ]

    With a (n,) active ``mask`` (capacity-padded stores, DESIGN.md §9)
    the sum runs over live slots only — dead rows are where-zeroed on
    the way in (so even NaN padding cannot leak), excluded from the
    kernel matrix via the mask outer product, and get phi = 0 out. The
    result restricted to live rows equals the dense force over just
    those rows. Masked forces use the jnp oracle (the Pallas kernel is
    dense-only)."""
    if mask is None:
        if use_kernel:
            # ops.svgd_force gates Pallas interpret mode on the platform
            # (compiled on TPU, interpreted elsewhere)
            from ..kernels import ops as _k
            return _k.svgd_force(theta, grads, lengthscale)
        ell = rbf_lengthscale(theta, lengthscale)
        K_w, n_eff = 1.0, theta.shape[0]
    else:
        m = mask.astype(theta.dtype)
        mb = m > 0
        theta = jnp.where(mb[:, None], theta, 0.0)
        grads = jnp.where(mb[:, None], grads, 0.0)
        n_eff = jnp.maximum(jnp.sum(m), 1.0)
        if lengthscale > 0:
            ell = jnp.asarray(lengthscale, theta.dtype)
        else:
            # median heuristic over live pairs only
            sq = pairwise_sqdist(theta)
            pair = mb[:, None] & mb[None, :]
            med = jnp.nan_to_num(jnp.nanmedian(jnp.where(pair, sq, jnp.nan)))
            ell = jnp.sqrt(0.5 * med / jnp.log(n_eff + 1.0) + 1e-12)
        K_w = m[:, None] * m[None, :]   # dead pairs fall out of the kernel
    d2 = pairwise_sqdist(theta) * (1.0 - jnp.eye(theta.shape[0]))
    K = jnp.exp(-0.5 * d2 / (ell * ell)) * K_w                 # (n, n), k_ji
    ksum = K.sum(axis=0)                                       # sum_j k_ji
    attract = K.T @ grads                                      # (n, D)
    repulse = (ksum[:, None] * theta - K.T @ theta) / (ell * ell)
    return (attract - repulse) / n_eff


def fused_svgd_step(loss_fn, *, lr: float, lengthscale: float = 1.0,
                    use_kernel: bool = False, placement=None,
                    num_particles: Optional[int] = None,
                    compute_dtype=None):
    """One compiled SVGD step over stacked particles.

    With a mesh placement the per-particle backward pass is distributed
    over the particle axis (``spmd_axis_name``); the cross-particle kernel
    matrix is expressed as an on-device all-gather over that axis: the
    flattened (n, D) matrix is constrained particle-sharded for the local
    math, then constrained gathered (replicated rows, D over `model`) to
    feed the RBF kernel + force, then re-constrained particle-sharded —
    GSPMD lowers those transitions to all-gathers, never to host copies."""
    placement = placement or Placement()
    spmd = (placement.spmd_axis(num_particles)
            if num_particles is not None else None)
    vag = jax.vmap(jax.value_and_grad(lambda p, b: loss_fn(p, b)[0]),
                   in_axes=(0, None), spmd_axis_name=spmd)

    def step(stacked_params, batch, mask=None):
        if compute_dtype is not None:
            # backward pass in the compute dtype; the kernel force below
            # is fp32 regardless (theta32/g32), and the update lands back
            # in the master dtype via new_theta = theta - ...
            from ..core.precision import cast_floats
            losses, grads = vag(cast_floats(stacked_params, compute_dtype),
                                cast_floats(batch, compute_dtype))
            losses = losses.astype(jnp.float32)
        else:
            losses, grads = vag(stacked_params, batch)
        theta, unravel = functional.flatten_stacked(stacked_params)
        g, _ = functional.flatten_stacked(grads)
        theta32 = theta.astype(jnp.float32)
        g32 = g.astype(jnp.float32)
        if placement.mesh is not None:
            n, d = theta32.shape
            wide = placement.matrix(n, d)
            gathered = placement.gathered_matrix(d)
            theta32 = jax.lax.with_sharding_constraint(theta32, wide)
            g32 = jax.lax.with_sharding_constraint(g32, wide)
            # the all-to-all the paper identifies as SVGD's bottleneck
            # (§5.1), as one on-device collective over the particle axis:
            theta_all = jax.lax.with_sharding_constraint(theta32, gathered)
            g_all = jax.lax.with_sharding_constraint(g32, gathered)
            phi = svgd_force(theta_all, g_all, lengthscale,
                             use_kernel=use_kernel, mask=mask)
            phi = jax.lax.with_sharding_constraint(phi, wide)
        else:
            phi = svgd_force(theta32, g32, lengthscale, use_kernel=use_kernel,
                             mask=mask)
        new_theta = theta - lr * phi.astype(theta.dtype)
        if mask is not None:
            # dead slots stay bit-for-bit frozen and report loss 0.0
            new_theta = jnp.where(mask[:, None] > 0, new_theta, theta)
            losses = jnp.where(mask > 0, losses, 0.0)
        new_params = jax.vmap(unravel)(new_theta)
        return new_params, losses

    return step


def svgd_step_spec(loss_fn, *, lr: float, lengthscale: float = 1.0,
                   use_kernel: bool = False, precision=None):
    """ProgramSpec for the fused SVGD step: stacked params sharded over
    the particle axis and donated across the epoch loop; the kernel
    matrix's all-to-all stays an on-device all-gather (fused_svgd_step).

    ``precision`` (None | preset name | Precision) selects the compute
    dtype of the per-particle backward pass; the kernel force is fp32
    either way. The policy is folded into ``ProgramSpec.precision`` —
    the compute cast is traced over the same master inputs, so abstract
    dtypes alone cannot distinguish the programs."""
    from ..core import precision as precision_mod
    from ..runtime import ProgramSpec, ident
    prec = precision_mod.get(precision)
    cd = prec.compute if prec.casts_compute else None

    def make(ctx):
        return fused_svgd_step(
            loss_fn, lr=lr, lengthscale=lengthscale, use_kernel=use_kernel,
            placement=ctx.placement,
            num_particles=ctx.num_particles or None,
            compute_dtype=cd)

    return ProgramSpec(
        name="svgd_step",
        key=("svgd_step", ident(loss_fn), float(lr), float(lengthscale),
             bool(use_kernel)),
        make=make,
        in_kinds=("state", "replicated", "replicated"),
        out_kinds=("in:0", "vector"),
        donate=(0,),
        precision=prec.key() if prec.casts_compute else None)


def compile_svgd_step(loss_fn, placement, stacked, batch, mask=None, *,
                      lr: float, lengthscale: float = 1.0,
                      use_kernel: bool = False, state_token=None,
                      precision=None):
    """The fused SVGD step against a placement plan, lowered and cached
    by the shared ProgramCache (runtime layer). Pass
    ``mask=store.active_mask()`` for the capacity-padded masked program
    and ``state_token=store.generation()`` to share the entry with
    programs the Runtime lowered against that store."""
    from ..runtime import global_cache
    spec = svgd_step_spec(loss_fn, lr=lr, lengthscale=lengthscale,
                          use_kernel=use_kernel, precision=precision)
    args = (stacked, batch) + (() if mask is None else (mask,))
    return global_cache().program(spec, placement, args, state_token)


# ---------------------------------------------------------------------------
# paper-faithful message-passing SVGD (Fig. 5 / Fig. 6)
# ---------------------------------------------------------------------------

def _svgd_step(particle, batch):
    """SVGD_STEP handler: local backward pass, stash grads."""
    return particle.grad(batch).wait()


def _svgd_follow(particle, lr, update):
    """SVGD_FOLLOW handler: apply the leader's kernel update."""
    return particle.apply_update(update, lr).wait()


def _svgd_leader(particle, lr, lengthscale, dataloader, epochs):
    """SVGD_LEADER handler (paper Fig. 6, jax-native).

    Per batch: (1) step every particle (concurrent backward passes),
    (2) gather every particle's params+grads via read-only views,
    (3) compute the kernel force, (4) send SVGD_FOLLOW to every particle.
    """
    n_pids = particle.particle_ids()
    others = [pid for pid in n_pids if pid != particle.pid]
    losses = []
    for _ in range(epochs):
        for batch in dataloader:
            # 1. step every particle
            fut = particle.grad(batch)
            futs = [particle.send(pid, "SVGD_STEP", batch) for pid in others]
            losses = [float(fut.wait())] + [float(f.wait()) for f in futs]

            # 2. gather every other particle's parameters + grads
            views = {pid: particle.get(pid) for pid in others}
            views = {pid: f.wait() for pid, f in views.items()}

            flat, unravel = ravel_pytree(particle.state["params"])
            theta = [flat] + [ravel_pytree(views[pid].parameters())[0]
                              for pid in others]
            gflat = [ravel_pytree(particle.state["grads"])[0]] + \
                    [ravel_pytree(views[pid].gradients())[0] for pid in others]
            theta = jnp.stack(theta).astype(jnp.float32)
            g = jnp.stack(gflat).astype(jnp.float32)

            # 3. kernel force
            phi = svgd_force(theta, g, lengthscale)

            # 4. send updates (concurrent follow)
            futs = [particle.send(pid, "SVGD_FOLLOW", lr, unravel(phi[i + 1]))
                    for i, pid in enumerate(others)]
            _svgd_follow(particle, lr, unravel(phi[0]))
            for f in futs:
                f.wait()
    return losses


class SteinVGD(Infer):
    def _create(self, num_particles: int):
        pid_leader = self.push_dist.p_create(
            None, device=0, receive={"SVGD_LEADER": _svgd_leader,
                                     "SVGD_STEP": _svgd_step,
                                     "SVGD_FOLLOW": _svgd_follow})
        pids = [pid_leader]
        for p in range(num_particles - 1):
            pid = self.push_dist.p_create(
                None, device=(p + 1) % self.num_devices,
                receive={"SVGD_STEP": _svgd_step, "SVGD_FOLLOW": _svgd_follow})
            pids.append(pid)
        return pids

    def _nel_infer(self, dataloader, epochs: int, *, num_particles: int = 4,
                   lengthscale: float = 1.0, lr: float = 1e-3):
        pids = self._create(num_particles)
        losses = self.push_dist.p_wait([self.push_dist.p_launch(
            pids[0], "SVGD_LEADER", lr, lengthscale, dataloader, epochs)])[0]
        return pids, losses

    def _fused_infer(self, dataloader, epochs: int, *, num_particles: int = 4,
                     lengthscale: float = 1.0, lr: float = 1e-3):
        """Compiled stacked-axis SVGD: identical particles (same rng stream
        as the NEL path), the whole kernel step in one XLA program."""
        pids = self._create(num_particles)
        losses = self._fused_epochs(pids, dataloader, epochs, lr=lr,
                                    lengthscale=lengthscale)
        return pids, losses

    def _fused_epochs(self, pids, dataloader, epochs: int, *,
                      lr: float = 1e-3, lengthscale: float = 1.0):
        rt = self._compiled_runtime()
        spec = svgd_step_spec(self.module.loss, lr=lr,
                              lengthscale=lengthscale,
                              precision=self.precision)
        co_pids, mask, slots = self._fused_plan(pids)
        prog, ls = None, None
        with self._checked_out(co_pids, ("params",)) as co:
            for _ in self._traced_epochs(epochs, "svgd"):
                for batch in dataloader:
                    if prog is None:  # one cache lookup per fused run
                        prog = rt.program(spec, co["params"], batch, mask)
                    co["params"], ls = prog(co["params"], batch, mask)
        return [] if ls is None else [float(ls[s]) for s in slots]
