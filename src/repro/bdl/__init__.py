from .infer import Infer
from .ensemble import DeepEnsemble, compiled_ensemble_step
from .swag import MultiSWAG, swag_state_init, swag_collect, swag_sample
from .svgd import SteinVGD, fused_svgd_step, svgd_force, pairwise_sqdist
from . import baselines, lifecycle
