"""Particle lifecycle policies: resample / grow / prune over a live PD.

The paper's pitch — "Push enables easy creation of particles so that an
input NN can be replicated" — only pays off if churn is cheap AND
principled. The elastic ParticleStore (DESIGN.md §9) makes clone/kill
free of recompiles within capacity; this module supplies the *policies*
that decide which particles live:

  * ``resample``  — SMC-style systematic resampling on per-particle
    weights (SVGD birth/death, Del Moral-style population maintenance):
    zero-weight lineages die, high-weight lineages clone with jitter.
    The live count is preserved, so kills free exactly the slots the
    clones reuse — capacity, shapes, and every compiled program survive.
  * ``grow``      — warm-started progressive deep ensembles: new members
    are jittered clones of the current best member (or fresh inits),
    then trained on — the ensemble widens without restarting (the
    trajectory Wilson & Izmailov 2020 motivate for ensembles-as-BMA).
  * ``prune``     — drop the lowest-weight members (serve fewer, better
    particles under a latency budget).
  * ``ensemble_weights`` — the default weight function: softmax(-loss)
    per live particle from one evaluation batch.

All policies run against ``PushDistribution``'s lifecycle API
(``p_clone``/``p_kill``) and are backend-agnostic: the NEL path sees
handlers and optimizer state copied onto the clones; the compiled and
serving paths see slot writes and a flipped active mask.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


def _resolve_pd(obj):
    return getattr(obj, "push_dist", obj)


def ensemble_weights(obj, batch) -> Dict[int, float]:
    """softmax(-loss) over live particles: one *jitted* loss evaluation
    per particle (read-only — no optimizer step; all members share one
    compiled program via the runtime cache), normalized into sampling
    weights. Lower loss => heavier lineage."""
    pd = _resolve_pd(obj)
    losses = {}
    for pid in pd.particle_ids():
        p = pd.particles[pid]
        losses[pid] = float(pd.module._loss_value(p.parameters(), batch))
    xs = np.asarray(list(losses.values()), np.float64)
    xs = np.exp(-(xs - xs.min()))
    xs = xs / xs.sum()
    return dict(zip(losses, xs))


def systematic_counts(weights: Sequence[float], n: int,
                      rng: Optional[np.random.Generator] = None
                      ) -> List[int]:
    """Systematic resampling (one uniform draw, n evenly spaced
    positions against the weight CDF) -> offspring count per input.
    Counts sum to n; variance-minimal among single-draw schemes."""
    w = np.asarray(weights, np.float64)
    if w.sum() <= 0:
        raise ValueError("weights must have positive mass")
    w = w / w.sum()
    if rng is None:
        # fresh entropy, NOT a fixed seed: repeated resample rounds must
        # draw independent offsets or the same rank boundary decides
        # life/death every round
        rng = np.random.default_rng()
    positions = (rng.random() + np.arange(n)) / n
    cum = np.cumsum(w)
    cum[-1] = 1.0                       # float-sum guard
    counts = np.zeros(len(w), np.int64)
    j = 0
    for pos in positions:
        while cum[j] < pos:
            j += 1
        counts[j] += 1
    return counts.tolist()


def resample(obj, weights: Optional[Dict[int, float]] = None, *,
             batch=None, jitter: float = 0.0,
             rng: Optional[np.random.Generator] = None) -> List[int]:
    """SMC-style birth/death over the live particle set.

    ``weights`` maps pid -> weight (defaults to ``ensemble_weights`` on
    ``batch``). Offspring counts come from systematic resampling; a
    particle with count 0 is killed, count k spawns k-1 jittered clones.
    Kills run FIRST so every clone lands in a just-freed slot — the live
    count is preserved and capacity never grows, which is what keeps the
    whole operation recompile-free. Returns the new live pid list."""
    pd = _resolve_pd(obj)
    if weights is None:
        if batch is None:
            raise ValueError("pass weights= or batch= to resample")
        weights = ensemble_weights(pd, batch)
    pids = list(weights)
    counts = systematic_counts([weights[p] for p in pids], len(pids), rng)
    for pid, c in zip(pids, counts):
        if c == 0:
            pd.p_kill(pid)
    for pid, c in zip(pids, counts):
        for _ in range(c - 1):
            pd.p_clone(pid, jitter=jitter)
    return pd.particle_ids()


def grow(obj, n_new: int, *, jitter: float = 0.01,
         weights: Optional[Dict[int, float]] = None, batch=None,
         optimizer=None) -> List[int]:
    """Progressive ensemble growth: add ``n_new`` members warm-started
    as jittered clones of the best current member (by ``weights`` /
    ``batch``; the first live particle when neither is given). With
    ``optimizer=`` the new members get fresh optimizer state instead of
    the source's (cold optimizer, warm params). Growth past capacity
    doubles the store (one generation bump) — preallocate via
    ``PushDistribution(capacity=...)`` to avoid it. Returns new pids."""
    pd = _resolve_pd(obj)
    if weights is None and batch is not None:
        weights = ensemble_weights(pd, batch)
    if weights:
        src = max(weights, key=weights.get)
    else:
        src = pd.particle_ids()[0]
    new = [pd.p_clone(src, jitter=jitter) for _ in range(n_new)]
    if optimizer is not None:
        for pid in new:
            p = pd.particles[pid]
            p.optimizer = optimizer
            p.state["opt_state"] = optimizer.init(p.parameters())
    return new


def prune(obj, keep: int, *, weights: Optional[Dict[int, float]] = None,
          batch=None) -> List[int]:
    """Kill all but the ``keep`` heaviest members (lowest-loss under the
    default weights). Freed slots go on the free list for later clones;
    nothing recompiles. Returns the surviving pid list."""
    pd = _resolve_pd(obj)
    if keep < 1:
        raise ValueError("keep must be >= 1")
    if weights is None:
        if batch is None:
            raise ValueError("pass weights= or batch= to prune")
        weights = ensemble_weights(pd, batch)
    ranked = sorted(weights, key=weights.get, reverse=True)
    for pid in ranked[keep:]:
        pd.p_kill(pid)
    return pd.particle_ids()
