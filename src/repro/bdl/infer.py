"""Infer base class (paper App. B): BDL algorithms extend Infer and express
inference as concurrent procedures on particles. The same algorithm code is
agnostic to the number of devices (paper §B.2 comment 2).

Backend seam (DESIGN.md §3): ``bayes_infer`` is the stable entry point.
Subclasses implement ``_nel_infer`` (the paper-faithful message-passing
procedure) and may implement ``_fused_infer`` (the compiled stacked-axis
form from core/functional.py). Under ``backend="compiled"`` the fused form
is selected transparently when present; algorithms without one fall back
to the NEL path, so every algorithm runs under either backend.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

from ..core import ParticleModule, PushDistribution


class Infer:
    def __init__(self, module: ParticleModule, *, num_devices: int = 1,
                 cache_size: int = 4, view_size: int = 4, seed: int = 0,
                 backend: str = "nel"):
        self.module = module
        self.num_devices = num_devices
        self.push_dist = PushDistribution(module, num_devices=num_devices,
                                          cache_size=cache_size,
                                          view_size=view_size, seed=seed,
                                          backend=backend)

    @property
    def backend(self) -> str:
        return self.push_dist.backend

    def _has_fused(self) -> bool:
        return type(self)._fused_infer is not Infer._fused_infer

    def bayes_infer(self, dataloader, epochs: int, **kw):
        if self.backend == "compiled" and self._has_fused():
            return self._fused_infer(dataloader, epochs, **kw)
        return self._nel_infer(dataloader, epochs, **kw)

    def _nel_infer(self, dataloader, epochs: int, **kw):
        raise NotImplementedError

    def _fused_infer(self, dataloader, epochs: int, **kw):
        raise NotImplementedError  # overriding marks the algorithm as fusable

    def posterior_pred(self, batch):
        return self.push_dist.p_predict(batch)

    def p_parameters(self):
        return [self.push_dist.p_params(pid)
                for pid in self.push_dist.particle_ids()]

    def cleanup(self):
        self.push_dist.cleanup()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.cleanup()
