"""Infer base class (paper App. B): BDL algorithms extend Infer and express
inference as concurrent procedures on particles. The same algorithm code is
agnostic to the number of devices (paper §B.2 comment 2)."""
from __future__ import annotations

from typing import Callable, Optional

import jax

from ..core import ParticleModule, PushDistribution


class Infer:
    def __init__(self, module: ParticleModule, *, num_devices: int = 1,
                 cache_size: int = 4, view_size: int = 4, seed: int = 0):
        self.module = module
        self.num_devices = num_devices
        self.push_dist = PushDistribution(module, num_devices=num_devices,
                                          cache_size=cache_size,
                                          view_size=view_size, seed=seed)

    def bayes_infer(self, dataloader, epochs: int, **kw):
        raise NotImplementedError

    def posterior_pred(self, batch):
        return self.push_dist.p_predict(batch)

    def p_parameters(self):
        return [self.push_dist.p_params(pid)
                for pid in self.push_dist.particle_ids()]

    def cleanup(self):
        self.push_dist.cleanup()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.cleanup()
