"""Infer base class (paper App. B): BDL algorithms extend Infer and express
inference as concurrent procedures on particles. The same algorithm code is
agnostic to the number of devices (paper §B.2 comment 2).

Backend seam (DESIGN.md §3): ``bayes_infer`` is the stable entry point.
Subclasses implement ``_nel_infer`` (the paper-faithful message-passing
procedure) and may implement ``_fused_infer`` (the compiled stacked-axis
form from core/functional.py). Under ``backend="compiled"`` the fused form
is selected transparently when present; algorithms without one fall back
to the NEL path, so every algorithm runs under either backend.

Placement (DESIGN.md §6): ``placement`` is the mesh/placement plan the
fused forms compile against — particle axis sharded over the mesh's
``data`` axis, within-particle sharding from ``sharding/rules``. The
default (no mesh) is the single-device fast path; ``placement="auto"``
builds a mesh over all local devices. The NEL path ignores the mesh (its
devices come from ``num_devices``), but both paths share the PD's
ParticleStore, so state written by one is visible to the other.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Callable, Optional, Union

import jax

from ..core import ParticleModule, Placement, PushDistribution


class Infer:
    def __init__(self, module: ParticleModule, *, num_devices: int = 1,
                 cache_size: int = 4, view_size: int = 4, seed: int = 0,
                 backend: str = "nel",
                 placement: Optional[Union[Placement, str]] = None):
        self.module = module
        self.num_devices = num_devices
        if placement == "auto":
            placement = Placement.auto()
        self.push_dist = PushDistribution(module, num_devices=num_devices,
                                          cache_size=cache_size,
                                          view_size=view_size, seed=seed,
                                          backend=backend,
                                          placement=placement)

    @property
    def backend(self) -> str:
        return self.push_dist.backend

    @property
    def placement(self) -> Placement:
        return self.push_dist.placement

    @property
    def store(self):
        return self.push_dist.store

    def _has_fused(self) -> bool:
        return type(self)._fused_infer is not Infer._fused_infer

    @contextmanager
    def _checked_out(self, pids, keys):
        """Checkout/commit protocol shared by every fused epoch loop: yield
        a dict of stacked state (the loop rebinds its entries as it trains
        on donated buffers); whatever was successfully checked out is
        committed back exactly once, even on mid-loop failure."""
        store = self.push_dist.store
        co = {}
        try:
            for k in keys:
                co[k] = store.checkout(k, pids)
            yield co
        finally:
            for k, v in co.items():
                store.commit(k, v, pids)

    def _reset_step_cache(self, key):
        """Invalidate the cached fused step when `key` changed; the actual
        compile happens lazily against the first real batch (so compiling
        never consumes a dataloader iteration)."""
        if getattr(self, "_step_key", None) != key:
            self._step_key, self._step = key, None

    def bayes_infer(self, dataloader, epochs: int, **kw):
        if self.backend == "compiled" and self._has_fused():
            return self._fused_infer(dataloader, epochs, **kw)
        return self._nel_infer(dataloader, epochs, **kw)

    def _nel_infer(self, dataloader, epochs: int, **kw):
        raise NotImplementedError

    def _fused_infer(self, dataloader, epochs: int, **kw):
        raise NotImplementedError  # overriding marks the algorithm as fusable

    def posterior_pred(self, batch):
        return self.push_dist.p_predict(batch)

    def posterior_predictive(self, **kw):
        """Hand a trained posterior off to the serving layer: a
        PredictiveService doing fused BMA over this Infer's particles
        (repro.serve). Algorithms whose posterior is richer than its
        particles override this — MultiSWAG samples its Gaussians at
        serve time. Caller owns the service (use as a context manager)."""
        return self.push_dist.serve(**kw)

    def p_parameters(self):
        return [self.push_dist.p_params(pid)
                for pid in self.push_dist.particle_ids()]

    def cleanup(self):
        self.push_dist.cleanup()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.cleanup()
