"""Infer base class (paper App. B): BDL algorithms extend Infer and express
inference as concurrent procedures on particles. The same algorithm code is
agnostic to the number of devices (paper §B.2 comment 2).

Backend seam (DESIGN.md §3, §8): ``bayes_infer`` is the stable entry
point; it hands the algorithm to the PD's Runtime object
(``repro.runtime.backends``). Subclasses implement ``_nel_infer`` (the
paper-faithful message-passing procedure) and may implement
``_fused_infer`` (thin ProgramSpec builders + an epoch loop on the
store's checkout/commit protocol). The CompiledRuntime selects the fused
form transparently when present; algorithms without one fall back to the
NEL path, so every algorithm runs under either backend.

Placement (DESIGN.md §6): ``placement`` is the mesh/placement plan the
fused forms compile against — particle axis sharded over the mesh's
``data`` axis, within-particle sharding from ``sharding/rules``. The
default (no mesh) is the single-device fast path; ``placement="auto"``
builds a mesh over all local devices. The NEL path ignores the mesh (its
devices come from ``num_devices``), but both paths share the PD's
ParticleStore, so state written by one is visible to the other.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Callable, Optional, Union

import jax

from ..core import ParticleModule, Placement, PushDistribution


def _init_shapes(module):
    """Abstract per-particle param tree (eval_shape: no FLOPs, no
    memory) for policy-aware ``Placement.auto`` sizing; None when the
    module's init cannot be traced abstractly."""
    try:
        return jax.eval_shape(module.init, jax.random.PRNGKey(0))
    except Exception:
        return None


class Infer:
    def __init__(self, module: ParticleModule, *, num_devices: int = 1,
                 cache_size: int = 4, view_size: int = 4, seed: int = 0,
                 backend: str = "nel",
                 placement: Optional[Union[Placement, str]] = None,
                 capacity: int = 0, precision=None):
        self.module = module
        self.num_devices = num_devices
        if placement == "auto":
            # policy-aware sizing: the model axis is picked against the
            # MASTER-dtype per-particle bytes, so a bf16 store does not
            # reserve 2x the model shards it needs
            placement = Placement.auto(
                model="auto", precision=precision,
                param_tree=_init_shapes(module))
        # capacity preallocates store slots so a planned lifecycle
        # (bayes_infer then lifecycle.grow) never pays a growth recompile
        self.push_dist = PushDistribution(module, num_devices=num_devices,
                                          cache_size=cache_size,
                                          view_size=view_size, seed=seed,
                                          backend=backend,
                                          placement=placement,
                                          capacity=capacity,
                                          precision=precision)

    @property
    def backend(self) -> str:
        return self.push_dist.backend

    @property
    def precision(self):
        return self.push_dist.precision

    @property
    def placement(self) -> Placement:
        return self.push_dist.placement

    @property
    def store(self):
        return self.push_dist.store

    def _has_fused(self) -> bool:
        return type(self)._fused_infer is not Infer._fused_infer

    @contextmanager
    def _checked_out(self, pids, keys):
        """Checkout/commit protocol shared by every fused epoch loop: yield
        a dict of stacked state (the loop rebinds its entries as it trains
        on donated buffers); whatever was successfully checked out is
        committed back exactly once, even on mid-loop failure."""
        store = self.push_dist.store
        co = {}
        try:
            for k in keys:
                co[k] = store.checkout(k, pids)
            yield co
        finally:
            for k, v in co.items():
                store.commit(k, v, pids)

    def _fused_plan(self, pids):
        """(checkout pids, active mask, row index per pid) for one fused
        run over `pids`.

        Full live set in slot order -> the canonical capacity-padded
        path: checkout with ``None`` (padded trees whose shapes survive
        churn) plus the store's active mask; any other subset -> a dense
        checkout of exactly those rows under an all-ones mask. Loss
        vectors coming back from masked programs are indexed with the
        returned slots."""
        import jax.numpy as jnp
        store = self.push_dist.store
        pids = list(pids)
        # set comparison, not order: after churn store.pids is in slot
        # order while callers enumerate in pid order — both mean "the
        # full live set", and the returned per-pid slots keep the loss
        # indexing right either way
        if len(pids) == len(store) and set(pids) == set(store.pids):
            return None, store.active_mask(), [store.slot_of(p)
                                               for p in pids]
        return pids, jnp.ones((len(pids),), jnp.float32), \
            list(range(len(pids)))

    def _compiled_runtime(self):
        """The PD's runtime when it is already the compiled one, else a
        CompiledRuntime over the same PD/cache — benchmarks drive
        ``_fused_epochs`` directly on NEL-backend instances to time the
        fused path in isolation."""
        from ..runtime import CompiledRuntime
        rt = self.push_dist.runtime
        return rt if isinstance(rt, CompiledRuntime) \
            else CompiledRuntime(self.push_dist, rt.cache)

    @staticmethod
    def _traced_epochs(epochs: int, label: str):
        """Iterate ``range(epochs)``, bracketing each epoch's body (the
        code between yields) in an obs ``bdl.epoch`` span plus a
        ``jax.profiler.StepTraceAnnotation`` so device profiles show
        per-epoch step markers. Free when tracing is off."""
        from ..obs import trace as _trace
        if not _trace.enabled():
            yield from range(epochs)
            return
        for e in range(epochs):
            with _trace.span("bdl.epoch", "bdl", algo=label, epoch=e), \
                    jax.profiler.StepTraceAnnotation(label, step_num=e):
                yield e

    def bayes_infer(self, dataloader, epochs: int, **kw):
        return self.push_dist.runtime.infer(self, dataloader, epochs, **kw)

    def _nel_infer(self, dataloader, epochs: int, **kw):
        raise NotImplementedError

    def _fused_infer(self, dataloader, epochs: int, **kw):
        raise NotImplementedError  # overriding marks the algorithm as fusable

    def posterior_pred(self, batch):
        return self.push_dist.p_predict(batch)

    def posterior_predictive(self, **kw):
        """Hand a trained posterior off to the serving layer: a
        PredictiveService doing fused BMA over this Infer's particles
        (repro.serve). Algorithms whose posterior is richer than its
        particles override this — MultiSWAG samples its Gaussians at
        serve time. Caller owns the service (use as a context manager)."""
        return self.push_dist.serve(**kw)

    def p_parameters(self):
        return [self.push_dist.p_params(pid)
                for pid in self.push_dist.particle_ids()]

    def cleanup(self):
        self.push_dist.cleanup()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.cleanup()
