"""Deep ensembles (Lakshminarayanan et al., 2017) on particles.

No communication between particles (paper §3.1) — each particle trains
independently on its own device timeline; the NEL overlaps their steps
across devices. This is the best-scaling algorithm in the paper's Fig. 4.

Under ``backend="compiled"`` the same algorithm lowers to one fused XLA
program over the stacked particle axis (core/functional.py): identical
per-particle inits (the PD's rng stream is shared by both paths), one
vmapped value_and_grad + optimizer update per batch, state checked out of
the ParticleStore once, donated to XLA every step (multi-epoch training
never leaves the device), and committed back once at the end. With a
mesh placement the particle axis is sharded across devices
(``spmd_axis_name`` + explicit in/out shardings).
"""
from __future__ import annotations

import jax

from ..core import functional
from .infer import Infer


class DeepEnsemble(Infer):
    def _nel_infer(self, dataloader, epochs: int, *, optimizer,
                   num_particles: int = 4):
        pids = [self.push_dist.p_create(optimizer) for _ in range(num_particles)]
        losses = []
        for _ in range(epochs):
            for batch in dataloader:
                futs = [self.push_dist.particles[pid].step(batch) for pid in pids]
                losses = [float(f.wait()) for f in futs]
        return pids, losses

    def _fused_infer(self, dataloader, epochs: int, *, optimizer,
                     num_particles: int = 4):
        pids = [self.push_dist.p_create(optimizer) for _ in range(num_particles)]
        losses = self._fused_epochs(pids, dataloader, epochs,
                                    optimizer=optimizer)
        return pids, losses

    def _fused_epochs(self, pids, dataloader, epochs: int, *, optimizer):
        """Train existing particles for `epochs` through the fused program
        (store checkout -> donated compiled loop -> one commit). Reused by
        benchmarks so the timed region is exactly the backend="compiled"
        epoch path."""
        placement = self.placement
        self._reset_step_cache((id(optimizer), id(placement), len(pids)))
        ls = None
        with self._checked_out(pids, ("params", "opt_state")) as co:
            for _ in range(epochs):
                for batch in dataloader:
                    if self._step is None:  # compile against the real batch
                        self._step = functional.compile_ensemble_step(
                            self.module.loss, optimizer, placement,
                            co["params"], co["opt_state"], batch)
                    co["params"], co["opt_state"], ls = self._step(
                        co["params"], co["opt_state"], batch)
        return [] if ls is None else [float(l) for l in ls]


def compiled_ensemble_step(module, optimizer):
    """Fused path: all particles in one XLA program (single-device form;
    mesh-aware compilation lives in functional.compile_ensemble_step)."""
    step = functional.ensemble_step(module.loss, optimizer)
    return jax.jit(step)
