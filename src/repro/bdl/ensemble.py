"""Deep ensembles (Lakshminarayanan et al., 2017) on particles.

No communication between particles (paper §3.1) — each particle trains
independently on its own device timeline; the NEL overlaps their steps
across devices. This is the best-scaling algorithm in the paper's Fig. 4.

Under ``backend="compiled"`` the same algorithm lowers to one fused XLA
program over the stacked particle axis: ``_fused_epochs`` is a thin
builder over the runtime layer — one ``ensemble_step`` ProgramSpec
(repro.runtime.specs), lowered/cached by the shared ProgramCache, driven
on state checked out of the ParticleStore once, donated to XLA every step
(multi-epoch training never leaves the device), and committed back once
at the end. Identical per-particle inits (the PD's rng stream is shared
by both paths); with a mesh placement the particle axis is sharded across
devices (``spmd_axis_name`` + explicit in/out shardings).
"""
from __future__ import annotations

from ..runtime import specs
from .infer import Infer


class DeepEnsemble(Infer):
    def _nel_infer(self, dataloader, epochs: int, *, optimizer,
                   num_particles: int = 4):
        pids = [self.push_dist.p_create(optimizer) for _ in range(num_particles)]
        losses = []
        for _ in range(epochs):
            for batch in dataloader:
                futs = [self.push_dist.particles[pid].step(batch) for pid in pids]
                losses = [float(f.wait()) for f in futs]
        return pids, losses

    def _fused_infer(self, dataloader, epochs: int, *, optimizer,
                     num_particles: int = 4):
        pids = [self.push_dist.p_create(optimizer) for _ in range(num_particles)]
        losses = self._fused_epochs(pids, dataloader, epochs,
                                    optimizer=optimizer)
        return pids, losses

    def _fused_epochs(self, pids, dataloader, epochs: int, *, optimizer):
        """Train existing particles for `epochs` through the fused program
        (store checkout -> donated compiled loop -> one commit). Reused by
        benchmarks so the timed region is exactly the backend="compiled"
        epoch path."""
        rt = self._compiled_runtime()
        spec = specs.ensemble_step(self.module.loss, optimizer,
                                   precision=self.precision)
        co_pids, mask, slots = self._fused_plan(pids)
        prog, ls = None, None
        with self._checked_out(co_pids, ("params", "opt_state")) as co:
            for _ in self._traced_epochs(epochs, "ensemble"):
                for batch in dataloader:
                    if prog is None:  # one cache lookup per fused run
                        prog = rt.program(spec, co["params"],
                                          co["opt_state"], batch, mask)
                    co["params"], co["opt_state"], ls = prog(
                        co["params"], co["opt_state"], batch, mask)
        return [] if ls is None else [float(ls[s]) for s in slots]


def compiled_ensemble_step(module, optimizer):
    """Fused path: all particles in one XLA program. Returns a callable
    compiling lazily per argument shapes through the shared ProgramCache
    (single-device form; pass a placement via runtime specs for meshes).

    NON-donating (dataclasses.replace of the epoch-loop spec): callers
    of this standalone helper may reuse their input arrays after the
    call — the donation plan is part of the cache key, so this never
    collides with the donating program the epoch loop uses."""
    import dataclasses

    from ..runtime import global_cache
    spec = dataclasses.replace(specs.ensemble_step(module.loss, optimizer),
                               donate=())
    cache = global_cache()

    def step(stacked_params, stacked_opt_state, batch):
        return cache.run(spec, stacked_params, stacked_opt_state, batch)

    return step
