"""Deep ensembles (Lakshminarayanan et al., 2017) on particles.

No communication between particles (paper §3.1) — each particle trains
independently on its own device timeline; the NEL overlaps their steps
across devices. This is the best-scaling algorithm in the paper's Fig. 4.
"""
from __future__ import annotations

import jax

from ..core import functional
from .infer import Infer


class DeepEnsemble(Infer):
    def bayes_infer(self, dataloader, epochs: int, *, optimizer,
                    num_particles: int = 4):
        pids = [self.push_dist.p_create(optimizer) for _ in range(num_particles)]
        losses = []
        for _ in range(epochs):
            for batch in dataloader:
                futs = [self.push_dist.particles[pid].step(batch) for pid in pids]
                losses = [float(f.wait()) for f in futs]
        return pids, losses


def compiled_ensemble_step(module, optimizer):
    """Beyond-paper fused path: all particles in one XLA program."""
    step = functional.ensemble_step(module.loss, optimizer)
    return jax.jit(step)
