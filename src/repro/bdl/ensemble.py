"""Deep ensembles (Lakshminarayanan et al., 2017) on particles.

No communication between particles (paper §3.1) — each particle trains
independently on its own device timeline; the NEL overlaps their steps
across devices. This is the best-scaling algorithm in the paper's Fig. 4.

Under ``backend="compiled"`` the same algorithm lowers to one fused XLA
program over the stacked particle axis (core/functional.py): identical
per-particle inits (the PD's rng stream is shared by both paths), one
vmapped value_and_grad + optimizer update per batch, results written
back into the particles.
"""
from __future__ import annotations

import jax

from ..core import functional
from .infer import Infer


class DeepEnsemble(Infer):
    def _nel_infer(self, dataloader, epochs: int, *, optimizer,
                   num_particles: int = 4):
        pids = [self.push_dist.p_create(optimizer) for _ in range(num_particles)]
        losses = []
        for _ in range(epochs):
            for batch in dataloader:
                futs = [self.push_dist.particles[pid].step(batch) for pid in pids]
                losses = [float(f.wait()) for f in futs]
        return pids, losses

    def _fused_infer(self, dataloader, epochs: int, *, optimizer,
                     num_particles: int = 4):
        pids = [self.push_dist.p_create(optimizer) for _ in range(num_particles)]
        losses = self._fused_epochs(pids, dataloader, epochs,
                                    optimizer=optimizer)
        return pids, losses

    def _fused_epochs(self, pids, dataloader, epochs: int, *, optimizer):
        """Train existing particles for `epochs` through the fused program
        (stack -> compiled loop -> write back). Reused by benchmarks so the
        timed region is exactly the backend="compiled" epoch path."""
        pd = self.push_dist
        stacked = pd.p_stack(pids)
        opt_state = pd.p_stack(pids, key="opt_state")
        # cache the jitted step per optimizer so repeated calls don't retrace
        if getattr(self, "_step_key", None) != id(optimizer):
            self._step_key = id(optimizer)
            self._step = compiled_ensemble_step(self.module, optimizer)
        losses = []
        for _ in range(epochs):
            for batch in dataloader:
                stacked, opt_state, ls = self._step(stacked, opt_state, batch)
                losses = [float(l) for l in ls]
        pd.p_unstack(pids, stacked)
        pd.p_unstack(pids, opt_state, key="opt_state")
        return losses


def compiled_ensemble_step(module, optimizer):
    """Fused path: all particles in one XLA program."""
    step = functional.ensemble_step(module.loss, optimizer)
    return jax.jit(step)
