"""Handwritten (non-particle) baselines, as compared against in paper §5.1.

These are the "baseline implementations" of Fig. 4: single-process,
sequential-over-networks, no particle abstraction. The SVGD baseline
materializes the full kernel matrix and updates all parameters only after
the kernel matrix is computed, keeping one copy of each NN (paper §5.1's
description verbatim).

Not to be confused with ``backend="compiled"`` (DESIGN.md §3): the
compiled backend is *fused* (vmapped over a stacked particle axis, one
XLA program); these baselines are deliberately sequential Python loops —
the grey curves the particle runtime is measured against.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from ..runtime import ident, jit_program
from .svgd import svgd_force
from .swag import swag_collect, swag_state_init

# The baselines are deliberately sequential, but their single-NN programs
# still compile through the shared runtime cache (jit_program): one
# compiled program per module, visible in the same hit/miss/cold-compile
# stats as the particle paths. Fetched Programs are memoized module-level
# (keyed on the same identity tokens the cache uses) so the per-step host
# cost stays a dict lookup — the timed baseline rows must measure the
# sequential math, not cache-key construction.

_PROGRAMS: dict = {}


def _memo_program(name, key, fn, args):
    prog = _PROGRAMS.get(key)
    if prog is None:
        prog = _PROGRAMS[key] = jit_program(name, key, fn, args)
    return prog


def _jit_sgd_step(module, optimizer, params, opt_state, batch):
    def step(p, s, b):
        loss, grads = jax.value_and_grad(lambda pp: module.loss(pp, b)[0])(p)
        new_p, new_s = optimizer.update(p, grads, s)
        return new_p, new_s, loss

    prog = _memo_program(
        "baseline_sgd_step",
        ("baseline_sgd_step", ident(module), ident(optimizer)),
        step, (params, opt_state, batch))
    return prog(params, opt_state, batch)


def _jit_grad(module, params, batch):
    def g(p, b):
        return jax.grad(lambda pp: module.loss(pp, b)[0])(p)

    prog = _memo_program("baseline_grad", ("baseline_grad", ident(module)),
                         g, (params, batch))
    return prog(params, batch)


def _jit_kernel_update(theta, g, lr, lengthscale):
    def upd(t, gg):
        return t - lr * svgd_force(t, gg, lengthscale)

    prog = _memo_program(
        "baseline_kernel_update",
        ("baseline_kernel_update", float(lr), float(lengthscale)),
        upd, (theta, g))
    return prog(theta, g)


def _baseline_collect(state, params):
    return swag_collect(state, params, use_kernel=False)


def _jit_collect(state, params):
    prog = _memo_program("baseline_swag_collect", ("baseline_swag_collect",),
                         _baseline_collect, (state, params))
    return prog(state, params)


def ensemble_baseline(module, optimizer, n: int, dataloader, epochs: int,
                      seed: int = 0):
    """Sequential deep ensemble: train each NN one after another."""
    rngs = jax.random.split(jax.random.PRNGKey(seed), n)
    all_params = [module.init(r) for r in rngs]
    opt_states = [optimizer.init(p) for p in all_params]
    losses = [0.0] * n
    for _ in range(epochs):
        for batch in dataloader:
            for i in range(n):
                all_params[i], opt_states[i], l = _jit_sgd_step(
                    module, optimizer, all_params[i], opt_states[i], batch)
                losses[i] = float(l)
    return all_params, losses


def multiswag_baseline(module, optimizer, n: int, dataloader, epochs: int,
                       pretrain_epochs: int = 0, max_rank: int = 20,
                       seed: int = 0):
    """Sequential multi-SWAG: ensemble training + per-NN moment collection."""
    rngs = jax.random.split(jax.random.PRNGKey(seed), n)
    all_params = [module.init(r) for r in rngs]
    opt_states = [optimizer.init(p) for p in all_params]
    swag_states = [swag_state_init(p, max_rank) for p in all_params]
    for e in range(epochs):
        for batch in dataloader:
            for i in range(n):
                all_params[i], opt_states[i], _ = _jit_sgd_step(
                    module, optimizer, all_params[i], opt_states[i], batch)
        if e >= pretrain_epochs:
            for i in range(n):
                swag_states[i] = _jit_collect(swag_states[i], all_params[i])
    return all_params, swag_states


def svgd_baseline(module, n: int, dataloader, epochs: int, *, lr: float,
                  lengthscale: float = 1.0, seed: int = 0):
    """Monolithic SVGD: full kernel matrix, then update all params (one copy
    of each NN, no concurrency — paper §5.1 baseline)."""
    rngs = jax.random.split(jax.random.PRNGKey(seed), n)
    all_params = [module.init(r) for r in rngs]
    _, unravel = ravel_pytree(all_params[0])
    for _ in range(epochs):
        for batch in dataloader:
            grads = [_jit_grad(module, p, batch) for p in all_params]  # sequential
            theta = jnp.stack([ravel_pytree(p)[0] for p in all_params])
            g = jnp.stack([ravel_pytree(gr)[0] for gr in grads])
            theta = _jit_kernel_update(theta.astype(jnp.float32),
                                       g.astype(jnp.float32), lr, lengthscale)
            all_params = [unravel(theta[i]) for i in range(n)]
    return all_params
