"""Handwritten (non-particle) baselines, as compared against in paper §5.1.

These are the "baseline implementations" of Fig. 4: single-process,
sequential-over-networks, no particle abstraction. The SVGD baseline
materializes the full kernel matrix and updates all parameters only after
the kernel matrix is computed, keeping one copy of each NN (paper §5.1's
description verbatim).

Not to be confused with ``backend="compiled"`` (DESIGN.md §3): the
compiled backend is *fused* (vmapped over a stacked particle axis, one
XLA program); these baselines are deliberately sequential Python loops —
the grey curves the particle runtime is measured against.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from functools import partial

from .svgd import svgd_force
from .swag import swag_collect, swag_state_init


@partial(jax.jit, static_argnums=(0, 1))
def _jit_sgd_step(module, optimizer, params, opt_state, batch):
    loss, grads = jax.value_and_grad(lambda p: module.loss(p, batch)[0])(params)
    params, opt_state = optimizer.update(params, grads, opt_state)
    return params, opt_state, loss


@partial(jax.jit, static_argnums=(0,))
def _jit_grad(module, params, batch):
    return jax.grad(lambda p: module.loss(p, batch)[0])(params)


@partial(jax.jit, static_argnums=(2, 3))
def _jit_kernel_update(theta, g, lr, lengthscale):
    return theta - lr * svgd_force(theta, g, lengthscale)


@jax.jit
def _jit_collect(state, params):
    return swag_collect(state, params, use_kernel=False)


def ensemble_baseline(module, optimizer, n: int, dataloader, epochs: int,
                      seed: int = 0):
    """Sequential deep ensemble: train each NN one after another."""
    rngs = jax.random.split(jax.random.PRNGKey(seed), n)
    all_params = [module.init(r) for r in rngs]
    opt_states = [optimizer.init(p) for p in all_params]
    losses = [0.0] * n
    for _ in range(epochs):
        for batch in dataloader:
            for i in range(n):
                all_params[i], opt_states[i], l = _jit_sgd_step(
                    module, optimizer, all_params[i], opt_states[i], batch)
                losses[i] = float(l)
    return all_params, losses


def multiswag_baseline(module, optimizer, n: int, dataloader, epochs: int,
                       pretrain_epochs: int = 0, max_rank: int = 20,
                       seed: int = 0):
    """Sequential multi-SWAG: ensemble training + per-NN moment collection."""
    rngs = jax.random.split(jax.random.PRNGKey(seed), n)
    all_params = [module.init(r) for r in rngs]
    opt_states = [optimizer.init(p) for p in all_params]
    swag_states = [swag_state_init(p, max_rank) for p in all_params]
    for e in range(epochs):
        for batch in dataloader:
            for i in range(n):
                all_params[i], opt_states[i], _ = _jit_sgd_step(
                    module, optimizer, all_params[i], opt_states[i], batch)
        if e >= pretrain_epochs:
            for i in range(n):
                swag_states[i] = _jit_collect(swag_states[i], all_params[i])
    return all_params, swag_states


def svgd_baseline(module, n: int, dataloader, epochs: int, *, lr: float,
                  lengthscale: float = 1.0, seed: int = 0):
    """Monolithic SVGD: full kernel matrix, then update all params (one copy
    of each NN, no concurrency — paper §5.1 baseline)."""
    rngs = jax.random.split(jax.random.PRNGKey(seed), n)
    all_params = [module.init(r) for r in rngs]
    _, unravel = ravel_pytree(all_params[0])
    for _ in range(epochs):
        for batch in dataloader:
            grads = [_jit_grad(module, p, batch) for p in all_params]  # sequential
            theta = jnp.stack([ravel_pytree(p)[0] for p in all_params])
            g = jnp.stack([ravel_pytree(gr)[0] for gr in grads])
            theta = _jit_kernel_update(theta.astype(jnp.float32),
                                       g.astype(jnp.float32), lr, lengthscale)
            all_params = [unravel(theta[i]) for i in range(n)]
    return all_params
