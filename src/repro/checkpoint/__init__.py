from .ckpt import (latest_step, latest_store_step, restore, restore_store,
                   save, save_store)
