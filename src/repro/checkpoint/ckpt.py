"""Step-versioned pytree checkpointing (npz-based, no orbax dependency).

save(dir, step, tree) writes <dir>/ckpt_<step>.npz with flattened leaves +
a JSON treedef manifest; restore(dir, step=None) returns (step, tree).
Atomic via tmp-file rename. Works for params, optimizer states and SWAG
moments alike (anything jax.tree-flattenable with array leaves).
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {f"leaf_{i}": np.asarray(v) for i, (_, v) in enumerate(leaves_with_paths)}
    manifest = {"paths": [_keystr(p) for p, _ in leaves_with_paths],
                "step": step}
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp.npz")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez(f, __manifest__=json.dumps(manifest), **arrays)
    os.replace(tmp, path)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None, like: Any = None
            ) -> Tuple[int, Any]:
    """Returns (step, tree). If `like` is given, leaves are reassembled into
    its structure (paths must match); else a flat {path: array} dict."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    data = np.load(path, allow_pickle=False)
    manifest = json.loads(str(data["__manifest__"]))
    arrays = [data[f"leaf_{i}"] for i in range(len(manifest["paths"]))]
    if like is None:
        return step, dict(zip(manifest["paths"], arrays))
    leaves_with_paths, tdef = jax.tree_util.tree_flatten_with_path(like)
    by_path = dict(zip(manifest["paths"], arrays))
    out = []
    for p, leaf in leaves_with_paths:
        key = _keystr(p)
        if key not in by_path:
            raise KeyError(f"checkpoint missing leaf {key}")
        out.append(jax.numpy.asarray(by_path[key], dtype=leaf.dtype))
    return step, jax.tree_util.tree_unflatten(tdef, out)
