"""Step-versioned pytree checkpointing (npz-based, no orbax dependency).

save(dir, step, tree) writes <dir>/ckpt_<step>.npz with flattened leaves +
a JSON treedef manifest; restore(dir, step=None) returns (step, tree).
Atomic via tmp-file rename. Works for params, optimizer states and SWAG
moments alike (anything jax.tree-flattenable with array leaves).

Store-aware checkpointing (serving handoff): ``save_store`` writes a
whole ParticleStore — every state key's canonical stacked pytree plus
placement metadata (particle axis, mode, mesh shape) and the pid
registry — into ONE npz; ``restore_store`` rebuilds a ready-to-serve
store in one round trip, re-placing stacked state on a mesh without
replaying inference. Tree structure is self-describing (typed key paths
in the manifest), so no template pytree is needed on restore.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _keystr(path) -> str:
    return jax.tree_util.keystr(path)


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {f"leaf_{i}": np.asarray(v) for i, (_, v) in enumerate(leaves_with_paths)}
    manifest = {"paths": [_keystr(p) for p, _ in leaves_with_paths],
                "step": step}
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp.npz")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez(f, __manifest__=json.dumps(manifest), **arrays)
    os.replace(tmp, path)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int] = None, like: Any = None
            ) -> Tuple[int, Any]:
    """Returns (step, tree). If `like` is given, leaves are reassembled into
    its structure (paths must match); else a flat {path: array} dict."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    data = np.load(path, allow_pickle=False)
    manifest = json.loads(str(data["__manifest__"]))
    arrays = [data[f"leaf_{i}"] for i in range(len(manifest["paths"]))]
    if like is None:
        return step, dict(zip(manifest["paths"], arrays))
    leaves_with_paths, tdef = jax.tree_util.tree_flatten_with_path(like)
    by_path = dict(zip(manifest["paths"], arrays))
    out = []
    for p, leaf in leaves_with_paths:
        key = _keystr(p)
        if key not in by_path:
            raise KeyError(f"checkpoint missing leaf {key}")
        out.append(jax.numpy.asarray(by_path[key], dtype=leaf.dtype))
    return step, jax.tree_util.tree_unflatten(tdef, out)


# ---------------------------------------------------------------------------
# store-aware checkpointing (one round trip for a whole ParticleStore)
# ---------------------------------------------------------------------------

def _skeleton(tree, leaves: List[Any]):
    """Self-describing structure record: dict/tuple/list/None nodes plus
    leaf indices into the flat array list (tuples restore as tuples —
    unlike keypath-based reconstruction, empty containers survive)."""
    if tree is None:
        return {"t": "none"}
    if isinstance(tree, dict):
        if not all(isinstance(k, str) for k in tree):
            raise TypeError("store checkpoint requires str dict keys")
        return {"t": "dict", "k": {k: _skeleton(v, leaves)
                                   for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"t": "tuple" if isinstance(tree, tuple) else "list",
                "c": [_skeleton(v, leaves) for v in tree]}
    leaves.append(tree)
    return {"t": "leaf", "i": len(leaves) - 1}


def _rebuild(skel, arrays):
    t = skel["t"]
    if t == "none":
        return None
    if t == "dict":
        return {k: _rebuild(v, arrays) for k, v in skel["k"].items()}
    if t in ("tuple", "list"):
        out = [_rebuild(v, arrays) for v in skel["c"]]
        return tuple(out) if t == "tuple" else out
    return arrays[skel["i"]]


# npz cannot round-trip extension dtypes (bfloat16 is an ml_dtypes
# registration, not a native numpy descr) without pickling; such leaves
# are stored widened to fp32 and the manifest records the true dtype so
# restore_store re-casts them back exactly.
_NPZ_NATIVE = frozenset(
    "bool int8 int16 int32 int64 uint8 uint16 uint32 uint64 "
    "float16 float32 float64 complex64 complex128".split())


def _npz_savable(arr: np.ndarray) -> np.ndarray:
    return arr if arr.dtype.name in _NPZ_NATIVE else arr.astype(np.float32)


def save_store(ckpt_dir: str, step: int, store,
               keys: Optional[List[str]] = None) -> str:
    """Write a ParticleStore — every key's *live* rows (dense, in slot
    order), the pid/slot registry, capacity + free-slot list + active
    mask, and the placement plan — as <dir>/store_<step>.npz.

    Dense-rows (not the capacity-padded canonical form) is what makes
    restore elastic: padding rows never hit disk, and the file can be
    re-placed onto a different capacity or device count. Keys that
    cannot stack (e.g. ``grads`` of an un-stepped particle) are skipped
    when ``keys`` is not explicit; keys held by only some particles
    record exactly which pids hold them."""
    os.makedirs(ckpt_dir, exist_ok=True)
    explicit = keys is not None
    keys = list(keys) if explicit else store.keys()
    live = list(store.pids)
    arrays: Dict[str, np.ndarray] = {}
    skels: Dict[str, Any] = {}
    for ki, key in enumerate(keys):
        pids_k = [p for p in live if store.has(key, p)]
        try:
            if not pids_k:
                raise KeyError(key)
            st = store.dense(key, pids_k)
        except (KeyError, TypeError, ValueError):
            if explicit:
                raise
            continue
        flat: List[Any] = []
        skels[key] = _skeleton(st, flat)
        dtypes = []
        for i, leaf in enumerate(flat):
            arr = np.asarray(leaf)
            dtypes.append(arr.dtype.name)
            arrays[f"k{ki}_l{i}"] = _npz_savable(arr)
        skels[key]["_slot"] = ki
        skels[key]["_pids"] = pids_k
        # per-leaf dtypes (flat order): the precision ladder's restore
        # contract — a bf16 store round-trips as bf16 even though the
        # npz payload is widened (see _npz_savable)
        skels[key]["_dtypes"] = dtypes
    pl = store.placement
    # slot layout recorded for forensics/tooling; restore_store re-derives
    # its own layout from the pids' saved (slot) order, so these three
    # fields never constrain a restore onto a different capacity
    live_slots = {p: store.slot_of(p) for p in live}
    occupied = set(live_slots.values())
    manifest = {
        "step": step,
        "pids": live,
        "capacity": store.capacity,
        "slots": {str(p): s for p, s in live_slots.items()},
        "free": sorted(set(range(store.capacity)) - occupied),
        "active_mask": [int(s in occupied)
                        for s in range(store.capacity)],
        "placement": {
            "particle_axis": pl.particle_axis,
            "model_axis": pl.model_axis,
            "mode": pl.mode,
            "mesh_shape": (None if pl.mesh is None
                           else [int(pl.mesh.shape[a])
                                 for a in pl.mesh.axis_names]),
            "mesh_axes": (None if pl.mesh is None
                          else list(pl.mesh.axis_names)),
        },
        # the precision policy the store ran under + the dtype surface
        # each key actually held — restore revives the policy by default
        "precision": (store.precision.describe()
                      if getattr(store, "precision", None) is not None
                      else None),
        "dtypes": {k: store.key_dtypes(k) for k in skels},
        "keys": skels,
    }
    path = os.path.join(ckpt_dir, f"store_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp.npz")
    os.close(fd)
    with open(tmp, "wb") as f:
        np.savez(f, __store_manifest__=json.dumps(manifest), **arrays)
    os.replace(tmp, path)
    return path


def latest_store_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"store_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_store(ckpt_dir: str, step: Optional[int] = None,
                  placement=None, capacity: Optional[int] = None,
                  precision=None) -> Tuple[int, Any]:
    """Rebuild a ready-to-serve ParticleStore from ``save_store`` output.

    Returns (step, store): pids re-registered (slot order preserved),
    every saved key committed back as dense live rows and re-flushed to
    the capacity-padded canonical form, state re-placed on a mesh — so a
    PredictiveEngine can serve it immediately, no inference replay.

    Elastic restore: ``capacity`` overrides the saved capacity (rounded
    up to a power of two, never below the live count) — a store saved at
    capacity 4 can restore at 8 with room to grow, or shrink-fit; the
    active mask and free-slot list re-derive from the new slot layout.
    ``placement``: an explicit Placement wins; None tries to revive the
    saved plan (a mesh of the saved shape when the local device count
    matches, else single-device).

    Precision (DESIGN.md §13): leaves restore at their saved dtypes (the
    manifest records them; npz payloads for extension dtypes like bf16
    are widened on disk). ``precision=`` overrides the saved policy and
    RE-CASTS master state on load — a fp32 checkpoint restores straight
    into a bf16 store and vice versa; kv scratch keys follow the
    policy's ``kv_dtype`` instead of the master dtype."""
    from ..core.precision import Precision, cast_floats
    from ..core.precision import get as _resolve_precision
    from ..core.store import ParticleStore, Placement

    if step is None:
        step = latest_store_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no store checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"store_{step:08d}.npz")
    data = np.load(path, allow_pickle=False)
    manifest = json.loads(str(data["__store_manifest__"]))
    meta = manifest["placement"]
    if placement is None:
        mesh = None
        if meta["mesh_shape"] is not None:
            n_want = int(np.prod(meta["mesh_shape"]))
            n_have = len(jax.devices())
            if n_want <= n_have and n_have % n_want == 0:
                from ..launch.mesh import make_mesh
                mesh = make_mesh(tuple(meta["mesh_shape"]),
                                 tuple(meta["mesh_axes"]))
        placement = Placement(mesh=mesh, particle_axis=meta["particle_axis"],
                              mode=meta["mode"],
                              # pre-2D checkpoints carry no model axis
                              model_axis=meta.get("model_axis", "model"))
    saved_prec = manifest.get("precision")
    if precision is None and saved_prec is not None:
        precision = Precision(master_dtype=saved_prec["master"],
                              compute_dtype=saved_prec["compute"],
                              serve_dtype=saved_prec["serve"],
                              serve_quant=saved_prec.get("serve_quant"),
                              kv_dtype=saved_prec.get("kv"))
    prec = _resolve_precision(precision)
    pids = manifest["pids"]
    want_cap = capacity if capacity is not None \
        else manifest.get("capacity", len(pids))
    store = ParticleStore(placement, capacity=max(want_cap, len(pids)),
                          precision=prec)
    for pid in pids:          # saved slot order -> same relative layout
        store.register(pid)
    for key, skel in manifest["keys"].items():
        ki = skel["_slot"]
        arrays = []
        while f"k{ki}_l{len(arrays)}" in data:
            arrays.append(data[f"k{ki}_l{len(arrays)}"])
        dts = skel.get("_dtypes")
        if dts:   # undo the on-disk widening of extension dtypes (bf16)
            arrays = [a if a.dtype.name == d
                      else jax.numpy.asarray(a).astype(jax.numpy.dtype(d))
                      for a, d in zip(arrays, dts)]
        tree = _rebuild(skel, arrays)
        if tree is None:
            continue
        tree = jax.tree_util.tree_map(jax.numpy.asarray, tree)
        # the policy's master re-cast (fp32 <-> bf16, both directions);
        # kv scratch keys track kv_dtype, never the master dtype
        if key.startswith("kv"):
            if prec.kv_dtype is not None:
                tree = cast_floats(tree, prec.kv_dtype)
        else:
            tree = cast_floats(tree, prec.master)
        pids_k = skel.get("_pids", pids)
        # per-pid row writes (not a full commit): the saved rows are
        # dense while the new store's capacity may differ from the saved
        # one — the flush below pads and places the canonical form
        for j, p in enumerate(pids_k):
            store.write(key, p, jax.tree_util.tree_map(
                lambda x, j=j: x[j], tree))
        store.stacked(key)
    return step, store
