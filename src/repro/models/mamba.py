"""Mamba2 (SSD) block, chunked-scan training + O(1)-state decode.

Per head h (state: d_state x head_dim):

    a_t = exp(-softplus(dt_t + dt_bias) * exp(A_log))      scalar decay
    h_t = a_t h_{t-1} + dt_t * B_t^T x_t
    y_t = C_t h_t + D * x_t

Because the decay is *scalar per head per step* (unlike RWKV6's
per-channel decay), the chunked form is pure matmuls:

    scores_ts = (C_t . B_s) * exp(ca_t - ca_s) * dt_s      (s <= t)
    y_intra   = scores @ x
    y_inter_t = exp(ca_t) * (C_t h_0)
    h_L       = exp(ca_L) h_0 + sum_s exp(ca_L - ca_s) dt_s B_s^T x_s

with ca the inclusive cumulative log decay (everything <= 0: no overflow).
Includes the Mamba2 depthwise causal conv (width cfg.ssm_conv) on (x,B,C)
and the gated RMSNorm before out_proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .blocks import dense_init, dense_apply, norm_init, norm_apply
from ..sharding.policy import maybe_shard


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    return d_inner, H, cfg.ssm_head_dim, cfg.ssm_state


def mamba_init(key, cfg):
    D = cfg.d_model
    d_inner, H, hd, ds = _dims(cfg)
    conv_dim = d_inner + 2 * ds
    ks = jax.random.split(key, 4)
    return {
        "ln": norm_init(cfg.norm, D),
        # in_proj -> [z (d_inner), x (d_inner), B (ds), C (ds), dt (H)]
        "in_proj": dense_init(ks[0], D, 2 * d_inner + 2 * ds + H),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),           # A = -exp(A_log) = -1
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "gn": norm_init("rms", d_inner),
        "out_proj": dense_init(ks[2], d_inner, D),
    }


def _causal_conv(w, b, x, state=None):
    """Depthwise causal conv. x: (B, S, C); state: (B, K-1, C) carry-in.

    Returns (y, new_state) with new_state = last K-1 inputs.
    """
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    y = jax.nn.silu(y + b.astype(x.dtype))
    return y, xp[:, -(K - 1):]


def _project(p, xin, cfg):
    """xin: (B, S, D) -> z, x, Bm, Cm, dt (+conv applied), plus raw conv input."""
    d_inner, H, hd, ds = _dims(cfg)
    proj = dense_apply(p["in_proj"], xin)
    z, xr, Bm, Cm, dt = jnp.split(proj, [d_inner, 2 * d_inner, 2 * d_inner + ds,
                                         2 * d_inner + 2 * ds], axis=-1)
    conv_in = jnp.concatenate([xr, Bm, Cm], axis=-1)
    return z, conv_in, dt


def _ssd_inputs(p, conv_out, dt, cfg):
    d_inner, H, hd, ds = _dims(cfg)
    B_, S = conv_out.shape[0], conv_out.shape[1]
    xr, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + ds], axis=-1)
    xh = maybe_shard(xr.reshape(B_, S, H, hd), "ssm_heads")
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # (B, S, H)
    loga = -dtv * jnp.exp(p["A_log"])                                  # (B, S, H) <= 0
    return xh, Bm, Cm, dtv, loga


def mamba_block_full(p, x, cfg, chunk: int = 64, st=None):
    """x: (B, S, D) -> (out, state dict)."""
    B_, S, D = x.shape
    d_inner, H, hd, ds = _dims(cfg)
    xin = norm_apply(p["ln"], x)
    z, conv_in, dt = _project(p, xin, cfg)
    conv_out, conv_state = _causal_conv(p["conv_w"], p["conv_b"], conv_in,
                                        None if st is None else st["conv"])
    xh, Bm, Cm, dtv, loga = _ssd_inputs(p, conv_out, dt, cfg)

    L = min(chunk, S)
    n = -(-S // L)
    pad = n * L - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))   # log a = 0 keeps state

    @jax.checkpoint
    def chunk_step(h0, inp):
        xx, BB, CC, dd, la = inp                          # (B, L, ...), f32 below
        xx32, BB32, CC32 = xx.astype(jnp.float32), BB.astype(jnp.float32), CC.astype(jnp.float32)
        ca = jnp.cumsum(la, axis=1)                       # (B, L, H)
        # intra-chunk
        cbts = jnp.einsum("btn,bsn->bts", CC32, BB32)     # (B, t, s)
        decay = jnp.exp(ca[:, :, None] - ca[:, None, :])  # (B, t, s, H)
        tri = jnp.tril(jnp.ones((decay.shape[1],) * 2, bool))
        scores = cbts[..., None] * decay * dd[:, None, :, :]          # (B, t, s, H)
        scores = jnp.where(tri[None, :, :, None], scores, 0.0)
        y = jnp.einsum("btsh,bshp->bthp", scores, xx32)
        # inter
        y = y + jnp.einsum("btn,bth,bhnp->bthp", CC32, jnp.exp(ca), h0)
        # state update
        caL = ca[:, -1:]                                  # (B, 1, H)
        w = jnp.exp(caL - ca) * dd                        # (B, L, H)
        h1 = jnp.exp(caL[:, 0])[:, :, None, None] * h0 + jnp.einsum(
            "bsn,bsh,bshp->bhnp", BB32, w, xx32)
        return h1, y.astype(x.dtype)

    h0 = (jnp.zeros((B_, H, ds, hd), jnp.float32) if st is None else st["ssm"])
    xs = tuple(t.reshape(B_, n, L, *t.shape[2:]).swapaxes(0, 1)
               for t in (xh, Bm, Cm, dtv, loga))
    h_final, ys = lax.scan(chunk_step, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B_, n * L, H, hd)[:, :S]
    y = y + p["D"].astype(x.dtype)[None, None, :, None] * xh[:, :S].astype(x.dtype)
    y = y.reshape(B_, S, d_inner)
    y = norm_apply(p["gn"], y * jax.nn.silu(z))
    out = dense_apply(p["out_proj"], y)
    return x + out, {"ssm": h_final, "conv": conv_state}


def mamba_ref(p, x, cfg):
    """Per-step scan oracle."""
    B_, S, D = x.shape
    d_inner, H, hd, ds = _dims(cfg)
    xin = norm_apply(p["ln"], x)
    z, conv_in, dt = _project(p, xin, cfg)
    conv_out, _ = _causal_conv(p["conv_w"], p["conv_b"], conv_in)
    xh, Bm, Cm, dtv, loga = _ssd_inputs(p, conv_out, dt, cfg)

    def step(h, inp):
        xx, BB, CC, dd, la = inp
        xx, BB, CC = xx.astype(jnp.float32), BB.astype(jnp.float32), CC.astype(jnp.float32)
        h = jnp.exp(la)[:, :, None, None] * h + jnp.einsum(
            "bn,bh,bhp->bhnp", BB, dd, xx)
        y = jnp.einsum("bn,bhnp->bhp", CC, h)
        return h, y

    xs = tuple(t.swapaxes(0, 1) for t in (xh, Bm, Cm, dtv, loga))
    h0 = jnp.zeros((B_, H, ds, hd), jnp.float32)
    _, ys = lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1).astype(x.dtype)
    y = y + p["D"].astype(x.dtype)[None, None, :, None] * xh.astype(x.dtype)
    y = y.reshape(B_, S, d_inner)
    y = norm_apply(p["gn"], y * jax.nn.silu(z))
    return x + dense_apply(p["out_proj"], y)


def mamba_state_init(cfg, batch: int, dtype=jnp.float32):
    d_inner, H, hd, ds = _dims(cfg)
    conv_dim = d_inner + 2 * ds
    return {"ssm": jnp.zeros((batch, H, ds, hd), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype)}


def mamba_block_decode(p, x, cfg, st):
    """x: (B, 1, D). O(1) recurrent step."""
    B_, _, D = x.shape
    d_inner, H, hd, ds = _dims(cfg)
    xin = norm_apply(p["ln"], x)
    z, conv_in, dt = _project(p, xin, cfg)
    conv_out, conv_state = _causal_conv(p["conv_w"], p["conv_b"], conv_in, st["conv"])
    xh, Bm, Cm, dtv, loga = _ssd_inputs(p, conv_out, dt, cfg)
    xx, BB, CC = (t[:, 0].astype(jnp.float32) for t in (xh, Bm, Cm))
    dd, la = dtv[:, 0], loga[:, 0]
    h = jnp.exp(la)[:, :, None, None] * st["ssm"] + jnp.einsum("bn,bh,bhp->bhnp", BB, dd, xx)
    y = jnp.einsum("bn,bhnp->bhp", CC, h)[:, None].astype(x.dtype)
    y = y + p["D"].astype(x.dtype)[None, None, :, None] * xh.astype(x.dtype)
    y = y.reshape(B_, 1, d_inner)
    y = norm_apply(p["gn"], y * jax.nn.silu(z))
    out = dense_apply(p["out_proj"], y)
    return x + out.astype(x.dtype), {"ssm": h, "conv": conv_state}
