"""Mixture-of-Experts layer: top-k router + static-shape sort-gather dispatch.

TPU adaptation notes (DESIGN.md §2): GPU MoE implementations use ragged
grouped-GEMM (megablocks). On TPU under pjit we keep every shape static:

  1. router: logits (T, E) -> top-k (weights, expert ids)
  2. dispatch: sort the T*k (token, expert) assignments by expert id, then
     scatter into a fixed (E, C) slot buffer, C = capacity per expert
     (tokens beyond capacity are dropped — standard Switch-style capacity).
  3. expert compute: one batched einsum over the (E, C, D) buffer against
     expert weights (E, D, F) — MXU-friendly, and with experts sharded over
     the `model` mesh axis this is expert parallelism: GSPMD materializes
     the token all-to-all that the roofline analysis measures.
  4. combine: gather back to token order, weight, and sum over k.

Aux losses: load-balance (Switch) + router z-loss, returned for logging.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .blocks import dense_init, dense_apply, mlp_init, mlp_apply
from ..sharding.policy import maybe_shard


def moe_init(key, cfg):
    ks = jax.random.split(key, 5)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    def ew(k, a, b):
        return jax.random.normal(k, (E, a, b), jnp.float32) / jnp.sqrt(a)
    p = {
        "router": dense_init(ks[0], D, E),
        "wi": ew(ks[1], D, F),
        "wg": ew(ks[2], D, F),
        "wo": ew(ks[3], F, D),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, d_ff=cfg.shared_d_ff)
    return p


def capacity(cfg, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    # round up to 128 so the capacity axis stays shardable / MXU-aligned
    return max(128, -(-c // 128) * 128)


def moe_apply(p, x, cfg):
    """x: (B, S, D) -> (out (B, S, D), aux dict)."""
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(cfg, T)
    xt = x.reshape(T, D)

    logits = dense_apply(p["router"], xt.astype(jnp.float32))        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, k)                               # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm

    # ---- dispatch: sort assignments by expert, slot within capacity ----
    flat_e = top_e.reshape(-1)                                       # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # position of each assignment within its expert group
    counts = jnp.bincount(se, length=E)                              # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * k) - starts[se]
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)                 # E*C = drop bin

    buf = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(xt[st])
    buf = maybe_shard(buf[:-1].reshape(E, C, D), "moe_buffer")

    # ---- expert compute (experts sharded over `model` => all-to-all) ----
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(x.dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(x.dtype))
    h = maybe_shard(jax.nn.silu(g) * h, "moe_buffer")
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))   # (E, C, D)
    out_e = maybe_shard(out_e, "moe_buffer")

    # ---- combine: gather back to assignment order, weighted scatter-add --
    out_flat = out_e.reshape(E * C, D)
    per_assign = jnp.where(keep[:, None], out_flat[jnp.minimum(slot, E * C - 1)], 0.0)
    per_assign = maybe_shard(per_assign, "moe_tokens")   # (T*k, D) token-sharded
    y = jnp.zeros((T, D), x.dtype).at[st].add(per_assign * sw[:, None].astype(x.dtype))
    y = maybe_shard(y, "moe_tokens")

    if "shared" in p:
        y = y + mlp_apply(p["shared"], xt, cfg)

    # ---- aux losses ----
    frac_tokens = jnp.bincount(top_e.reshape(-1), length=E) / (T * k)
    frac_probs = probs.mean(0)
    lb_loss = E * jnp.sum(frac_tokens * frac_probs)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - keep.mean()
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "dropped_frac": dropped}
    return y.reshape(B, S, D), aux


def moe_ref(p, x, cfg):
    """Dense (no-capacity, no-drop) oracle for tests: loops experts."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = dense_apply(p["router"], xt.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    y = jnp.zeros_like(xt)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xt @ p["wg"][e]) * (xt @ p["wi"][e])
        oe = h @ p["wo"][e]
        w = jnp.sum(jnp.where(top_e == e, top_p, 0.0), axis=-1)
        y = y + oe * w[:, None]
    if "shared" in p:
        y = y + mlp_apply(p["shared"], xt, cfg)
    return y.reshape(B, S, D)
