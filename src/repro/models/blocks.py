"""Core NN blocks: norms, RoPE, GQA attention (chunked/flash + decode), MLPs.

All blocks are pure functions over parameter pytrees (plain dicts) so they
compose with vmap (particle axis), pjit (mesh sharding) and lax.scan
(layer stacking). No framework dependency.

Attention never materializes an (Sq, Sk) score matrix for the full
sequence: training/prefill use a double-chunked online-softmax scan
(flash-attention structurally, in pure jnp — the Pallas VMEM-tiled version
for TPU lives in repro.kernels.attention and is numerically checked
against this one), decode uses a single-query pass over the KV cache.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..sharding.policy import maybe_shard


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, scale: float = 1.0):
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * (scale / math.sqrt(d_in))
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense_apply(p, x):
    w = p["w"]
    if isinstance(w, dict):
        # int8 serve pack (core.precision.quantize_int8): expand q * s at
        # the matmul — callers can run quantized trees straight through
        # the model without a whole-tree dequantize
        w = w["q"].astype(w["s"].dtype) * w["s"]
    y = x @ w.astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def norm_init(kind: str, d: int):
    if kind == "rms":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def norm_apply(p, x, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # LayerNorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:            # RMSNorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) or (S,)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq          # (..., S, half)
    ang = ang[..., :, None, :]                                        # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention masks (built per chunk — never (S, S) for the full sequence)
# --------------------------------------------------------------------------

def _chunk_mask(kind: str, q_pos, k_pos, *, window: int = 0, prefix_len=0):
    """q_pos: (qc,), k_pos: (kc,) -> bool (qc, kc) allowed."""
    q = q_pos[:, None]
    k = k_pos[None, :]
    if kind == "bidir":
        return jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    causal = k <= q
    if kind == "causal":
        return causal
    if kind == "sliding":
        return causal & (k > q - window)
    if kind == "prefix":
        return causal | (k < prefix_len)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# flash attention (pure-jnp, double-chunked online softmax)
# --------------------------------------------------------------------------

NEG_INF = -1e30


def _fa_fwd_impl(q, k, v, kind, window, prefix_len, q_offset, softcap,
                 q_chunk, k_chunk):
    """Padded-shape flash forward. Returns (out (B,Sqp,H,hd), L (B,KVH,G,Sqp))
    with L = logsumexp of the score rows (the flash softmax stats)."""
    B, Sqp, H, hd = q.shape
    Skp, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    nq, nk = Sqp // q_chunk, Skp // k_chunk
    qg = q.reshape(B, nq, q_chunk, KVH, G, hd)
    kg = k.reshape(B, nk, k_chunk, KVH, hd)
    vg = v.reshape(B, nk, k_chunk, KVH, hd)

    def one_q_chunk(qi):
        qq = qg[:, qi] * scale                       # (B, qc, KVH, G, hd)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m, l, acc = carry
            kk = lax.dynamic_index_in_dim(kg, ki, 1, keepdims=False)
            vv = lax.dynamic_index_in_dim(vg, ki, 1, keepdims=False)
            s = jnp.einsum("bqngh,bknh->bngqk", qq, kk).astype(jnp.float32)
            if softcap > 0.0:
                s = softcap * jnp.tanh(s / softcap)
            k_pos = ki * k_chunk + jnp.arange(k_chunk)
            mask = _chunk_mask(kind, q_pos, k_pos, window=window,
                               prefix_len=prefix_len)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))                 # (B,KVH,G,qc)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bngqk,bknh->bngqh", p.astype(vv.dtype), vv)
            acc = acc * corr[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KVH, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        lsafe = jnp.maximum(l, 1e-30)
        out = (acc / lsafe[..., None]).astype(q.dtype)
        L = m + jnp.log(lsafe)                                     # (B,KVH,G,qc)
        return jnp.moveaxis(out, 3, 1).reshape(B, q_chunk, H, hd), L

    out, L = lax.map(one_q_chunk, jnp.arange(nq))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * q_chunk, H, hd)
    L = jnp.moveaxis(L, 0, -2).reshape(B, KVH, G, nq * q_chunk)
    return out, L


def _fa_bwd_impl(q, k, v, out, L, do, kind, window, prefix_len, q_offset,
                 softcap, q_chunk, k_chunk):
    """Blockwise flash backward (recompute attention; O(S*chunk) memory)."""
    del softcap  # bwd path only used with softcap == 0 (asserted by caller)
    B, Sqp, H, hd = q.shape
    Skp, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    nq, nk = Sqp // q_chunk, Skp // k_chunk
    qg = q.reshape(B, nq, q_chunk, KVH, G, hd)
    kg = k.reshape(B, nk, k_chunk, KVH, hd)
    vg = v.reshape(B, nk, k_chunk, KVH, hd)
    dog = do.reshape(B, nq, q_chunk, KVH, G, hd)
    Lg = L.reshape(B, KVH, G, nq, q_chunk)
    # D_i = rowsum(do * out)
    Df = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    Dg = Df.reshape(B, nq, q_chunk, KVH, G)

    def per_q_chunk(carry, qi):
        dk_acc, dv_acc = carry                       # (B, Skp, KVH, hd) f32
        qq = qg[:, qi].astype(jnp.float32)           # (B,qc,KVH,G,hd)
        doo = dog[:, qi].astype(jnp.float32)
        Li = Lg[:, :, :, qi]                         # (B,KVH,G,qc)
        Di = Dg[:, qi].transpose(0, 2, 3, 1)         # (B,KVH,G,qc)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def per_k_chunk(inner, ki):
            dq_c, dk_acc, dv_acc = inner
            kk = lax.dynamic_index_in_dim(kg, ki, 1, keepdims=False).astype(jnp.float32)
            vv = lax.dynamic_index_in_dim(vg, ki, 1, keepdims=False).astype(jnp.float32)
            s = jnp.einsum("bqngh,bknh->bngqk", qq * scale, kk)
            k_pos = ki * k_chunk + jnp.arange(k_chunk)
            mask = _chunk_mask(kind, q_pos, k_pos, window=window,
                               prefix_len=prefix_len)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - Li[..., None])                       # (B,n,g,qc,kc)
            dp = jnp.einsum("bqngh,bknh->bngqk", doo, vv)
            ds = p * (dp - Di[..., None])
            dq_c = dq_c + jnp.einsum("bngqk,bknh->bqngh", ds, kk) * scale
            dk_blk = jnp.einsum("bngqk,bqngh->bknh", ds, qq) * scale
            dv_blk = jnp.einsum("bngqk,bqngh->bknh", p, doo)
            dk_acc = lax.dynamic_update_slice_in_dim(
                dk_acc, lax.dynamic_slice_in_dim(dk_acc, ki * k_chunk, k_chunk, 1)
                + dk_blk, ki * k_chunk, 1)
            dv_acc = lax.dynamic_update_slice_in_dim(
                dv_acc, lax.dynamic_slice_in_dim(dv_acc, ki * k_chunk, k_chunk, 1)
                + dv_blk, ki * k_chunk, 1)
            return (dq_c, dk_acc, dv_acc), None

        dq0 = jnp.zeros((B, q_chunk, KVH, G, hd), jnp.float32)
        (dq_c, dk_acc, dv_acc), _ = lax.scan(
            per_k_chunk, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dq_c

    # keep the accumulators in the flash layout (S local, heads sharded):
    # without this they inherit the sequence-sharded residual layout and
    # every inner dynamic-update-slice re-gathers them (§Perf iteration 2)
    dk0 = maybe_shard(jnp.zeros((B, Skp, KVH, hd), jnp.float32), "attn_kv")
    dv0 = maybe_shard(jnp.zeros((B, Skp, KVH, hd), jnp.float32), "attn_kv")
    (dk, dv), dqs = lax.scan(per_q_chunk, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, Sqp, H, hd)
    dq = maybe_shard(dq, "attn_heads")
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def flash_attention(q, k, v, *, kind: str = "causal", window: int = 0,
                    prefix_len=0, q_offset=0, softcap: float = 0.0,
                    q_chunk: int = 512, k_chunk: int = 1024):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KVH, hd) -> (B, Sq, H, hd).

    GQA via head grouping; double-chunked online softmax; memory is
    O(Sq * k_chunk), never (Sq, Sk). Differentiable via a custom VJP that
    recomputes attention blockwise (flash backward) — AD through the
    forward scan would otherwise save per-step score blocks (full S^2).
    """
    B, Sq, H, hd = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    q_chunk = min(q_chunk, max(Sq, 1))
    k_chunk = min(k_chunk, max(Sk, 1))
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // k_chunk)
    pq, pk = nq * q_chunk - Sq, nk * k_chunk - Sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:  # padded keys are masked via prefix/causal/window position checks
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    # mask out padded keys for non-causal kinds by position validity:
    # _chunk_mask handles causal/window; for bidir/prefix we clamp via kind
    # -> use a window-free trick: treat pad keys as future (pos >= Sk).
    pad_kind = kind
    if kind == "bidir" and pk:
        # bidirectional with key padding: emulate with a prefix mask over the
        # real keys and push q positions negative so the causal branch of the
        # prefix mask never fires -> every query sees exactly keys [0, Sk).
        pad_kind, prefix_len = "prefix", Sk
        q_offset = -(nq * q_chunk + 1)

    statics = (pad_kind, window, prefix_len, q_offset, softcap, q_chunk, k_chunk)

    if softcap > 0.0:
        out, _ = _fa_fwd_impl(q, k, v, *statics)   # no custom bwd w/ softcap
        return out[:, :Sq]

    @jax.custom_vjp
    def fa(q, k, v):
        return _fa_fwd_impl(q, k, v, *statics)[0]

    def fa_fwd(q, k, v):
        out, L = _fa_fwd_impl(q, k, v, *statics)
        return out, (q, k, v, out, L)

    def fa_bwd(res, do):
        return _fa_bwd_impl(*res, do, *statics)

    fa.defvjp(fa_fwd, fa_bwd)
    return fa(q, k, v)[:, :Sq]


def decode_attention(q, k_cache, v_cache, *, k_pos, cur_pos, softcap: float = 0.0,
                     use_kernel: bool = False):
    """Single-step attention over a KV cache.

    q: (B, 1, H, hd); k_cache, v_cache: (B, C, KVH, hd);
    k_pos: (B, C) absolute position of each cache slot (-1 = empty);
    cur_pos: scalar or (B,) current absolute position.

    ``use_kernel=True`` routes through the Pallas VMEM-tiled decode
    kernel (repro.kernels.ops.decode_attention, online softmax, one HBM
    pass over the cache; interpret mode platform-gated there) — the LM
    serve path's hot spot. Softcap models keep the jnp form (the kernel
    has no softcap).
    """
    if use_kernel and softcap == 0.0:
        from ..kernels import ops as _kops
        del cur_pos
        return _kops.decode_attention(q, k_cache, v_cache, k_pos)
    B, _, H, hd = q.shape
    C, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(hd)
    qq = (q[:, 0] * scale).reshape(B, KVH, G, hd)
    s = jnp.einsum("bngh,bknh->bngk", qq, k_cache)           # (B, KVH, G, C)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    del cur_pos  # slots are only ever written up to the current position
    valid = k_pos >= 0                                       # (B, C)
    s = jnp.where(valid[:, None, None, :], s.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bngk,bknh->bngh", p, v_cache)
    return out.reshape(B, 1, H, hd)


# --------------------------------------------------------------------------
# attention layer (init/apply over full-seq and decode paths)
# --------------------------------------------------------------------------

def attn_init(key, cfg):
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model),
    }


def attn_qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    hd = cfg.hd
    q = dense_apply(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = dense_apply(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense_apply(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply_fullseq(p, x, cfg, *, kind="causal", window=0, prefix_len=0,
                       positions=None, cross_kv=None):
    """Full-sequence attention (training / prefill). Returns (out, (k, v))."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    if cross_kv is None:
        q, k, v = attn_qkv(p, x, cfg, positions if cfg.rope_theta > 0 else None)
    else:
        hd = cfg.hd
        q = dense_apply(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
        if cfg.rope_theta > 0:
            q = rope(q, positions, cfg.rope_theta)
        k, v = cross_kv
        kind = "bidir"
    # pin the flash layout: heads -> `model`, S local per shard. Without
    # this, S stays sequence-sharded (residual policy) and every k-block
    # slice in the flash scans re-gathers the full K/V (EXPERIMENTS.md
    # §Perf iteration 1: 6.6 TB -> 0.1 TB of all-gather per step on
    # qwen1.5-0.5b train_4k).
    q = maybe_shard(q, "attn_heads")
    k = maybe_shard(k, "attn_kv")
    v = maybe_shard(v, "attn_kv")
    out = flash_attention(q, k, v, kind=kind, window=window,
                          prefix_len=prefix_len, softcap=cfg.logit_softcap)
    out = dense_apply(p["wo"], out.reshape(B, S, -1))
    return out, (k, v)


def attn_apply_decode(p, x, cfg, cache, *, cur_pos, window=0,
                      use_kernel=False):
    """One-token decode. cache: {k, v, pos}; ring-buffered when window>0.

    x: (B, 1, D). Returns (out, new_cache).
    """
    B = x.shape[0]
    hd = cfg.hd
    q = dense_apply(p["wq"], x).reshape(B, 1, cfg.n_heads, hd)
    k = dense_apply(p["wk"], x).reshape(B, 1, cfg.n_kv_heads, hd)
    v = dense_apply(p["wv"], x).reshape(B, 1, cfg.n_kv_heads, hd)
    if cfg.rope_theta > 0:
        pos = jnp.full((B, 1), cur_pos)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    C = cache["k"].shape[1]
    slot = jnp.asarray(cur_pos % C) if window else jnp.asarray(cur_pos)
    k_cache = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, 1)
    v_cache = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, 1)
    k_pos = lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((B, 1), cur_pos, cache["pos"].dtype), slot, 1)
    out = decode_attention(q, k_cache, v_cache, k_pos=k_pos, cur_pos=cur_pos,
                           softcap=cfg.logit_softcap, use_kernel=use_kernel)
    out = dense_apply(p["wo"], out.reshape(B, 1, -1))
    return out, {"k": k_cache, "v": v_cache, "pos": k_pos}


def paged_attention(q, k_pages, v_pages, *, block_tables, seq_lens,
                    use_kernel: bool = True):
    """Decode attention over a paged KV pool.

    q: (B, 1, H, hd); k/v_pages: (NP, page_size, KVH, hd); block_tables:
    (B, n_pmax) i32; seq_lens: (B,) i32 (last valid position, -1 =
    inactive). ``use_kernel=False`` routes through the jnp gather oracle
    (parity tests); the kernel path is the serve hot spot.
    """
    if use_kernel:
        from ..kernels import ops as _kops
        return _kops.paged_decode_attention(q, k_pages, v_pages,
                                            block_tables, seq_lens)
    from ..kernels import ref as _kref
    return _kref.paged_decode_attention(q, k_pages, v_pages, block_tables,
                                        seq_lens)


def attn_apply_paged(p, x, cfg, pages, *, block_tables, seq_lens,
                     use_kernel: bool = True):
    """One continuous-batching decode step for one attention layer.

    x: (B, 1, D); pages: {"k": (NP, ps, KVH, hd), "v": same};
    block_tables: (B, n_pmax) i32; seq_lens: (B,) i32 — the absolute
    position of the token in x. Rows with seq_lens < 0 are inactive:
    nothing is written to the pool (out-of-range scatter dropped) and
    their output rows are zeros. Returns (out, new_pages).
    """
    if cfg.logit_softcap > 0.0:
        raise NotImplementedError("paged decode does not support logit softcap")
    B = x.shape[0]
    hd = cfg.hd
    q = dense_apply(p["wq"], x).reshape(B, 1, cfg.n_heads, hd)
    k = dense_apply(p["wk"], x).reshape(B, 1, cfg.n_kv_heads, hd)
    v = dense_apply(p["wv"], x).reshape(B, 1, cfg.n_kv_heads, hd)
    if cfg.rope_theta > 0:
        pos = seq_lens[:, None]
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    NP, ps = pages["k"].shape[0], pages["k"].shape[1]
    active = seq_lens >= 0
    logical = jnp.where(active, seq_lens, 0) // ps
    page_idx = jnp.take_along_axis(block_tables, logical[:, None], axis=1)[:, 0]
    page_idx = jnp.where(active, page_idx, NP)        # out of range -> dropped
    slot = jnp.where(active, seq_lens, 0) % ps
    k_pages = pages["k"].at[page_idx, slot].set(
        k[:, 0].astype(pages["k"].dtype), mode="drop")
    v_pages = pages["v"].at[page_idx, slot].set(
        v[:, 0].astype(pages["v"].dtype), mode="drop")
    out = paged_attention(q, k_pages, v_pages, block_tables=block_tables,
                          seq_lens=seq_lens, use_kernel=use_kernel)
    out = dense_apply(p["wo"], out.reshape(B, 1, -1))
    return out, {"k": k_pages, "v": v_pages}


def paged_window_attention(q, k_pages, v_pages, *, block_tables, seq_lens,
                           use_kernel: bool = True):
    """Speculative-verify attention over a paged KV pool.

    q: (B, W, H, hd) — window query w sits at absolute position
    ``seq_lens[b] + w``; same pool/table conventions as
    :func:`paged_attention`. seq_lens is the position of query 0
    (-1 = inactive row)."""
    if use_kernel:
        from ..kernels import ops as _kops
        return _kops.paged_decode_window_attention(q, k_pages, v_pages,
                                                   block_tables, seq_lens)
    from ..kernels import ref as _kref
    return _kref.paged_decode_window_attention(q, k_pages, v_pages,
                                               block_tables, seq_lens)


def attn_apply_window_paged(p, x, cfg, pages, *, block_tables, seq_lens,
                            win_lens, use_kernel: bool = True):
    """One speculative verify step (drafted window) for one attn layer.

    x: (B, W, D) — token w of row b sits at absolute position
    ``seq_lens[b] + w``; win_lens: (B,) i32 — number of real window
    tokens per row (positions past win_lens are padding and are neither
    written to the pool nor trusted downstream). Rows with seq_lens < 0
    are inactive. The window K/V are scattered into the pool FIRST, then
    the window attends — so query w sees drafted tokens 0..w (causal
    within the window via the position mask) plus the full committed
    prefix. Returns (out (B, W, D), new_pages).
    """
    if cfg.logit_softcap > 0.0:
        raise NotImplementedError("paged decode does not support logit softcap")
    B, W, _ = x.shape
    hd = cfg.hd
    q = dense_apply(p["wq"], x).reshape(B, W, cfg.n_heads, hd)
    k = dense_apply(p["wk"], x).reshape(B, W, cfg.n_kv_heads, hd)
    v = dense_apply(p["wv"], x).reshape(B, W, cfg.n_kv_heads, hd)
    if cfg.rope_theta > 0:
        pos = jnp.maximum(seq_lens, 0)[:, None] + jnp.arange(W)[None, :]
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    NP, ps = pages["k"].shape[0], pages["k"].shape[1]
    active = seq_lens >= 0
    w_arange = jnp.arange(W)[None, :]                       # (1, W)
    valid = active[:, None] & (w_arange < win_lens[:, None])  # (B, W)
    abs_pos = jnp.where(valid, seq_lens[:, None] + w_arange, 0)
    logical = abs_pos // ps                                  # (B, W)
    page_idx = jnp.take_along_axis(block_tables, logical, axis=1)
    page_idx = jnp.where(valid, page_idx, NP)     # out of range -> dropped
    slot = abs_pos % ps
    k_pages = pages["k"].at[page_idx, slot].set(
        k.astype(pages["k"].dtype), mode="drop")
    v_pages = pages["v"].at[page_idx, slot].set(
        v.astype(pages["v"].dtype), mode="drop")
    out = paged_window_attention(q, k_pages, v_pages,
                                 block_tables=block_tables,
                                 seq_lens=seq_lens, use_kernel=use_kernel)
    out = dense_apply(p["wo"], out.reshape(B, W, -1))
    return out, {"k": k_pages, "v": v_pages}


def attn_apply_prefill_paged(p, x, cfg, pages, *, block_table_row, n_tokens):
    """Chunked prompt prefill for ONE sequence into the page pool.

    x: (1, Sp, D) prompt embeddings padded to a shape bucket; n_tokens is
    a traced scalar count of real tokens. Attention runs as causal flash
    over the padded prompt (the double-chunked online softmax keeps it
    memory-bound — the blockwise-prefill idiom), then the K/V rows of the
    real positions are scattered into the sequence's pages via its block
    table. Returns (out (1, Sp, D), new_pages).
    """
    B, Sp, _ = x.shape
    positions = jnp.arange(Sp)
    q, k, v = attn_qkv(p, x, cfg, positions if cfg.rope_theta > 0 else None)
    out = flash_attention(q, k, v, kind="causal", softcap=cfg.logit_softcap)
    out = dense_apply(p["wo"], out.reshape(B, Sp, -1))
    NP, ps = pages["k"].shape[0], pages["k"].shape[1]
    valid = positions < n_tokens
    page_idx = jnp.where(valid,
                         jnp.take(block_table_row, positions // ps,
                                  mode="clip"), NP)
    slot = positions % ps
    k_pages = pages["k"].at[page_idx, slot].set(
        k[0].astype(pages["k"].dtype), mode="drop")
    v_pages = pages["v"].at[page_idx, slot].set(
        v[0].astype(pages["v"].dtype), mode="drop")
    return out, {"k": k_pages, "v": v_pages}


def attn_pages_init(cfg, num_pages: int, page_size: int, *,
                    dtype=jnp.bfloat16):
    hd = cfg.hd
    return {
        "k": jnp.zeros((num_pages, page_size, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((num_pages, page_size, cfg.n_kv_heads, hd), dtype),
    }


def attn_cache_init(cfg, batch: int, seq_len: int, *, window: int = 0,
                    dtype=jnp.bfloat16):
    C = min(window, seq_len) if window else seq_len
    hd = cfg.hd
    return {
        "k": jnp.zeros((batch, C, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, C, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((batch, C), -1, jnp.int32),
    }


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def mlp_init(key, cfg, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {"wi": dense_init(ks[0], cfg.d_model, d_ff),
                "wg": dense_init(ks[1], cfg.d_model, d_ff),
                "wo": dense_init(ks[2], d_ff, cfg.d_model)}
    return {"w1": dense_init(ks[0], cfg.d_model, d_ff, bias=True),
            "w2": dense_init(ks[1], d_ff, cfg.d_model, bias=True)}


def mlp_apply(p, x, cfg):
    if "wi" in p:   # swiglu
        h = jax.nn.silu(dense_apply(p["wg"], x)) * dense_apply(p["wi"], x)
        return dense_apply(p["wo"], maybe_shard(h, "mlp_hidden"))
    h = jax.nn.gelu(dense_apply(p["w1"], x))
    return dense_apply(p["w2"], maybe_shard(h, "mlp_hidden"))
