from . import api, blocks, moe, rwkv, mamba, transformer, vit, unet1d
