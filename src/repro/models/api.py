"""Model facade: init / loss / prefill / decode for every architecture family.

Batch formats (all jnp arrays):
  LM (dense/moe/ssm/hybrid):  {"tokens": (B,S) i32, "labels": (B,S) i32}
  audio (whisper):            + {"frames": (B, n_frames, D) f32}   [conv stub]
  vlm (paligemma):            + {"patches": (B, n_prefix, D) f32}  [SigLIP stub]
  vision (vit):               {"images": (B,28,28,1), "labels": (B,) i32}
  pde (unet):                 {"u0": (B,L,1), "u1": (B,L,1)}

Losses: token cross-entropy (labels < 0 masked), class CE, MSE. MoE aux
losses are folded in with cfg.router_aux_coef. The LM head is computed in
sequence chunks under jax.checkpoint so (B, S, vocab) logits are never
materialized for the full sequence (vocab up to 262k).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from .blocks import dense_apply, dense_init, norm_apply, norm_init
from .transformer import (paged_guard, stack_apply_decode, stack_apply_full,
                          stack_apply_paged, stack_apply_prefill_paged,
                          stack_apply_window_paged, stack_cache_init,
                          stack_init, stack_paged_init)
from . import vit as vit_mod
from . import unet1d as unet_mod
from ..sharding.policy import maybe_shard

LOSS_CHUNK = 512


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(key, cfg):
    if cfg.family == "vision":
        return vit_mod.vit_init(key, cfg)
    if cfg.family == "pde":
        return unet_mod.unet_init(key, cfg)
    ks = jax.random.split(key, 5)
    params = {
        "embed": jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                   jnp.float32) * 0.02,
        "final_norm": norm_init(cfg.norm, cfg.d_model),
        **stack_init(ks[1], cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], cfg.d_model, cfg.vocab_size)
    if cfg.is_encoder_decoder:
        enc_cfg = cfg.replace(pattern=("enc_attn_mlp",), n_units=cfg.n_encoder_layers,
                              head_layers=(), tail_layers=())
        params["encoder"] = {**stack_init(ks[3], enc_cfg),
                             "final_norm": norm_init(cfg.norm, cfg.d_model)}
    return params


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def param_footprint(cfg, precision=None) -> int:
    """Per-particle parameter bytes under a precision policy, from
    ``jax.eval_shape`` (no FLOPs, no memory): float leaves count at the
    policy's master itemsize. The estimator ``Placement.auto`` and
    bench_precision size the model axis / HBM headline with."""
    from ..core.precision import tree_bytes
    shapes = jax.eval_shape(partial(init_params, cfg=cfg),
                            jax.random.PRNGKey(0))
    return tree_bytes(shapes, precision)


def _cache_dtype(cfg):
    return jnp.bfloat16 if jnp.dtype(cfg.dtype) == jnp.bfloat16 else jnp.float32


def _sinusoid(S: int, D: int, dtype):
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(D // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (2 * dim / D))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)[None]


def _embed(params, tokens, cfg, dtype):
    x = params["embed"].astype(dtype)[tokens]
    return x


def _lm_logits(params, x, cfg):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]["w"]
    return x @ w.astype(x.dtype)


def _encode(params, frames, cfg):
    enc_cfg = cfg.replace(pattern=("enc_attn_mlp",), n_units=cfg.n_encoder_layers,
                          head_layers=(), tail_layers=())
    x = frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)
    ctx = {"cache_dtype": jnp.bfloat16}
    x, _, _ = stack_apply_full(params["encoder"], x, enc_cfg, ctx)
    return norm_apply(params["encoder"]["final_norm"], x)


def _backbone_inputs(params, batch, cfg, dtype):
    """Returns (x, ctx, n_text_positions_offset)."""
    ctx: Dict[str, Any] = {"cache_dtype": _cache_dtype(cfg)}
    tokens = batch["tokens"]
    x = maybe_shard(_embed(params, tokens, cfg, dtype), "residual")
    offset = 0
    if cfg.family == "audio":
        ctx["enc_out"] = _encode(params, batch["frames"].astype(dtype), cfg)
        x = x + _sinusoid(x.shape[1], cfg.d_model, dtype)
    elif cfg.family == "vlm":
        x = jnp.concatenate([batch["patches"].astype(dtype), x], axis=1)
        ctx["prefix_len"] = cfg.n_prefix_tokens
        offset = cfg.n_prefix_tokens
    return x, ctx, offset


# --------------------------------------------------------------------------
# training forward/loss
# --------------------------------------------------------------------------

def _chunked_ce(params, x, labels, cfg):
    """Cross-entropy over sequence chunks; never a full (B,S,V) tensor."""
    B, S, D = x.shape
    C = min(LOSS_CHUNK, S)
    n = -(-S // C)
    pad = n * C - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    xc = x.reshape(B, n, C, D).swapaxes(0, 1)
    lc = labels.reshape(B, n, C).swapaxes(0, 1)

    @jax.checkpoint
    def one(xi, li):
        logits = _lm_logits(params, xi, cfg).astype(jnp.float32)
        logits = maybe_shard(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(li, 0)[..., None],
                                   axis=-1)[..., 0]
        mask = (li >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * mask), jnp.sum(mask)

    def body(acc, inp):
        l, m = one(*inp)
        return (acc[0] + l, acc[1] + m), None

    (tot, cnt), _ = lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def forward(params, batch, cfg):
    """Training-style full forward. Returns (per-task output, aux)."""
    if cfg.family == "vision":
        return vit_mod.vit_apply(params, batch["images"], cfg), {}
    if cfg.family == "pde":
        return unet_mod.unet_apply(params, batch["u0"], cfg), {}
    dtype = jnp.dtype(cfg.dtype)
    x, ctx, offset = _backbone_inputs(params, batch, cfg, dtype)
    ctx["want_cache"] = False
    x, aux, _ = stack_apply_full(params, x, cfg, ctx)
    x = norm_apply(params["final_norm"], x)
    if offset:
        x = x[:, offset:]
    return x, aux


def loss_fn(params, batch, cfg):
    """Returns (loss, metrics)."""
    out, aux = forward(params, batch, cfg)
    if cfg.family == "vision":
        logits = out.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
        loss = jnp.mean(lse - gold)
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
        return loss, {"loss": loss, "acc": acc}
    if cfg.family == "pde":
        loss = jnp.mean(jnp.square(out - batch["u1"]))
        return loss, {"loss": loss}
    loss = _chunked_ce(params, out, batch["labels"], cfg)
    metrics = {"loss": loss}
    if cfg.n_experts:
        loss = loss + cfg.router_aux_coef * (aux["lb_loss"] + aux["z_loss"])
        metrics.update({k: aux[k] for k in ("lb_loss", "z_loss", "dropped_frac")})
    return loss, metrics


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------

def prefill(params, batch, cfg, max_len=None):
    """Full-context pass. Returns (last-token logits, caches).

    max_len: allocate decode headroom in the returned caches (defaults to
    the prompt length -- pass prompt_len + decode_budget for generation).
    """
    dtype = jnp.dtype(cfg.dtype)
    x, ctx, _ = _backbone_inputs(params, batch, cfg, dtype)
    if max_len is not None:
        ctx["cache_len"] = max_len
    x, _, caches = stack_apply_full(params, x, cfg, ctx)
    x = norm_apply(params["final_norm"], x)
    logits = _lm_logits(params, x[:, -1:], cfg)
    return logits[:, 0], caches


def decode_step(params, token, caches, cur_pos, cfg, *,
                decode_kernel: bool = False):
    """token: (B,) i32; cur_pos: scalar i32. Returns (logits (B,V), caches).

    ``decode_kernel=True`` runs cache attention through the Pallas decode
    kernel (repro.serve's LM path sets this; platform-gated interpret)."""
    dtype = jnp.dtype(cfg.dtype)
    x = _embed(params, token[:, None], cfg, dtype)
    ctx: Dict[str, Any] = {"cache_dtype": _cache_dtype(cfg), "cur_pos": cur_pos,
                           "decode_kernel": decode_kernel}
    if cfg.family == "audio":
        D = cfg.d_model
        dim = jnp.arange(D // 2, dtype=jnp.float32)
        ang = jnp.asarray(cur_pos, jnp.float32) / (10_000.0 ** (2 * dim / D))
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None].astype(dtype)
        x = x + pe
    x, caches = stack_apply_decode(params, x, cfg, caches, ctx)
    x = norm_apply(params["final_norm"], x)
    logits = _lm_logits(params, x, cfg)
    return logits[:, 0], caches


def init_cache(cfg, batch: int, seq_len: int, dtype=None):
    return stack_cache_init(cfg, batch, seq_len, dtype or _cache_dtype(cfg))


# --------------------------------------------------------------------------
# serving: paged continuous-batching decode (repro.serve.DecodeScheduler)
# --------------------------------------------------------------------------

def paged_cache_init(cfg, *, num_pages: int, page_size: int, dtype=None):
    """The per-particle KV page pool: one (num_pages, page_size, KVH, hd)
    k/v pair per attention layer. Block tables are per-sequence and live
    with the scheduler, not here."""
    return stack_paged_init(cfg, num_pages, page_size,
                            dtype or _cache_dtype(cfg))


def decode_step_paged(params, tokens, pages, block_tables, seq_lens, cfg, *,
                      decode_kernel: bool = True):
    """One continuous-batching decode step.

    tokens: (B,) i32 (garbage ok on inactive rows); block_tables:
    (B, n_pmax) i32; seq_lens: (B,) i32 absolute position of each token
    (-1 = inactive row: no pool writes, logits garbage — mask downstream).
    Returns (logits (B, V), pages)."""
    paged_guard(cfg)
    dtype = jnp.dtype(cfg.dtype)
    x = _embed(params, jnp.maximum(tokens, 0)[:, None], cfg, dtype)
    ctx: Dict[str, Any] = {"cache_dtype": _cache_dtype(cfg),
                           "block_tables": block_tables,
                           "seq_lens": seq_lens,
                           "decode_kernel": decode_kernel}
    x, pages = stack_apply_paged(params, x, cfg, pages, ctx)
    x = norm_apply(params["final_norm"], x)
    logits = _lm_logits(params, x, cfg)
    return logits[:, 0], pages


def decode_window_paged(params, tokens, pages, block_tables, seq_lens,
                        win_lens, cfg, *, decode_kernel: bool = True):
    """Speculative verify: score a W-token drafted window in one pass.

    tokens: (B, W) i32 — token w of row b sits at absolute position
    ``seq_lens[b] + w`` (window token 0 is the last committed token, the
    rest are drafts); win_lens: (B,) i32 real window tokens per row
    (positions past win_lens are padding: not written to the pool, logits
    garbage — mask downstream); seq_lens: (B,) i32 (-1 = inactive row).
    Returns (logits (B, W, V), pages); logits[:, w] predicts the token
    AFTER window position w, so accepted drafts need no re-scoring."""
    paged_guard(cfg)
    dtype = jnp.dtype(cfg.dtype)
    x = _embed(params, jnp.maximum(tokens, 0), cfg, dtype)
    ctx: Dict[str, Any] = {"cache_dtype": _cache_dtype(cfg),
                           "block_tables": block_tables,
                           "seq_lens": seq_lens,
                           "win_lens": win_lens,
                           "decode_kernel": decode_kernel}
    x, pages = stack_apply_window_paged(params, x, cfg, pages, ctx)
    x = norm_apply(params["final_norm"], x)
    logits = _lm_logits(params, x, cfg)
    return logits, pages


def prefill_paged(params, tokens, pages, block_table_row, n_tokens, cfg):
    """Prompt prefill for ONE sequence into the page pool.

    tokens: (1, Sp) i32 padded to a shape bucket; block_table_row:
    (n_pmax,) i32; n_tokens: traced scalar count of real tokens.
    Returns (last-real-token logits (1, V), pages)."""
    paged_guard(cfg)
    dtype = jnp.dtype(cfg.dtype)
    x = _embed(params, tokens, cfg, dtype)
    ctx: Dict[str, Any] = {"cache_dtype": _cache_dtype(cfg),
                           "block_table_row": block_table_row,
                           "n_tokens": n_tokens}
    x, pages = stack_apply_prefill_paged(params, x, cfg, pages, ctx)
    x = norm_apply(params["final_norm"], x)
    last = lax.dynamic_slice_in_dim(x, jnp.maximum(n_tokens - 1, 0), 1, axis=1)
    logits = _lm_logits(params, last, cfg)
    return logits[:, 0], pages
