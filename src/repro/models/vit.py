"""Small Vision Transformer — the paper's Fig. 4 / Table 1 workload.

Patch-embeds 28x28 images (patch 14 -> 4 patches), prepends a CLS token,
runs `enc_attn_mlp` layers (bidirectional attention), classifies from CLS.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .blocks import dense_apply, dense_init, norm_apply, norm_init
from .transformer import layer_apply_full, layer_init


PATCH = 14
IMG = 28


def vit_init(key, cfg):
    n_patch = (IMG // PATCH) ** 2
    ks = jax.random.split(key, 4 + 1)
    units = jax.vmap(lambda kk: layer_init("enc_attn_mlp", kk, cfg))(
        jax.random.split(ks[0], cfg.n_units))
    return {
        "patch": dense_init(ks[1], PATCH * PATCH, cfg.d_model),
        "cls": jax.random.normal(ks[2], (1, 1, cfg.d_model), jnp.float32) * 0.02,
        "pos": jax.random.normal(ks[3], (1, n_patch + 1, cfg.d_model), jnp.float32) * 0.02,
        "units": units,
        "final_norm": norm_init(cfg.norm, cfg.d_model),
        "head": dense_init(ks[4], cfg.d_model, cfg.vocab_size),
    }


def vit_apply(params, images, cfg):
    """images: (B, 28, 28, 1) -> logits (B, n_classes)."""
    B = images.shape[0]
    g = IMG // PATCH
    x = images.reshape(B, g, PATCH, g, PATCH)
    x = x.transpose(0, 1, 3, 2, 4).reshape(B, g * g, PATCH * PATCH)
    x = dense_apply(params["patch"], x)
    x = jnp.concatenate([jnp.broadcast_to(params["cls"].astype(x.dtype),
                                          (B, 1, cfg.d_model)), x], axis=1)
    x = x + params["pos"].astype(x.dtype)
    ctx = {"cache_dtype": jnp.bfloat16}

    def body(x, unit_params):
        x, _, _ = layer_apply_full("enc_attn_mlp", unit_params, x, cfg, ctx)
        return x, None

    x, _ = jax.lax.scan(body, x, params["units"])
    x = norm_apply(params["final_norm"], x)
    return dense_apply(params["head"], x[:, 0])
