"""1-D conv UNet — the paper's PDEBench Advection workload, reduced to 1-D.

Down path: stride-2 convs doubling channels; up path: nearest-neighbour
upsample + conv with skip concatenation. Regression head to 1 channel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _conv_init(key, k, cin, cout):
    w = jax.random.normal(key, (k, cin, cout), jnp.float32) / jnp.sqrt(k * cin)
    return {"w": w, "b": jnp.zeros((cout,), jnp.float32)}


def _conv(p, x, stride=1):
    w = p["w"].astype(x.dtype)
    if w.shape[0] == 1 and stride == 1:
        # 1x1 conv as a per-position dense. vmap over the particle axis
        # rewrites convs as grouped convs (feature_group_count = particles)
        # and XLA's SPMD partitioner cannot split a grouped conv whose
        # cout-per-group is 1 across a sharded particle axis; a matmul
        # partitions cleanly and is numerically identical here.
        return x @ w[0] + p["b"].astype(x.dtype)
    y = lax.conv_general_dilated(
        x, w, window_strides=(stride,), padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"))
    return y + p["b"].astype(x.dtype)


def unet_init(key, cfg):
    c0 = cfg.d_model
    depth = cfg.n_units
    ks = iter(jax.random.split(key, 4 * depth + 4))
    enc, dec = [], []
    cin = 1
    chans = [c0 * (2 ** i) for i in range(depth)]
    for c in chans:
        enc.append({"c1": _conv_init(next(ks), 3, cin, c), "c2": _conv_init(next(ks), 3, c, c)})
        cin = c
    for c in reversed(chans):
        dec.append({"c1": _conv_init(next(ks), 3, cin + c, c), "c2": _conv_init(next(ks), 3, c, c)})
        cin = c
    return {"enc": tuple(enc), "dec": tuple(dec),
            "head": _conv_init(next(ks), 1, cin, 1)}


def unet_apply(params, u, cfg):
    """u: (B, L, 1) -> (B, L, 1)."""
    x = u
    skips = []
    for st in params["enc"]:
        x = jax.nn.gelu(_conv(st["c1"], x))
        x = jax.nn.gelu(_conv(st["c2"], x))
        skips.append(x)
        x = x[:, ::2]                              # downsample
    for st, sk in zip(params["dec"], reversed(skips)):
        x = jnp.repeat(x, 2, axis=1)[:, :sk.shape[1]]  # upsample
        x = jnp.concatenate([x, sk], axis=-1)
        x = jax.nn.gelu(_conv(st["c1"], x))
        x = jax.nn.gelu(_conv(st["c2"], x))
    return _conv(params["head"], x)
