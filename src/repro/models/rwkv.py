"""RWKV6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

The recurrence per head (k-dim x v-dim state S):

    S_t   = diag(w_t) S_{t-1} + k_t^T v_t
    y_t   = r_t (S_{t-1} + diag(u) k_t^T v_t)

with w_t = exp(-exp(w0 + lora_w(x_t))) in (0, 1) *per channel per step* —
the data-dependent decay that distinguishes RWKV6 [arXiv:2404.05892].

Training/prefill uses a chunked formulation (lax.scan over chunks of
length L): all decay ratios are formed in log space as pairwise
differences of the inclusive cumulative log-decay `a`, so nothing
overflows:

    intra: y_t += sum_{s<t} (r_t . (k_s * exp(b_t - a_s))) v_s
                 + (r_t . (k_t * u)) v_t            with b_t = a_{t-1}
    inter: y_t += (r_t * exp(b_t)) S_0
    state: S_L  = diag(exp(a_L)) S_0 + sum_s (k_s * exp(a_L - a_s))^T v_s

Decode is the plain O(1)-state recurrence (this is why rwkv6 runs the
long_500k shape).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .blocks import dense_init, dense_apply, norm_init, norm_apply
from ..sharding.policy import maybe_shard

LORA_RANK = 32


def _lora_init(key, d, rank, out=None):
    k1, k2 = jax.random.split(key)
    out = out or d
    return {"a": jax.random.normal(k1, (d, rank), jnp.float32) * 0.01,
            "b": jax.random.normal(k2, (rank, out), jnp.float32) * 0.01}


def _lora(p, x):
    return jnp.tanh(x @ p["a"].astype(x.dtype)) @ p["b"].astype(x.dtype)


def rwkv_init(key, cfg):
    D = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = D // hd
    ks = jax.random.split(key, 16)
    tm = {
        "mu_x": jnp.zeros((D,), jnp.float32) + 0.5,
        # per-target DDLerp mixes (r, k, v, g, w)
        **{f"mu_{t}": jnp.full((D,), 0.5, jnp.float32) for t in "rkvgw"},
        **{f"lora_{t}": _lora_init(ks[i], D, LORA_RANK) for i, t in enumerate("rkvgw")},
        "wr": dense_init(ks[6], D, D),
        "wk": dense_init(ks[7], D, D),
        "wv": dense_init(ks[8], D, D),
        "wg": dense_init(ks[9], D, D),
        "wo": dense_init(ks[10], D, D),
        "w0": jnp.full((D,), -2.0, jnp.float32),         # base log-log decay
        "lora_w": _lora_init(ks[11], D, 64),
        "u": jax.random.normal(ks[12], (H, hd), jnp.float32) * 0.1,
        "ln_x": norm_init("layer", D),                   # group-norm surrogate
    }
    cm = {
        "mu_k": jnp.full((D,), 0.5, jnp.float32),
        "mu_r": jnp.full((D,), 0.5, jnp.float32),
        "wk": dense_init(ks[13], D, cfg.d_ff),
        "wv": dense_init(ks[14], cfg.d_ff, D),
        "wr": dense_init(ks[15], D, D),
    }
    return {"ln1": norm_init(cfg.norm, D), "time_mix": tm,
            "ln2": norm_init(cfg.norm, D), "channel_mix": cm}


def _ddlerp(tm, x, x_prev):
    """Data-dependent token-shift interpolation -> dict of mixed inputs."""
    xx = x_prev - x
    base = x + xx * tm["mu_x"].astype(x.dtype)
    out = {}
    for t in "rkvgw":
        mix = tm[f"mu_{t}"].astype(x.dtype) + _lora(tm[f"lora_{t}"], base)
        out[t] = x + xx * mix
    return out


def _rkvgw(tm, x, x_prev, H, hd):
    """Project mixed inputs to r,k,v,g and per-channel decay w (B,S,H,hd)."""
    B, S, D = x.shape
    m = _ddlerp(tm, x, x_prev)
    r = dense_apply(tm["wr"], m["r"]).reshape(B, S, H, hd)
    k = dense_apply(tm["wk"], m["k"]).reshape(B, S, H, hd)
    v = dense_apply(tm["wv"], m["v"]).reshape(B, S, H, hd)
    g = jax.nn.silu(dense_apply(tm["wg"], m["g"]))
    logw = -jnp.exp(tm["w0"].astype(jnp.float32) + _lora(tm["lora_w"], m["w"]).astype(jnp.float32))
    logw = logw.reshape(B, S, H, hd)                     # log decay, < 0
    # pin the chunk-scan layout (heads -> model, S local): the (n, L) chunk
    # reshape of a sequence-sharded tensor would otherwise re-gather the
    # full stream at every use (EXPERIMENTS.md §Perf iteration 8)
    r, k, v = (maybe_shard(t, "ssm_heads") for t in (r, k, v))
    logw = maybe_shard(logw, "ssm_heads")
    return r, k, v, g, logw


def time_mix_chunked(tm, x, cfg, state=None, x_last=None, chunk: int = 32):
    """x: (B, S, D). Returns (out, (state (B,H,hd,hd), x_last (B,D)))."""
    B, S, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd
    x_prev = jnp.concatenate(
        [jnp.zeros_like(x[:, :1]) if x_last is None else x_last[:, None], x[:, :-1]], axis=1)
    r, k, v, g, logw = _rkvgw(tm, x, x_prev, H, hd)
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)

    L = min(chunk, S)
    n = -(-S // L)
    pad = n * L - S
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(t, z4) for t in (r, k, v))
        logw = jnp.pad(logw, z4)                         # log w = 0 -> w = 1 (keeps state)
    rc, kc, vc, wc = (t.reshape(B, n, L, H, hd) for t in (r, k, v, logw))
    u = tm["u"].astype(jnp.float32)

    @jax.checkpoint
    def chunk_step(S0, inp):
        rr, kk, vv, ww = inp                             # (B, L, H, hd)
        rr32, kk32, vv32 = rr.astype(jnp.float32), kk.astype(jnp.float32), vv.astype(jnp.float32)
        a = jnp.cumsum(ww, axis=1)                       # inclusive cum log decay
        b = a - ww                                       # exclusive
        # inter-chunk: y_t = (r_t * exp(b_t)) @ S0
        y_inter = jnp.einsum("blhk,bhkv->blhv", rr32 * jnp.exp(b), S0)
        # intra-chunk strict-lower scores (B, H, L, L)
        decay = jnp.exp(b[:, :, None] - a[:, None, :])   # (B, t, s, H, hd)
        scores = jnp.einsum("bthk,bshk,btshk->bhts", rr32, kk32, decay)
        tri = jnp.tril(jnp.ones((L, L), bool), k=-1)
        scores = jnp.where(tri[None, None], scores, 0.0)
        diag = jnp.einsum("blhk,blhk,hk->blh", rr32, kk32, u)
        y_intra = jnp.einsum("bhts,bshv->bthv", scores, vv32) + diag[..., None] * vv32
        # state update
        aL = a[:, -1][:, None]                           # (B, 1, H, hd)
        S1 = jnp.exp(aL[:, 0])[..., None] * S0 + jnp.einsum(
            "bshk,bshv->bhkv", kk32 * jnp.exp(aL - a), vv32)
        return S1, (y_inter + y_intra).astype(x.dtype)

    xs = tuple(t.swapaxes(0, 1) for t in (rc, kc, vc, wc))
    state, ys = lax.scan(chunk_step, state, xs)
    y = ys.swapaxes(0, 1).reshape(B, n * L, H, hd)[:, :S]
    y = norm_apply(tm["ln_x"], y.reshape(B, S, D)) * g
    return dense_apply(tm["wo"], y), (state, x[:, -1])


def time_mix_ref(tm, x, cfg):
    """Per-step scan oracle for tests."""
    B, S, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd
    x_prev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    r, k, v, g, logw = _rkvgw(tm, x, x_prev, H, hd)
    u = tm["u"].astype(jnp.float32)

    def step(S0, inp):
        rr, kk, vv, ww = (t.astype(jnp.float32) for t in inp)    # (B, H, hd)
        kv = jnp.einsum("bhk,bhv->bhkv", kk, vv)
        y = jnp.einsum("bhk,bhkv->bhv", rr, S0 + u[None, :, :, None] * kv)
        S1 = jnp.exp(ww)[..., None] * S0 + kv
        return S1, y

    xs = tuple(t.swapaxes(0, 1) for t in (r, k, v, logw))   # (S, B, H, hd)
    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    _, ys = lax.scan(step, S0, xs)
    y = ys.swapaxes(0, 1).astype(x.dtype)
    y = norm_apply(tm["ln_x"], y.reshape(B, S, D)) * g
    return dense_apply(tm["wo"], y)


def time_mix_decode(tm, x, cfg, state, x_last):
    """x: (B, 1, D); state: (B, H, hd, hd). O(1) recurrent step."""
    B, _, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd
    r, k, v, g, logw = _rkvgw(tm, x, x_last[:, None], H, hd)
    rr, kk, vv = (t[:, 0].astype(jnp.float32) for t in (r, k, v))
    ww = logw[:, 0]
    u = tm["u"].astype(jnp.float32)
    kv = jnp.einsum("bhk,bhv->bhkv", kk, vv)
    y = jnp.einsum("bhk,bhkv->bhv", rr, state + u[None, :, :, None] * kv)
    state = jnp.exp(ww)[..., None] * state + kv
    y = norm_apply(tm["ln_x"], y.reshape(B, 1, D).astype(x.dtype)) * g
    return dense_apply(tm["wo"], y), (state, x[:, -1])


def channel_mix(cm, x, x_last=None):
    """RWKV channel-mix (squared-relu MLP with token shift)."""
    x_prev = jnp.concatenate(
        [jnp.zeros_like(x[:, :1]) if x_last is None else x_last[:, None], x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * cm["mu_k"].astype(x.dtype)
    xr = x + (x_prev - x) * cm["mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(dense_apply(cm["wk"], xk)))
    return jax.nn.sigmoid(dense_apply(cm["wr"], xr)) * dense_apply(cm["wv"], kk), x[:, -1]


def rwkv_block_full(p, x, cfg, chunk: int = 32):
    y, (state, xl1) = time_mix_chunked(p["time_mix"], norm_apply(p["ln1"], x), cfg, chunk=chunk)
    x = x + y
    y, xl2 = channel_mix(p["channel_mix"], norm_apply(p["ln2"], x))
    x = x + y
    return x, {"state": state, "x_last_tm": xl1, "x_last_cm": xl2}


def rwkv_state_init(cfg, batch: int, dtype=jnp.float32):
    D = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = D // hd
    return {"state": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "x_last_tm": jnp.zeros((batch, D), dtype),
            "x_last_cm": jnp.zeros((batch, D), dtype)}


def rwkv_block_decode(p, x, cfg, st):
    dt = x.dtype
    y, (state, xl1) = time_mix_decode(p["time_mix"], norm_apply(p["ln1"], x), cfg,
                                      st["state"], st["x_last_tm"].astype(dt))
    x = (x + y).astype(dt)
    xp = st["x_last_cm"].astype(dt)
    y, xl2 = channel_mix(p["channel_mix"], norm_apply(p["ln2"], x), x_last=xp)
    x = (x + y).astype(dt)
    return x, {"state": state,
               "x_last_tm": xl1.astype(st["x_last_tm"].dtype),
               "x_last_cm": xl2.astype(st["x_last_cm"].dtype)}
