"""Generic layer-stacked LM: layer-kind dispatch + lax.scan over pattern units.

The layer stack is cfg.head_layers + cfg.pattern * cfg.n_units +
cfg.tail_layers (see configs.base). Repeated pattern units are *scanned*
(stacked params, single traced body) so compile time and HLO size are
depth-independent — llama3-405b's 126 layers compile as one scanned unit.

`shared_attn` layers (zamba2) use a single parameter copy stored at
params["shared"]; the scan body closes over it (one copy, applied at every
occurrence — gradients accumulate across invocations via AD).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .blocks import (attn_apply_decode, attn_apply_fullseq, attn_apply_paged,
                     attn_apply_prefill_paged, attn_apply_window_paged,
                     attn_cache_init, attn_init, attn_pages_init, dense_apply,
                     dense_init, mlp_apply, mlp_init, norm_apply, norm_init)
from . import moe as moe_mod
from . import rwkv as rwkv_mod
from . import mamba as mamba_mod
from ..sharding.policy import maybe_shard

ATTN_KINDS = ("attn_mlp", "attn_moe", "local", "shared_attn", "enc_attn_mlp")
AUX_KEYS = ("lb_loss", "z_loss", "dropped_frac")


# --------------------------------------------------------------------------
# per-kind init / apply / cache
# --------------------------------------------------------------------------

def layer_init(kind: str, key, cfg):
    ks = jax.random.split(key, 4)
    if kind in ("attn_mlp", "local", "shared_attn", "enc_attn_mlp"):
        return {"ln1": norm_init(cfg.norm, cfg.d_model), "attn": attn_init(ks[0], cfg),
                "ln2": norm_init(cfg.norm, cfg.d_model), "mlp": mlp_init(ks[1], cfg)}
    if kind == "attn_moe":
        return {"ln1": norm_init(cfg.norm, cfg.d_model), "attn": attn_init(ks[0], cfg),
                "ln2": norm_init(cfg.norm, cfg.d_model), "moe": moe_mod.moe_init(ks[1], cfg)}
    if kind == "dec_attn_mlp":
        return {"ln1": norm_init(cfg.norm, cfg.d_model), "attn": attn_init(ks[0], cfg),
                "ln_x": norm_init(cfg.norm, cfg.d_model), "xattn": attn_init(ks[1], cfg),
                "ln2": norm_init(cfg.norm, cfg.d_model), "mlp": mlp_init(ks[2], cfg)}
    if kind == "rwkv":
        return rwkv_mod.rwkv_init(key, cfg)
    if kind == "mamba":
        return mamba_mod.mamba_init(key, cfg)
    raise ValueError(kind)


def _mask_kind(kind: str, cfg, ctx) -> Tuple[str, int, int]:
    if kind == "enc_attn_mlp":
        return "bidir", 0, 0
    if kind == "local":
        return "sliding", cfg.sliding_window, 0
    if cfg.prefix_lm:
        return "prefix", 0, ctx.get("prefix_len", cfg.n_prefix_tokens)
    return "causal", 0, 0


def layer_apply_full(kind: str, p, x, cfg, ctx):
    """Returns (x, aux, cache_entry)."""
    aux = {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}
    if kind == "rwkv":
        x, st = rwkv_mod.rwkv_block_full(p, x, cfg)
        return x, aux, st
    if kind == "mamba":
        x, st = mamba_mod.mamba_block_full(p, x, cfg)
        return x, aux, st
    if kind == "dec_attn_mlp":
        h, (k, v) = attn_apply_fullseq(p["attn"], norm_apply(p["ln1"], x), cfg, kind="causal")
        x = x + h
        enc = ctx["enc_out"]
        B, F = enc.shape[0], enc.shape[1]
        hd = cfg.hd
        ck = dense_apply(p["xattn"]["wk"], enc).reshape(B, F, cfg.n_kv_heads, hd)
        cv = dense_apply(p["xattn"]["wv"], enc).reshape(B, F, cfg.n_kv_heads, hd)
        h, _ = attn_apply_fullseq(p["xattn"], norm_apply(p["ln_x"], x), cfg, cross_kv=(ck, cv))
        x = x + h
        x = x + mlp_apply(p["mlp"], norm_apply(p["ln2"], x), cfg)
        if not ctx.get("want_cache", True):
            return x, aux, ()
        S = k.shape[1]
        C = ctx.get("cache_len", S)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if C > S:
            k = jnp.pad(k, ((0, 0), (0, C - S), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, C - S), (0, 0), (0, 0)))
            pos = jnp.concatenate([pos, jnp.full((B, C - S), -1, jnp.int32)], 1)
        cache = {"self": {"k": k.astype(ctx["cache_dtype"]), "v": v.astype(ctx["cache_dtype"]),
                          "pos": pos},
                 "xk": ck.astype(ctx["cache_dtype"]), "xv": cv.astype(ctx["cache_dtype"])}
        return x, aux, cache
    # attention + (mlp|moe)
    mkind, window, prefix_len = _mask_kind(kind, cfg, ctx)
    h, (k, v) = attn_apply_fullseq(p["attn"], norm_apply(p["ln1"], x), cfg,
                                   kind=mkind, window=window, prefix_len=prefix_len)
    x = x + h
    if kind == "attn_moe":
        h, moe_aux = moe_mod.moe_apply(p["moe"], norm_apply(p["ln2"], x), cfg)
        aux = {**aux, **{k2: jnp.asarray(v2, jnp.float32) for k2, v2 in moe_aux.items()}}
    else:
        h = mlp_apply(p["mlp"], norm_apply(p["ln2"], x), cfg)
    x = x + h
    if kind == "enc_attn_mlp" or not ctx.get("want_cache", True):
        return x, aux, ()
    B, S = k.shape[0], k.shape[1]
    if window:
        # ring cache: the entry for position p must sit at slot p % W so the
        # decode path (slot = cur_pos % W) overwrites the oldest entry
        W = window
        if S >= W:
            k, v = k[:, S - W:], v[:, S - W:]
            pos = jnp.broadcast_to(jnp.arange(S - W, S, dtype=jnp.int32), (B, W))
            shift = (S - W) % W
            k, v = jnp.roll(k, shift, axis=1), jnp.roll(v, shift, axis=1)
            pos = jnp.roll(pos, shift, axis=1)
        else:  # prompt shorter than the window: slot p % W == p already
            pad = W - S
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            pos = jnp.concatenate(
                [jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S)),
                 jnp.full((B, pad), -1, jnp.int32)], axis=1)
    else:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        C = ctx.get("cache_len", S)    # decode headroom (api.prefill max_len)
        if C > S:
            k = jnp.pad(k, ((0, 0), (0, C - S), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, C - S), (0, 0), (0, 0)))
            pos = jnp.concatenate([pos, jnp.full((B, C - S), -1, jnp.int32)], 1)
    cache = {"k": k.astype(ctx["cache_dtype"]), "v": v.astype(ctx["cache_dtype"]), "pos": pos}
    return x, aux, cache


def layer_apply_decode(kind: str, p, x, cfg, cache, ctx):
    """x: (B, 1, D). Returns (x, new_cache)."""
    cur = ctx["cur_pos"]
    if kind == "rwkv":
        return rwkv_mod.rwkv_block_decode(p, x, cfg, cache)
    if kind == "mamba":
        return mamba_mod.mamba_block_decode(p, x, cfg, cache)
    if kind == "dec_attn_mlp":
        h, sc = attn_apply_decode(p["attn"], norm_apply(p["ln1"], x), cfg,
                                  cache["self"], cur_pos=cur,
                                  use_kernel=ctx.get("decode_kernel", False))
        x = x + h
        from .blocks import decode_attention, rope
        B = x.shape[0]
        hd = cfg.hd
        q = dense_apply(p["xattn"]["wq"], norm_apply(p["ln_x"], x)).reshape(B, 1, cfg.n_heads, hd)
        F = cache["xk"].shape[1]
        kpos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))
        h = decode_attention(q, cache["xk"], cache["xv"], k_pos=kpos, cur_pos=F)
        x = x + dense_apply(p["xattn"]["wo"], h.reshape(B, 1, -1))
        x = x + mlp_apply(p["mlp"], norm_apply(p["ln2"], x), cfg)
        return x, {"self": sc, "xk": cache["xk"], "xv": cache["xv"]}
    mkind, window, _ = _mask_kind(kind, cfg, ctx)
    h, cache = attn_apply_decode(p["attn"], norm_apply(p["ln1"], x), cfg, cache,
                                 cur_pos=cur, window=window,
                                 use_kernel=ctx.get("decode_kernel", False))
    x = x + h
    if kind == "attn_moe":
        h, _ = moe_mod.moe_apply(p["moe"], norm_apply(p["ln2"], x), cfg)
    else:
        h = mlp_apply(p["mlp"], norm_apply(p["ln2"], x), cfg)
    return x + h, cache


def layer_cache_init(kind: str, cfg, batch: int, seq_len: int, dtype):
    if kind == "rwkv":
        return rwkv_mod.rwkv_state_init(cfg, batch, dtype=dtype)
    if kind == "mamba":
        return mamba_mod.mamba_state_init(cfg, batch, dtype=dtype)
    if kind == "dec_attn_mlp":
        hd = cfg.hd
        return {"self": attn_cache_init(cfg, batch, seq_len, dtype=dtype),
                "xk": jnp.zeros((batch, cfg.n_frames, cfg.n_kv_heads, hd), dtype),
                "xv": jnp.zeros((batch, cfg.n_frames, cfg.n_kv_heads, hd), dtype)}
    window = cfg.sliding_window if kind == "local" else 0
    return attn_cache_init(cfg, batch, seq_len, window=window, dtype=dtype)


# --------------------------------------------------------------------------
# stack runner
# --------------------------------------------------------------------------

def _zero_aux():
    return {k: jnp.zeros((), jnp.float32) for k in AUX_KEYS}


def _acc_aux(a, b):
    return {k: a[k] + b[k] for k in AUX_KEYS}


def stack_init(key, cfg):
    params: Dict[str, Any] = {}
    n_pos = len(cfg.pattern)
    keys = jax.random.split(key, 4 + n_pos)
    params["head"] = tuple(
        layer_init(k, kk, cfg) for k, kk in
        zip(cfg.head_layers, jax.random.split(keys[0], max(len(cfg.head_layers), 1))))
    params["tail"] = tuple(
        layer_init(k, kk, cfg) for k, kk in
        zip(cfg.tail_layers, jax.random.split(keys[1], max(len(cfg.tail_layers), 1))))
    if any(k == "shared_attn" for k in cfg.pattern):
        params["shared"] = layer_init("shared_attn", keys[2], cfg)
    units = []
    for j, kind in enumerate(cfg.pattern):
        if kind == "shared_attn":
            units.append({})     # params live at params["shared"]
        else:
            per_unit = jax.vmap(lambda kk: layer_init(kind, kk, cfg))(
                jax.random.split(keys[3 + j], cfg.n_units))
            units.append(per_unit)
    params["units"] = tuple(units)
    return params


def stack_apply_full(params, x, cfg, ctx):
    """Returns (x, aux, caches dict)."""
    aux = _zero_aux()
    head_caches = []
    for kind, p in zip(cfg.head_layers, params["head"]):
        x, a, c = layer_apply_full(kind, p, x, cfg, ctx)
        aux = _acc_aux(aux, a)
        head_caches.append(c)

    unit_caches = ()
    if cfg.n_units:
        def body(carry, unit_params):
            x, aux = carry
            caches = []
            for j, kind in enumerate(cfg.pattern):
                p = params.get("shared") if kind == "shared_attn" else unit_params[j]
                x, a, c = layer_apply_full(kind, p, x, cfg, ctx)
                x = maybe_shard(x, "residual")
                aux = _acc_aux(aux, a)
                caches.append(c)
            return (x, aux), tuple(caches)

        # remat menu: a named jax.checkpoint policy beats the boolean
        # flag — "nothing_saveable" recomputes everything (min HBM),
        # "dots_saveable" keeps matmul outputs (cheapest recompute)
        if getattr(cfg, "remat_policy", None):
            from ..core.precision import checkpoint_policy
            body = jax.checkpoint(body,
                                  policy=checkpoint_policy(cfg.remat_policy))
        elif cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), unit_caches = lax.scan(body, (x, aux), params["units"])

    tail_caches = []
    for kind, p in zip(cfg.tail_layers, params["tail"]):
        x, a, c = layer_apply_full(kind, p, x, cfg, ctx)
        aux = _acc_aux(aux, a)
        tail_caches.append(c)
    caches = {"head": tuple(head_caches), "units": unit_caches, "tail": tuple(tail_caches)}
    return x, aux, caches


def stack_apply_decode(params, x, cfg, caches, ctx):
    new_head = []
    for kind, p, c in zip(cfg.head_layers, params["head"], caches["head"]):
        x, c = layer_apply_decode(kind, p, x, cfg, c, ctx)
        new_head.append(c)

    new_units = caches["units"]
    if cfg.n_units:
        def body(x, scan_in):
            dt = x.dtype
            unit_params, unit_caches = scan_in
            new_caches = []
            for j, kind in enumerate(cfg.pattern):
                p = params.get("shared") if kind == "shared_attn" else unit_params[j]
                x, c = layer_apply_decode(kind, p, x, cfg, unit_caches[j], ctx)
                x = x.astype(dt)
                new_caches.append(c)
            return x, tuple(new_caches)

        x, new_units = lax.scan(body, x, (params["units"], caches["units"]))

    new_tail = []
    for kind, p, c in zip(cfg.tail_layers, params["tail"], caches["tail"]):
        x, c = layer_apply_decode(kind, p, x, cfg, c, ctx)
        new_tail.append(c)
    return x, {"head": tuple(new_head), "units": new_units, "tail": tuple(new_tail)}


def stack_cache_init(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16):
    def stacked(kind):
        one = layer_cache_init(kind, cfg, batch, seq_len, dtype)
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (cfg.n_units,) + a.shape), one)
    return {
        "head": tuple(layer_cache_init(k, cfg, batch, seq_len, dtype) for k in cfg.head_layers),
        "units": tuple(stacked(k) for k in cfg.pattern),
        "tail": tuple(layer_cache_init(k, cfg, batch, seq_len, dtype) for k in cfg.tail_layers),
    }


# --------------------------------------------------------------------------
# paged stack (continuous-batching decode): the KV state is a pool of
# fixed-size pages per layer, indexed by per-sequence block tables shared
# across all layers. Restricted to global-attention stacks — sliding
# windows, SSM/RWKV state and encoder-decoder caches have no paged form.
# --------------------------------------------------------------------------

PAGED_KINDS = ("attn_mlp", "attn_moe")


def paged_guard(cfg):
    kinds = tuple(cfg.head_layers) + tuple(cfg.pattern) + tuple(cfg.tail_layers)
    bad = sorted({k for k in kinds if k not in PAGED_KINDS})
    if bad:
        raise NotImplementedError(
            f"paged decode supports {PAGED_KINDS} stacks only, got {bad}")
    if cfg.prefix_lm:
        raise NotImplementedError("paged decode does not support prefix_lm")


def _layer_apply_paged(kind, p, x, cfg, pages, ctx):
    h, pages = attn_apply_paged(
        p["attn"], norm_apply(p["ln1"], x), cfg, pages,
        block_tables=ctx["block_tables"], seq_lens=ctx["seq_lens"],
        use_kernel=ctx.get("decode_kernel", True))
    x = x + h
    if kind == "attn_moe":
        h, _ = moe_mod.moe_apply(p["moe"], norm_apply(p["ln2"], x), cfg)
    else:
        h = mlp_apply(p["mlp"], norm_apply(p["ln2"], x), cfg)
    return x + h, pages


def _layer_apply_window_paged(kind, p, x, cfg, pages, ctx):
    h, pages = attn_apply_window_paged(
        p["attn"], norm_apply(p["ln1"], x), cfg, pages,
        block_tables=ctx["block_tables"], seq_lens=ctx["seq_lens"],
        win_lens=ctx["win_lens"], use_kernel=ctx.get("decode_kernel", True))
    x = x + h
    if kind == "attn_moe":
        h, _ = moe_mod.moe_apply(p["moe"], norm_apply(p["ln2"], x), cfg)
    else:
        h = mlp_apply(p["mlp"], norm_apply(p["ln2"], x), cfg)
    return x + h, pages


def _layer_apply_prefill_paged(kind, p, x, cfg, pages, ctx):
    h, pages = attn_apply_prefill_paged(
        p["attn"], norm_apply(p["ln1"], x), cfg, pages,
        block_table_row=ctx["block_table_row"], n_tokens=ctx["n_tokens"])
    x = x + h
    if kind == "attn_moe":
        h, _ = moe_mod.moe_apply(p["moe"], norm_apply(p["ln2"], x), cfg)
    else:
        h = mlp_apply(p["mlp"], norm_apply(p["ln2"], x), cfg)
    return x + h, pages


def _stack_apply_paged_common(params, x, cfg, pages, ctx, layer_fn):
    new_head = []
    for kind, p, pg in zip(cfg.head_layers, params["head"], pages["head"]):
        x, pg = layer_fn(kind, p, x, cfg, pg, ctx)
        new_head.append(pg)

    new_units = pages["units"]
    if cfg.n_units:
        def body(x, scan_in):
            dt = x.dtype
            unit_params, unit_pages = scan_in
            new_pages = []
            for j, kind in enumerate(cfg.pattern):
                x, pg = layer_fn(kind, unit_params[j], x, cfg,
                                 unit_pages[j], ctx)
                x = x.astype(dt)
                new_pages.append(pg)
            return x, tuple(new_pages)

        x, new_units = lax.scan(body, x, (params["units"], pages["units"]))

    new_tail = []
    for kind, p, pg in zip(cfg.tail_layers, params["tail"], pages["tail"]):
        x, pg = layer_fn(kind, p, x, cfg, pg, ctx)
        new_tail.append(pg)
    return x, {"head": tuple(new_head), "units": new_units,
               "tail": tuple(new_tail)}


def stack_apply_paged(params, x, cfg, pages, ctx):
    """One decode step over the paged pool. x: (B, 1, D);
    ctx: block_tables (B, n_pmax), seq_lens (B,). Returns (x, pages)."""
    return _stack_apply_paged_common(params, x, cfg, pages, ctx,
                                     _layer_apply_paged)


def stack_apply_window_paged(params, x, cfg, pages, ctx):
    """Speculative-verify step over a drafted window. x: (B, W, D);
    ctx: block_tables (B, n_pmax), seq_lens (B,) (position of window
    token 0, -1 = inactive), win_lens (B,) real window tokens per row.
    Returns (x, pages)."""
    return _stack_apply_paged_common(params, x, cfg, pages, ctx,
                                     _layer_apply_window_paged)


def stack_apply_prefill_paged(params, x, cfg, pages, ctx):
    """Prompt prefill for one sequence into the pool. x: (1, Sp, D);
    ctx: block_table_row (n_pmax,), n_tokens scalar. Returns (x, pages)."""
    return _stack_apply_paged_common(params, x, cfg, pages, ctx,
                                     _layer_apply_prefill_paged)


def stack_paged_init(cfg, num_pages: int, page_size: int, dtype=jnp.bfloat16):
    paged_guard(cfg)

    def stacked():
        one = attn_pages_init(cfg, num_pages, page_size, dtype=dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_units,) + a.shape), one)

    return {
        "head": tuple(attn_pages_init(cfg, num_pages, page_size, dtype=dtype)
                      for _ in cfg.head_layers),
        "units": tuple(stacked() for _ in cfg.pattern),
        "tail": tuple(attn_pages_init(cfg, num_pages, page_size, dtype=dtype)
                      for _ in cfg.tail_layers),
    }
