"""llama3-8b [dense] — GQA, 128k vocab. [arXiv:2407.21783]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    d_model=4096,
    vocab_size=128_256,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14_336,
    pattern=("attn_mlp",),
    n_units=32,
    rope_theta=500_000.0,
    max_seq_len=131_072,
    default_particles=2,
)
