"""Paper-faithful workload: the b16 vision transformer of Push Fig. 4.

"image size of 28, patch size of 14, 10 classes, 8 heads, 16 layers,
MLP dimension of 1280, and hidden dimension of 320" (Appendix C.1).
Used by benchmarks/bench_scaling.py and bench_depth_particles.py (which
swaps in the Table-1 variant: 12 heads, MLP 3072, hidden 768, varying
layers).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="vit-mnist",
    family="vision",
    d_model=320,
    vocab_size=10,            # n_classes
    n_heads=8,
    n_kv_heads=8,
    d_ff=1280,
    act="gelu",
    norm="layer",
    pattern=("enc_attn_mlp",),
    n_units=16,
    max_seq_len=8,            # 4 patches + cls
    default_particles=8,
)


def table1_variant(depth: int) -> ModelConfig:
    """The Table-1 depth-vs-particles ViT: default b16 dims, varying layers."""
    return CONFIG.replace(
        name=f"vit-mnist-d{depth}", d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, n_units=depth)
