"""llama3-405b [dense] — GQA, 128k vocab. [arXiv:2407.21783]

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.

P=1 (a single particle sharded across devices — the paper's "single
particle across devices" future-work item). Uses adafactor in the dry-run
so optimizer state fits v5e HBM at 256 chips.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    d_model=16_384,
    vocab_size=128_256,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53_248,
    pattern=("attn_mlp",),
    n_units=126,
    rope_theta=500_000.0,
    max_seq_len=131_072,
    optimizer="adafactor",
    default_particles=1,
)
