"""qwen1.5-0.5b [dense] — QKV bias. [hf:Qwen/Qwen1.5-0.5B]

24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936.
The sweet spot of the paper's technique: many particles of a small model
(default 16 particles in the dry run).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    d_model=1024,
    vocab_size=151_936,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    qkv_bias=True,
    pattern=("attn_mlp",),
    n_units=24,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    max_seq_len=32_768,
    default_particles=16,
)
