"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]

38L d_model=2048 32H (kv=32) d_ff=8192, ssm_state=64. The backbone is
Mamba2 blocks; a single *shared* transformer block (one parameter copy,
applied at multiple depths — zamba2's core trick) is interleaved every
6th position: pattern unit = 5 mamba + 1 shared_attn (x6) + 2 mamba tail
= 38 blocks. We share the full block parameters across invocations
(zamba2's per-invocation LoRA deltas are omitted; noted in DESIGN.md).
Hybrid (SSM-dominant) decode -> runs long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    d_model=2048,
    vocab_size=32_000,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "shared_attn"),
    n_units=6,
    tail_layers=("mamba", "mamba"),
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    max_seq_len=1_048_576,
    default_particles=8,
)
