"""Config system for repro models.

A ``ModelConfig`` fully describes one architecture (one of the 10 assigned
configs, the paper's own ViT/UNet workloads, or a reduced smoke variant).
Configs are plain frozen dataclasses so they can be hashed into jit caches
and printed into EXPERIMENTS.md.

The layer stack is described by a *pattern* (a repeating unit of layer
kinds, scanned with ``lax.scan`` over units for compile scalability), plus
optional non-repeating ``head_layers`` / ``tail_layers``.

Layer kinds:
  attn_mlp      pre-norm GQA attention + (SwiGLU|GELU) MLP
  attn_moe      pre-norm GQA attention + top-k MoE (optional shared experts)
  local         attn_mlp with sliding-window attention
  rwkv          RWKV6 time-mix + channel-mix
  mamba         Mamba2 (SSD) block
  shared_attn   zamba2-style shared transformer block (one param copy,
                applied at every occurrence in the pattern)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm | vision | pde
    d_model: int
    vocab_size: int

    # --- layer stack -----------------------------------------------------
    pattern: Tuple[str, ...] = ("attn_mlp",)
    n_units: int = 1                 # pattern repeats; total = head + units*|pattern| + tail
    head_layers: Tuple[str, ...] = ()
    tail_layers: Tuple[str, ...] = ()

    # --- attention -------------------------------------------------------
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # for 'local' layers
    logit_softcap: float = 0.0

    # --- mlp ---------------------------------------------------------------
    d_ff: int = 0
    act: str = "swiglu"              # swiglu | gelu
    norm: str = "rms"                # rms | layer

    # --- moe ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    n_shared_experts: int = 0
    shared_d_ff: int = 0             # hidden dim of the shared-expert MLP
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- ssm / hybrid ------------------------------------------------------
    ssm_state: int = 0               # Mamba2 d_state
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    rwkv_head_dim: int = 64

    # --- encoder-decoder (audio) -------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_frames: int = 1500             # stubbed conv-frontend output length

    # --- vlm ----------------------------------------------------------------
    n_prefix_tokens: int = 0         # stubbed SigLIP patch embeddings
    prefix_lm: bool = False          # bidirectional attention over the prefix

    # --- misc ----------------------------------------------------------------
    tie_embeddings: bool = False
    max_seq_len: int = 8192
    dtype: str = "float32"           # compute dtype for smokes; dry-run uses bf16
    param_dtype: str = "float32"     # bf16 for the largest archs in the dry run
    remat: bool = False              # checkpoint the scanned layer body
    # named jax.checkpoint policy for the scanned unit (core.precision
    # .checkpoint_policy menu: "nothing_saveable", "dots_saveable",
    # "dots_with_no_batch_dims", ...). Overrides the boolean `remat`
    # flag; None + remat=True keeps the legacy full-remat behaviour.
    remat_policy: Optional[str] = None
    # default store precision preset ("fp32" | "mixed" | "bf16" |
    # "mixed_int8") picked up by PushDistribution when no explicit
    # precision is passed; None -> "fp32"
    precision: Optional[str] = None
    optimizer: str = "adam"          # adam | adafactor | sgd
    # particle-parallelism default (the paper's technique) per input shape
    default_particles: int = 1
    # which model axes get tensor-parallel sharding in the dry run
    shard_ffn: bool = True

    # -------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def n_layers(self) -> int:
        return len(self.head_layers) + self.n_units * len(self.pattern) + len(self.tail_layers)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Reduced variant for CPU smoke tests: same family / same layer kinds,
    # tiny dims (2 layers worth of pattern, d_model<=512, <=4 experts).
    def smoke(self) -> "ModelConfig":
        d = min(self.d_model, 128)
        nh = min(self.n_heads, 4) if self.n_heads else 0
        nkv = max(1, min(self.n_kv_heads, nh)) if self.n_heads else 0
        kw = dict(
            name=self.name + "-smoke",
            d_model=d,
            n_heads=nh,
            n_kv_heads=nkv,
            head_dim=(32 if self.n_heads else 0),
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_units=min(self.n_units, 2 if len(self.pattern) == 1 else 1),
            head_layers=self.head_layers[:1],
            tail_layers=self.tail_layers[:1],
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            shared_d_ff=min(self.shared_d_ff, 128) if self.shared_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            rwkv_head_dim=32,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            n_frames=min(self.n_frames, 16),
            n_prefix_tokens=min(self.n_prefix_tokens, 8),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            max_seq_len=256,
            default_particles=1,
        )
        return self.replace(**kw)


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned (seq_len, global_batch) workload shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
