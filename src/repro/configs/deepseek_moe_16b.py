"""deepseek-moe-16b [moe] — fine-grained MoE, 2 shared + 64 routed top-6.

28L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400, MoE 64e top-6.
[arXiv:2401.06066] Layer 0 is a dense MLP (DeepSeekMoE keeps the first
layer dense); remaining 27 layers are attn+MoE. The per-expert hidden dim
is the fine-grained d_ff=1408; the two shared experts form a dense
2*1408-wide MLP applied to every token.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    d_model=2048,
    vocab_size=102_400,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408 * 8,               # dense layer-0 MLP width (8x fine-grained)
    head_layers=("attn_mlp",),
    pattern=("attn_moe",),
    n_units=27,
    n_experts=64,
    top_k=6,
    moe_d_ff=1408,
    n_shared_experts=2,
    shared_d_ff=1408 * 2,
    rope_theta=10_000.0,
    max_seq_len=32_768,
    default_particles=2,
)
