"""rwkv6-7b [ssm] — Finch, data-dependent decay. [arXiv:2404.05892]

32L d_model=4096 (attention-free) d_ff=14336 vocab=65536.
Linear-time decode (O(1) state) -> runs long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    d_model=4096,
    vocab_size=65_536,
    n_heads=0,
    d_ff=14_336,
    pattern=("rwkv",),
    n_units=32,
    rwkv_head_dim=64,
    act="relu_sq",               # RWKV channel-mix uses squared relu
    max_seq_len=1_048_576,
    default_particles=2,
)
