"""paligemma-3b [vlm] — SigLIP + gemma decoder. [arXiv:2407.07726]

18L d_model=2048 8H (GQA kv=1, i.e. MQA) d_ff=16384 vocab=257216.
The SigLIP vision encoder + projector are a stub per the brief:
input_specs() provides precomputed patch embeddings (B, 256, d_model),
prepended to the text embeddings with a PaliGemma-style prefix-LM mask
(bidirectional attention over the image prefix, causal over text).
long_500k skipped (full attention).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    d_model=2048,
    vocab_size=257_216,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    pattern=("attn_mlp",),
    n_units=18,
    n_prefix_tokens=256,
    prefix_lm=True,
    rope_theta=10_000.0,
    max_seq_len=32_768 + 256,
    default_particles=4,
)
