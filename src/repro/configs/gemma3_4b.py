"""gemma3-4b [dense] — 5:1 local:global sliding window, 128k, 262k vocab.
[hf:google/gemma-3-1b-pt family]

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144, head_dim=256.
Pattern unit = 5 sliding-window (W=1024) layers + 1 global layer;
34 = 5*6 + 4 trailing local layers. Sliding-window local layers give
sub-quadratic prefill blocks and a bounded (W) local KV cache, so gemma3
runs long_500k: local layers use a W-token ring cache, the 1-in-6 global
layers decode against the full (linear-per-step) cache.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    d_model=2560,
    vocab_size=262_144,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10_240,
    pattern=("local", "local", "local", "local", "local", "attn_mlp"),
    n_units=5,
    tail_layers=("local", "local", "local", "local"),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    logit_softcap=0.0,
    max_seq_len=1_048_576,
    default_particles=4,
)
