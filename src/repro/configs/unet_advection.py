"""Paper-faithful workload: UNet on the (1-D) Advection PDE (PDEBench).

The paper uses the PDEBench UNet on the Advection dataset (batch 50).
We implement a 1-D conv UNet surrogate u(x, t) -> u(x, t+dt) on a
synthetic advection dataset (repro.data.synthetic.advection_batch).
This is a conv net, not a transformer, so it exercises a genuinely
different compute profile for the scaling benchmarks (paper §5.1).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="unet-advection",
    family="pde",
    d_model=32,               # base channel count
    vocab_size=1,             # regression: 1 output channel
    pattern=("unet",),        # handled specially by models.api
    n_units=4,                # depth of the U (number of down/up stages)
    act="gelu",
    max_seq_len=128,          # spatial resolution
    default_particles=8,
)
