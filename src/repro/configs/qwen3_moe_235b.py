"""qwen3-moe-235b-a22b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B family]

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per expert) vocab=151936,
MoE 128e top-8, no shared experts. head_dim=128. P=1; expert-parallel over
the model axis. Adafactor in the dry run (optimizer-state HBM).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    d_model=4096,
    vocab_size=151_936,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    pattern=("attn_moe",),
    n_units=94,
    n_experts=128,
    top_k=8,
    moe_d_ff=1536,
    n_shared_experts=0,
    rope_theta=1_000_000.0,
    max_seq_len=131_072,
    optimizer="adafactor",
    default_particles=1,
)
