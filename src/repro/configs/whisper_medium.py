"""whisper-medium [audio] — enc-dec, conv frontend (stub). [arXiv:2212.04356]

24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865. The mel-spectrogram +
conv feature extractor is a stub: input_specs() provides precomputed frame
embeddings (B, n_frames=1500, d_model). The transformer backbone (24-layer
encoder + 24-layer decoder with cross-attention) is fully implemented.
long_500k is skipped (audio-context-bounded decode; see DESIGN.md).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    d_model=1024,
    vocab_size=51_865,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    act="gelu",
    norm="layer",
    pattern=("dec_attn_mlp",),
    n_units=24,
    is_encoder_decoder=True,
    n_encoder_layers=24,
    n_frames=1500,
    max_seq_len=32_768,
    default_particles=8,
)
