"""Registry of all architecture configs (``--arch <id>``)."""
from .base import (ModelConfig, InputShape, INPUT_SHAPES,
                   TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

from . import (deepseek_moe_16b, llama3_8b, llama3_405b, rwkv6_7b,
               whisper_medium, gemma3_4b, paligemma_3b, zamba2_1p2b,
               qwen1p5_0p5b, qwen3_moe_235b, vit_mnist, unet_advection)

# The 10 assigned architectures (plus the paper's own two workloads).
ARCHS = {
    "deepseek-moe-16b": deepseek_moe_16b.CONFIG,
    "llama3-8b": llama3_8b.CONFIG,
    "llama3-405b": llama3_405b.CONFIG,
    "rwkv6-7b": rwkv6_7b.CONFIG,
    "whisper-medium": whisper_medium.CONFIG,
    "gemma3-4b": gemma3_4b.CONFIG,
    "paligemma-3b": paligemma_3b.CONFIG,
    "zamba2-1.2b": zamba2_1p2b.CONFIG,
    "qwen1.5-0.5b": qwen1p5_0p5b.CONFIG,
    "qwen3-moe-235b-a22b": qwen3_moe_235b.CONFIG,
}
PAPER_WORKLOADS = {
    "vit-mnist": vit_mnist.CONFIG,
    "unet-advection": unet_advection.CONFIG,
}
ALL = {**ARCHS, **PAPER_WORKLOADS}

# (arch, shape) pairs skipped in the dry run, with the DESIGN.md reason.
SKIPS = {
    ("llama3-8b", "long_500k"): "pure full-attention decode",
    ("llama3-405b", "long_500k"): "pure full-attention decode",
    ("qwen1.5-0.5b", "long_500k"): "pure full-attention decode",
    ("qwen3-moe-235b-a22b", "long_500k"): "pure full-attention decode",
    ("deepseek-moe-16b", "long_500k"): "pure full-attention decode",
    ("paligemma-3b", "long_500k"): "pure full-attention decode",
    ("whisper-medium", "long_500k"): "audio-context-bounded decode",
}


def get(name: str) -> ModelConfig:
    if name not in ALL:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALL)}")
    return ALL[name]


def is_skipped(arch: str, shape: str) -> str | None:
    return SKIPS.get((arch, shape))
