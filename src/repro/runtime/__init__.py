# repro.runtime — the single plan/compile/execute layer (DESIGN.md §8).
#
# program.py   — ProgramSpec / BuildCtx / Program + lowering (the ONLY
#                jax.jit site for fused particle programs)
# cache.py     — process-wide ProgramCache (hit/miss/cold-compile stats,
#                AOT serialization hook) + jit_program for host-driven
#                single-network programs (NEL steps, baselines)
# specs.py     — generic spec builders (ensemble step/predict, map_step)
# backends.py  — Runtime protocol: NelRuntime / CompiledRuntime
# bucketing.py — power-of-two batch bucketing shared with serve/
from .backends import (BACKENDS, CompiledRuntime, NelRuntime, Runtime,
                       make_runtime)
from .bucketing import bucket_size, pad_rows
from .cache import ProgramCache, global_cache, jit_program
from .program import (BuildCtx, Program, ProgramSpec, abstract_key, ident,
                      lower)
from . import specs
