"""ProgramSpec — the *plan* stage of the runtime's plan -> lower -> execute.

A ProgramSpec is a declarative description of one fused particle program:
the pure function (via a ``make(ctx)`` builder so the body can depend on
the placement / particle count it is lowered against), the *role* of every
positional argument and output (which decides its sharding under a mesh
placement), and which arguments are donated to XLA.

Argument kinds (``in_kinds``):

  "state"       stacked per-particle state pytree (leading particle axis).
                Sharded by ``Placement.shardings`` (particle axis leading,
                ``sharding/rules`` on the trailing dims). The particle
                count ``n`` is read off the first "state" argument.
  "replicated"  replicated on every device (batches: deep-ensemble
                semantics — every particle sees the same data).
  "vector"      per-particle scalars stacked to (n,) (losses).
  "rows"        a pytree whose *every leaf* has a leading particle axis
                but does not follow parameter sharding rules (serving
                state such as per-particle KV caches).

Output kinds (``out_kinds``) additionally allow ``"in:<i>"`` — same
resolved sharding as input ``i`` (the donated-state round trip). A single
``("replicated",)`` entry is applied as a prefix to the whole output
tree. ``out_kinds=None`` leaves output layout to XLA.

With ``Placement(mesh=None)`` every kind degrades to "no constraint" and
lowering is a plain ``jax.jit`` — one code path, placement decided by
shardings (the Tran et al. 2018 design point the whole repo follows).
"""
from __future__ import annotations

import itertools
import threading
import weakref
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.store import Placement
from ..obs import clock
from ..obs.trace import TRACER as _TRACER

IN_KINDS = ("state", "replicated", "vector", "rows")


# ---------------------------------------------------------------------------
# stable identity tokens for closures / optimizers in spec keys
# ---------------------------------------------------------------------------

_token_lock = threading.Lock()
_tokens: "weakref.WeakKeyDictionary[Any, int]" = weakref.WeakKeyDictionary()
_token_counter = itertools.count()


def ident(obj) -> Any:
    """Stable hashable identity token for an object referenced by a spec
    key. ``id()`` alone can be reused after GC; a weakref-keyed token
    cannot collide while either object is alive."""
    try:
        with _token_lock:
            tok = _tokens.get(obj)
            if tok is None:
                tok = next(_token_counter)
                _tokens[obj] = tok
            return tok
    except TypeError:  # not weakref-able / unhashable: fall back to id
        return ("id", id(obj))


def abstract_key(tree) -> Tuple:
    """Hashable (structure, shapes, dtypes) key for one argument."""
    leaves, treedef = jax.tree.flatten(tree)
    return (str(treedef),
            tuple((tuple(jnp.shape(x)), jnp.result_type(x).name)
                  for x in leaves))


# ---------------------------------------------------------------------------
# spec / build context / compiled program
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BuildCtx:
    """What ``ProgramSpec.make`` lowers against: the 2D placement plan,
    the particle count of the state being traced, the resolved
    ``vmap(spmd_axis_name=...)`` (None off-mesh or when n does not divide
    the mesh's particle axis), and the model axis the trailing dims are
    tensor-parallel over (None when the plan's model axis has size 1)."""
    placement: Placement
    num_particles: int
    spmd_axis: Optional[str]
    model_axis: Optional[str] = None


@dataclass(frozen=True)
class ProgramSpec:
    """Declarative description of one fused program (see module doc)."""
    name: str                       # human-readable (stats / AOT manifest)
    key: Tuple                      # stable semantic identity (hashable)
    make: Callable                  # make(ctx: BuildCtx) -> fn(*args)
    in_kinds: Tuple[str, ...]       # one kind per positional argument
    out_kinds: Optional[Tuple[str, ...]] = None
    donate: Tuple[int, ...] = ()    # argnums donated to XLA
    # mixed-precision identity (core.precision.Precision.key()). A
    # bf16-compute program takes the SAME fp32 master inputs as its fp32
    # twin — the cast is traced inside — so the abstract-arg dtypes
    # alone cannot distinguish them; this token folds the policy into
    # the ProgramCache key. None = fp32 default (key-compatible with
    # every pre-policy entry).
    precision: Optional[Tuple] = None

    def __post_init__(self):
        for k in self.in_kinds:
            if k not in IN_KINDS:
                raise ValueError(f"unknown in_kind {k!r}")
        for k in (self.out_kinds or ()):
            if k not in ("replicated", "vector", "rows") \
                    and not k.startswith("in:"):
                raise ValueError(f"unknown out_kind {k!r}")


class Program:
    """A lowered + jitted program, ready to execute. ``__call__`` is the
    hot path; everything else is introspection / AOT export support.

    Cost attribution (DESIGN.md §12): ``param_bytes_per_device`` is
    computed eagerly at ``lower()`` time (pure sharding arithmetic, no
    compile); ``cost()`` — FLOPs, bytes accessed, loop-aware HLO model —
    runs an AOT analysis compile ON DEMAND and memoizes it, because
    JAX's AOT path does not share the jit wrapper's dispatch cache and
    eager analysis would double every cold compile."""

    __slots__ = ("fn", "name", "cache_key", "num_particles",
                 "abstract_args", "donate", "param_bytes_per_device",
                 "_cost")

    def __init__(self, fn, name, cache_key, num_particles, abstract_args,
                 donate, param_bytes_per_device: int = 0):
        self.fn = fn
        self.name = name
        self.cache_key = cache_key
        self.num_particles = num_particles
        self.abstract_args = abstract_args   # ShapeDtypeStruct trees
        self.donate = donate
        self.param_bytes_per_device = param_bytes_per_device
        self._cost = None

    def __call__(self, *args):
        tr = _TRACER
        if not tr.enabled:
            return self.fn(*args)
        # tracing on: the fused dispatch gets an obs span AND a
        # jax.profiler named scope, so device-side profiles (perfetto
        # via jax.profiler.trace) line up with the host timeline
        t0 = clock.now()
        with jax.profiler.TraceAnnotation(f"repro.program.{self.name}"):
            out = self.fn(*args)
        tr.record(f"program.{self.name}", "runtime", t0, clock.now(),
                  {"n": self.num_particles})
        return out

    def cost(self):
        """Memoized cost attribution dict (flops / bytes_accessed /
        param_bytes_per_device / memory / loop_aware); None for
        AOT-preloaded programs (no abstract args) or when the analysis
        fails. First call pays one analysis compile."""
        if self._cost is None:
            from ..obs.device import program_cost
            try:
                self._cost = program_cost(self)
            except Exception:
                return None
        return self._cost

    def cost_if_computed(self):
        return self._cost

    def __repr__(self) -> str:
        return f"Program({self.name!r}, n={self.num_particles})"


# ---------------------------------------------------------------------------
# lowering: spec + placement + example args -> jitted Program
# ---------------------------------------------------------------------------

def _num_particles(spec: ProgramSpec, args) -> int:
    for kind, a in zip(spec.in_kinds, args):
        if kind == "state":
            return jax.tree.leaves(a)[0].shape[0]
    return 0


def _in_sharding(kind: str, arg, placement: Placement, n: int):
    if kind == "state":
        return placement.shardings(arg)
    if kind == "replicated":
        return placement.replicated(arg)
    if kind == "vector":
        return placement.vector(n)
    if kind == "rows":
        return jax.tree.map(lambda _: placement.vector(n), arg)
    raise ValueError(kind)


def _param_bytes_per_device(spec: ProgramSpec, args, in_shs) -> int:
    """Bytes of the first "state" argument resident on ONE device under
    the lowering's input shardings — the per-program analogue of
    ``store.per_device_bytes``, captured at compile time (no mesh: the
    whole tree lives on the one device)."""
    for i, (kind, a) in enumerate(zip(spec.in_kinds, args)):
        if kind != "state":
            continue
        leaves = jax.tree.leaves(a)
        shs = (jax.tree.leaves(in_shs[i]) if in_shs is not None
               else [None] * len(leaves))
        total = 0
        for x, sh in zip(leaves, shs):
            shape = tuple(jnp.shape(x))
            if sh is not None and hasattr(sh, "shard_shape"):
                try:
                    shape = sh.shard_shape(shape)
                except Exception:
                    pass
            total += int(np.prod(shape, dtype=np.int64)
                         * np.dtype(jnp.result_type(x)).itemsize)
        return total
    return 0


def _out_shardings(spec: ProgramSpec, in_shs, placement: Placement, n: int):
    outs = []
    for kind in spec.out_kinds:
        if kind.startswith("in:"):
            outs.append(in_shs[int(kind[3:])])
        elif kind == "replicated":
            outs.append(placement.replicated(0))
        elif kind == "vector":
            outs.append(placement.vector(n))
        else:
            raise ValueError(kind)
    # a single entry acts as a prefix over the whole output tree (the
    # fully-replicated serving heads); multiple entries must match the
    # output tuple positionally
    return outs[0] if len(outs) == 1 else tuple(outs)


def lower(spec: ProgramSpec, placement: Optional[Placement], args,
          cache_key=None) -> Program:
    """Lower a spec against a placement plan into a jitted Program.

    This is the ONLY place in the repository that is allowed to call
    ``jax.jit`` on fused particle programs — train, serve, and NEL
    backends all compile through here (tests/test_runtime.py greps)."""
    placement = placement or Placement()
    n = _num_particles(spec, args)
    model_axis = (placement.model_axis
                  if placement.model_axis_size() > 1 else None)
    ctx = BuildCtx(placement=placement, num_particles=n,
                   spmd_axis=placement.spmd_axis(n) if n else None,
                   model_axis=model_axis)
    fn = spec.make(ctx)
    policy = placement.activation_policy()
    if policy is not None:
        # enter the activation policy *inside* the traced body so every
        # trace (first call AND shape-driven retraces) sees the model-axis
        # constraints at the models' maybe_shard sites; the mesh context
        # lets the bare-PartitionSpec constraints resolve against this
        # placement's mesh (and composes with vmap's spmd-axis prepend)
        from ..sharding.policy import activation_policy as _act
        inner = fn

        def fn(*call_args, __inner=inner, __pol=policy,
               __mesh=placement.mesh):
            with __mesh, _act(__pol):
                return __inner(*call_args)
    kwargs = {}
    in_shs = None
    if spec.donate:
        kwargs["donate_argnums"] = spec.donate
    if placement.mesh is not None:
        in_shs = tuple(_in_sharding(k, a, placement, n)
                       for k, a in zip(spec.in_kinds, args))
        kwargs["in_shardings"] = in_shs
        if spec.out_kinds is not None:
            kwargs["out_shardings"] = _out_shardings(spec, in_shs,
                                                     placement, n)
    jitted = jax.jit(fn, **kwargs)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        tuple(args))
    return Program(jitted, spec.name, cache_key, n, abstract, spec.donate,
                   param_bytes_per_device=_param_bytes_per_device(
                       spec, args, in_shs))
