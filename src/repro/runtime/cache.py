"""ProgramCache — the process-wide compile cache of the runtime layer.

One cache replaces the three ad-hoc ones that grew over PRs 1-3
(``functional.compile_*`` rebuilt per call site, each ``Infer``'s
``self._step`` keyed on ``id(optimizer)``, ``PredictiveEngine._programs``
keyed per engine). Cache-key anatomy (DESIGN.md §8):

    (spec.key,            # semantic identity of the program
     in/out kinds+donate, # argument roles + donation plan (replace()
                          # variants of one spec must not collide)
     spec.precision,      # mixed-precision policy token — the compute
                          # cast is traced INSIDE the body, so the fp32
                          # masters' abstract dtypes cannot carry it
     placement,           # Placement is frozen/hashable: mesh + axes + mode
     state_token,         # store generation: particle-set changes invalidate
     abstract(args))      # (treedef, shape, dtype) per argument — request
                          # batches are power-of-two padded *before* lookup
                          # (bucketing.py), so mixed sizes share programs

Notably NOT in the key: the engine/Infer/PD instance. Train, predict,
and serve over the same module+store therefore share programs — a serve
engine opened after a second engine on the same store compiles nothing.

``stats`` distinguishes hits (key present), misses (key absent), and
cold_compiles (a program was actually built — misses served from an AOT
``preload`` are not cold). ``aot_dump``/``preload`` are the ahead-of-time
serialization hook: programs export via ``jax.export`` to one file per
cache key so a warm process can be seeded without recompiling.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core.store import Placement
from ..obs import trace as _trace
from .program import Program, ProgramSpec, abstract_key, lower


def _key_fingerprint(key: Tuple) -> str:
    return hashlib.sha256(repr(key).encode()).hexdigest()[:16]


class ProgramCache:
    """Process-wide (or private, for tests) plan -> Program cache.

    Bounded LRU: ``max_programs`` caps resident compiled programs so a
    long-lived process that churns stores/placements/closures cannot
    grow without bound; evicted programs that are still referenced keep
    working (the cache only forgets them), and a re-lookup recompiles.
    """

    def __init__(self, max_programs: int = 512):
        self._lock = threading.Lock()
        self._programs: "OrderedDict[Tuple, Program]" = OrderedDict()
        self._preloaded: Dict[Tuple, Program] = {}
        self.max_programs = max_programs
        self.stats = {"hits": 0, "misses": 0, "cold_compiles": 0,
                      "evictions": 0}

    # -- key construction ----------------------------------------------------
    @staticmethod
    def cache_key(spec: ProgramSpec, placement: Optional[Placement], args,
                  state_token=None, arg_keys: Optional[Sequence] = None
                  ) -> Tuple:
        # in/out kinds and the donation plan are part of program identity:
        # two specs sharing a semantic key but differing in donation (a
        # dataclasses.replace variant) must not collide
        abstract = tuple(
            arg_keys[i] if arg_keys is not None and arg_keys[i] is not None
            else abstract_key(a)
            for i, a in enumerate(args))
        # spec.precision is part of program identity: a bf16-compute
        # program consumes the SAME fp32 master inputs as its fp32 twin
        # (the cast is traced inside the body), so abstract dtypes alone
        # cannot tell them apart. Changing precision = cold compile;
        # re-running the same precision = warm hit (test_precision.py).
        return (spec.key, spec.in_kinds, spec.out_kinds, spec.donate,
                spec.precision, placement or Placement(), state_token,
                abstract)

    # -- the lookup path -----------------------------------------------------
    def lookup(self, spec: ProgramSpec, placement: Optional[Placement],
               args, state_token=None, arg_keys: Optional[Sequence] = None
               ) -> Tuple[Program, bool]:
        """(program, hit). On miss the spec is lowered + jitted (a *cold
        compile*) unless an AOT-preloaded program covers the key.

        ``arg_keys`` lets hot paths pass precomputed ``abstract_key``
        entries (None entries are computed here) — serving engines cache
        the stacked-params key between store commits so a request never
        re-flattens the whole parameter tree."""
        key = self.cache_key(spec, placement, args, state_token, arg_keys)
        with self._lock:
            prog = self._programs.get(key)
            if prog is not None:
                self._programs.move_to_end(key)
                self.stats["hits"] += 1
                _trace.instant("cache.hit", "runtime", program=spec.name)
                return prog, True
            self.stats["misses"] += 1
            _trace.instant("cache.miss", "runtime", program=spec.name)
            pre = self._preloaded.pop(key, None)
            if pre is not None:
                self._insert(key, pre)
                return pre, False
        # the cold compile happens OUTSIDE the lock; the span brackets
        # trace + jit dispatch-cache population for this key
        with _trace.span("runtime.lower", "runtime", program=spec.name):
            built = lower(spec, placement, args, cache_key=key)
        with self._lock:
            prog = self._programs.get(key)
            if prog is None:
                prog = built
                self._insert(key, built)
                self.stats["cold_compiles"] += 1
        return prog, False

    def _insert(self, key, prog):
        """Insert + LRU-evict (lock held)."""
        self._programs[key] = prog
        self._programs.move_to_end(key)
        while len(self._programs) > self.max_programs:
            self._programs.popitem(last=False)
            self.stats["evictions"] += 1

    def program(self, spec: ProgramSpec, placement: Optional[Placement],
                args, state_token=None,
                arg_keys: Optional[Sequence] = None) -> Program:
        return self.lookup(spec, placement, args, state_token, arg_keys)[0]

    def run(self, spec: ProgramSpec, *args,
            placement: Optional[Placement] = None, state_token=None):
        """plan -> (cached) lower -> execute in one call."""
        return self.program(spec, placement, args, state_token)(*args)

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._programs)

    def snapshot_stats(self) -> Dict[str, Any]:
        with self._lock:
            s = dict(self.stats)
            s["programs"] = len(self._programs)
            total = s["hits"] + s["misses"]
            s["hit_rate"] = s["hits"] / total if total else 0.0
            return s

    def program_costs(self, compute: bool = False) -> List[Dict[str, Any]]:
        """Per-entry cost attribution (obs.device): name, key
        fingerprint, particle count, eager per-device param bytes, and —
        when already analyzed or ``compute=True`` — the FLOPs/bytes
        ``Program.cost()`` dict (compute forces one analysis compile per
        not-yet-analyzed entry; AOT-preloaded entries stay None)."""
        with self._lock:
            items = list(self._programs.items())
        return [{
            "name": prog.name,
            "fingerprint": _key_fingerprint(key),
            "num_particles": prog.num_particles,
            "param_bytes_per_device": prog.param_bytes_per_device,
            "cost": prog.cost() if compute else prog.cost_if_computed(),
        } for key, prog in items]

    def clear(self):
        with self._lock:
            self._programs.clear()
            self._preloaded.clear()

    # -- AOT serialization hook ---------------------------------------------
    def aot_dump(self, directory: str) -> Dict[str, str]:
        """Serialize every cached program via ``jax.export`` to
        ``<fingerprint>.jaxprog`` (+ a manifest.json mapping fingerprints
        to spec names). Programs that cannot be exported (e.g. exotic
        custom calls) are skipped. Returns {fingerprint: name}."""
        from jax import export as jax_export
        os.makedirs(directory, exist_ok=True)
        with self._lock:
            items = list(self._programs.items())
        manifest = {}
        for key, prog in items:
            try:
                exported = jax_export.export(prog.fn)(*prog.abstract_args)
                blob = exported.serialize()
            except Exception:   # best-effort: AOT is an optimization only
                continue
            fp = _key_fingerprint(key)
            with open(os.path.join(directory, f"{fp}.jaxprog"), "wb") as f:
                f.write(blob)
            manifest[fp] = prog.name
        with open(os.path.join(directory, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        return manifest

    def preload(self, spec: ProgramSpec, placement: Optional[Placement],
                args, blob: bytes, state_token=None):
        """Seed the cache for one key from ``aot_dump`` output: the next
        miss on that key deserializes instead of cold-compiling."""
        from jax import export as jax_export
        exported = jax_export.deserialize(blob)
        key = self.cache_key(spec, placement, args, state_token)
        prog = Program(exported.call, spec.name, key, 0, None, spec.donate)
        with self._lock:
            self._preloaded[key] = prog


# ---------------------------------------------------------------------------
# the process-wide cache
# ---------------------------------------------------------------------------

_GLOBAL = ProgramCache()


def global_cache() -> ProgramCache:
    return _GLOBAL


def jit_program(name: str, key: Tuple, fn, args, donate: Tuple[int, ...] = ()
                ) -> Program:
    """Cached plain-jit through the shared cache (no mesh semantics): the
    compile path for host-driven single-network programs — the NEL
    backend's per-particle step/forward and the paper's sequential
    baselines. ``fn`` may be a fresh closure per call; only ``key`` and
    the argument shapes decide identity."""
    spec = ProgramSpec(name=name, key=key, make=lambda ctx: fn,
                       in_kinds=("replicated",) * len(args), out_kinds=None,
                       donate=donate)
    return _GLOBAL.program(spec, None, args)
