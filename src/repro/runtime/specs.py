"""Generic ProgramSpec builders shared by train and predict paths.

Algorithm- and workload-specific specs live next to their math
(``bdl/svgd.svgd_step_spec``, ``serve/engine``'s BMA specs); the builders
here cover the shapes every ensemble-family algorithm reuses. All of them
are *thin*: the functional bodies stay in ``core/functional.py`` — a spec
only names the function, the argument roles, and the donation plan.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax

from ..core import functional
from .program import ProgramSpec, ident


def ensemble_step(loss_fn: Callable, optimizer) -> ProgramSpec:
    """One train step for all particles: vmapped value_and_grad +
    optimizer update. State donated — a multi-epoch loop reuses the
    buffers in place and never touches the host."""
    return ProgramSpec(
        name="ensemble_step",
        key=("ensemble_step", ident(loss_fn), ident(optimizer)),
        make=lambda ctx: functional.ensemble_step(loss_fn, optimizer,
                                                  ctx.spmd_axis),
        in_kinds=("state", "state", "replicated"),
        out_kinds=("in:0", "in:1", "vector"),
        donate=(0, 1))


def ensemble_predict(forward: Callable) -> ProgramSpec:
    """hat f(x) = (1/n) sum_i nn_{theta_i}(x) — one fused program."""
    return ProgramSpec(
        name="ensemble_predict",
        key=("ensemble_predict", ident(forward)),
        make=lambda ctx: functional.ensemble_predict(forward, ctx.spmd_axis),
        in_kinds=("state", "replicated"))


def map_step(fn: Callable, *, key: Tuple, n_state: int = 1,
             donate: Tuple[int, ...] = (0,)) -> ProgramSpec:
    """A per-particle map vmapped over `n_state` stacked trees (SWAG
    moment collection), sharded and donated like the train step. ``key``
    must be stable across calls (use ``ident`` on long-lived functions)."""
    return ProgramSpec(
        name="map_step",
        key=("map_step",) + tuple(key),
        make=lambda ctx: jax.vmap(fn, spmd_axis_name=ctx.spmd_axis),
        in_kinds=("state",) * n_state,
        out_kinds=("in:0",),
        donate=donate)
