"""Generic ProgramSpec builders shared by train and predict paths.

Algorithm- and workload-specific specs live next to their math
(``bdl/svgd.svgd_step_spec``, ``serve/engine``'s BMA specs); the builders
here cover the shapes every ensemble-family algorithm reuses. All of them
are *thin*: the functional bodies stay in ``core/functional.py`` — a spec
only names the function, the argument roles, and the donation plan.

Elastic lifecycle (DESIGN.md §9): every builder optionally threads the
store's ``active_mask()`` as one extra argument after the dense ones —
"replicated" for whole-mask reductions (BMA means, losses), "vector" for
per-slot gating vmapped alongside the state. The mask's *content* is a
runtime value, never part of the cache key, so clone/kill churn within
capacity reuses the same compiled program.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from ..core import functional
from ..core import precision as precision_mod
from .program import ProgramSpec, ident


def ensemble_step(loss_fn: Callable, optimizer,
                  precision=None) -> ProgramSpec:
    """One train step for all particles: vmapped value_and_grad +
    optimizer update. State donated — a multi-epoch loop reuses the
    buffers in place and never touches the host. Call with or without a
    trailing active mask (the functional body defaults it to dense).

    ``precision`` (preset name / ``Precision``) selects the
    master/compute split: when compute != master, the body casts the
    masters to the compute dtype in-trace, casts grads back, and updates
    the masters (core.functional.ensemble_step). The policy rides on
    ``ProgramSpec.precision`` so the ProgramCache keys on it."""
    prec = precision_mod.get(precision)
    cd = prec.compute if prec.casts_compute else None
    return ProgramSpec(
        name="ensemble_step",
        key=("ensemble_step", ident(loss_fn), ident(optimizer)),
        make=lambda ctx: functional.ensemble_step(loss_fn, optimizer,
                                                  ctx.spmd_axis,
                                                  compute_dtype=cd),
        in_kinds=("state", "state", "replicated", "replicated"),
        out_kinds=("in:0", "in:1", "vector"),
        donate=(0, 1),
        precision=prec.key() if prec.casts_compute else None)


def ensemble_predict(forward: Callable) -> ProgramSpec:
    """hat f(x) = (1/n) sum_i nn_{theta_i}(x) — one fused program
    (mask-weighted over live slots when called with a trailing mask)."""
    return ProgramSpec(
        name="ensemble_predict",
        key=("ensemble_predict", ident(forward)),
        make=lambda ctx: functional.ensemble_predict(forward, ctx.spmd_axis),
        in_kinds=("state", "replicated", "replicated"))


def paged_decode_step(decode_fn: Callable, reduce_fn: Callable, *,
                      key: Tuple) -> ProgramSpec:
    """One fixed-shape continuous-batching decode step.

    ``decode_fn(params_row, pages_row, tokens, block_tables, seq_lens)
    -> (logits, pages_row)`` is vmapped over the stacked particle axis
    (params and pages batched; the step inputs replicated). The step
    inputs arrive PACKED in one ``(B, 2 + n_pmax)`` i32 array —
    ``[:, 0]`` tokens, ``[:, 1]`` seq_lens, ``[:, 2:]`` block tables —
    so a scheduler step is exactly one H2D transfer. ``reduce_fn(member
    logits (P, B, V), mask, ctx)`` produces the replicated per-row heads
    (BMA + sampling live there). Pages are donated: the pool updates in
    place on device, no per-step copy.

    All shapes (max-active rows × max pages, capacity particles) are
    scheduler constants, so the whole serving loop is ONE cached program
    — admission/retirement churn only changes the packed values.
    """
    def make(ctx):
        def fused(stacked_params, pages, packed, mask):
            tokens, seq_lens, bt = packed[:, 0], packed[:, 1], packed[:, 2:]
            logits, new_pages = jax.vmap(
                decode_fn, in_axes=(0, 0, None, None, None),
                spmd_axis_name=ctx.spmd_axis)(
                stacked_params, pages, tokens, bt, seq_lens)
            return reduce_fn(logits, mask, ctx), new_pages

        return fused

    return ProgramSpec(
        name="paged_decode_step",
        key=("paged_decode_step",) + tuple(key),
        make=make,
        in_kinds=("state", "state", "replicated", "replicated"),
        out_kinds=("replicated", "in:1"),
        donate=(1,))


def paged_prefill(prefill_fn: Callable, reduce_fn: Callable, *, n_pmax: int,
                  key: Tuple) -> ProgramSpec:
    """Prompt admission: chunked prefill of ONE sequence into the pool.

    ``prefill_fn(params_row, pages_row, tokens (1, Sp), block_table_row,
    n_tokens) -> (last-token logits, pages_row)``; the packed input is a
    ``(Sp + n_pmax + 1,)`` i32 vector ``[tokens..., block_table...,
    n_tokens]`` (one H2D per admission; Sp is the caller's pow2 prompt
    bucket, so the cache holds one program per bucket)."""
    def make(ctx):
        def fused(stacked_params, pages, packed, mask):
            sp = packed.shape[0] - n_pmax - 1
            tokens = packed[None, :sp]
            bt_row = packed[sp:sp + n_pmax]
            n_tokens = packed[-1]
            logits, new_pages = jax.vmap(
                prefill_fn, in_axes=(0, 0, None, None, None),
                spmd_axis_name=ctx.spmd_axis)(
                stacked_params, pages, tokens, bt_row, n_tokens)
            return reduce_fn(logits, mask, ctx), new_pages

        return fused

    return ProgramSpec(
        name="paged_prefill",
        key=("paged_prefill", n_pmax) + tuple(key),
        make=make,
        in_kinds=("state", "state", "replicated", "replicated"),
        out_kinds=("replicated", "in:1"),
        donate=(1,))


def spec_draft_step(decode_fn: Callable, *, k_max: int, key: Tuple,
                    quantized: bool = False,
                    dequant_dtype=jnp.float32) -> ProgramSpec:
    """Draft ``k_max`` tokens per row from ONE particle, in ONE program.

    ``decode_fn`` is the same single-row closure ``paged_decode_step``
    vmaps — here it runs un-vmapped on the draft particle's slice, inside
    an internal ``lax.scan`` over the drafted positions (token argmax fed
    back in-trace, the pages row as carry), so a whole drafted window
    costs one dispatch. The packed input is ``(B, 3 + n_pmax)`` i32 —
    ``[:, 0]`` last committed token, ``[:, 1]`` its absolute position
    (-1 = inactive row), ``[:, 2]`` per-row draft length k in
    ``[0, k_max]`` (adaptive-K is a runtime value; the program shape is
    fixed at ``k_max``), ``[:, 3:]`` block tables. The draft slot arrives
    as a traced i32 scalar — clone/kill churn re-picks it without
    touching the cache key. Returns ``(drafts (B, k_max) i32,
    new_pages)``; entries past a row's k are garbage the host ignores.

    ``quantized=True`` swaps the first operand from the stacked params to
    a pre-sliced int8 pack (leading axis 1, as built by
    ``precision.quantize_int8``), dequantized in-trace — the draft then
    costs int8 memory traffic and zero live-slot time, while verify
    still reads only full-precision particles."""
    def make(ctx):
        def fused(params_in, pages, packed, slot):
            tok0, sl0 = packed[:, 0], packed[:, 1]
            k_lens, bt = packed[:, 2], packed[:, 3:]
            if quantized:
                row = precision_mod.dequantize(params_in, dequant_dtype)
                params_row = jax.tree.map(lambda a: a[0], row)
            else:
                params_row = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, slot, 0, keepdims=False), params_in)
            pages_row = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(
                    a, slot, 0, keepdims=False), pages)

            def step(carry, j):
                tok, sl, pg = carry
                live = (sl >= 0) & (j < k_lens)
                logits, pg = decode_fn(params_row, pg, tok, bt,
                                       jnp.where(live, sl, -1))
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                tok = jnp.where(live, nxt, tok)
                sl = sl + live.astype(jnp.int32)
                return (tok, sl, pg), tok

            (_, _, pages_row), drafts = jax.lax.scan(
                step, (tok0, sl0, pages_row), jnp.arange(k_max))
            new_pages = jax.tree.map(
                lambda full, r: jax.lax.dynamic_update_index_in_dim(
                    full, r.astype(full.dtype), slot, 0), pages, pages_row)
            return drafts.T, new_pages

        return fused

    return ProgramSpec(
        name="spec_draft_step",
        key=("spec_draft_step", k_max, bool(quantized),
             jnp.dtype(dequant_dtype).name) + tuple(key),
        make=make,
        in_kinds=(("replicated" if quantized else "state"),
                  "state", "replicated", "replicated"),
        out_kinds=("replicated", "in:1"),
        donate=(1,))


def spec_verify(verify_fn: Callable, reduce_fn: Callable, *, w_max: int,
                key: Tuple) -> ProgramSpec:
    """Score a drafted window across the whole ensemble in one pass.

    ``verify_fn(params_row, pages_row, tokens (B, W), block_tables,
    seq_lens, win_lens) -> (logits (B, W, V), pages_row)`` is vmapped
    over the stacked particle axis. The packed input is
    ``(B, w_max + 2 + n_pmax)`` i32 — ``[:, :W]`` window tokens (the last
    committed token followed by the drafts), ``[:, W]`` the absolute
    position of window token 0 (-1 = inactive), ``[:, W+1]`` the live
    window length, ``[:, W+2:]`` block tables. ``reduce_fn(member logits
    (P, B, W, V), mask, ctx)`` yields the per-position BMA heads the
    accept rule consumes. The verify scatter overwrites the draft
    particle's drafted KV with bit-identical values and writes every
    other particle's, so an accepted prefix leaves the pool exactly as k
    sequential committed steps would."""
    def make(ctx):
        def fused(stacked_params, pages, packed, mask):
            tokens = packed[:, :w_max]
            seq_lens = packed[:, w_max]
            win_lens = packed[:, w_max + 1]
            bt = packed[:, w_max + 2:]
            logits, new_pages = jax.vmap(
                verify_fn, in_axes=(0, 0, None, None, None, None),
                spmd_axis_name=ctx.spmd_axis)(
                stacked_params, pages, tokens, bt, seq_lens, win_lens)
            return reduce_fn(logits, mask, ctx), new_pages

        return fused

    return ProgramSpec(
        name="spec_verify",
        key=("spec_verify", w_max) + tuple(key),
        make=make,
        in_kinds=("state", "state", "replicated", "replicated"),
        out_kinds=("replicated", "in:1"),
        donate=(1,))


def map_step(fn: Callable, *, key: Tuple, n_state: int = 1,
             donate: Tuple[int, ...] = (0,), masked: bool = False
             ) -> ProgramSpec:
    """A per-particle map vmapped over `n_state` stacked trees (SWAG
    moment collection), sharded and donated like the train step. ``key``
    must be stable across calls (use ``ident`` on long-lived functions).

    ``masked=True`` appends a (capacity,) active-mask argument, vmapped
    per row: dead slots keep their first state tree bit-for-bit instead
    of taking ``fn``'s output."""
    if not masked:
        return ProgramSpec(
            name="map_step",
            key=("map_step",) + tuple(key),
            make=lambda ctx: jax.vmap(fn, spmd_axis_name=ctx.spmd_axis),
            in_kinds=("state",) * n_state,
            out_kinds=("in:0",),
            donate=donate)

    def row(*args):
        *rows, m = args
        return jax.tree.map(lambda nw, od: jnp.where(m > 0, nw, od),
                            fn(*rows), rows[0])

    # the mask rides in replicated (that is how the store places its
    # cached device mask) and is split per row by the vmap itself
    return ProgramSpec(
        name="map_step",
        key=("map_step", "masked") + tuple(key),
        make=lambda ctx: jax.vmap(row, in_axes=(0,) * n_state + (0,),
                                  spmd_axis_name=ctx.spmd_axis),
        in_kinds=("state",) * n_state + ("replicated",),
        out_kinds=("in:0",),
        donate=donate)
