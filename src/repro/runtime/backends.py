"""Runtime protocol: ``backend="nel"|"compiled"`` selects an object.

``PushDistribution`` used to branch on a backend *string* at every seam
(``pd.p_predict``, ``Infer.bayes_infer``); each branch then kept its own
compile cache. Now the string selects a Runtime once, at construction:

  * ``NelRuntime``     — the paper-faithful actor path: inference runs the
    algorithm's message-passing procedure on the PR-1 Executor (persistent
    per-device event loops); prediction is n sequential per-particle
    forwards. Its per-particle step/forward programs ALSO compile through
    the shared ProgramCache (``jit_program`` via ``ParticleModule``), so
    all three workloads — train, serve, NEL — share one compile layer.
  * ``CompiledRuntime`` — the fused stacked-axis path: algorithms with a
    ``_fused_infer`` form run one XLA program over the store's stacked
    state (checkout -> donated epochs -> commit); algorithms without one
    transparently fall back to the NEL procedure.

Both expose ``stats()`` merging the executor's wait-vs-run counters with
the ProgramCache's hit/miss/cold-compile counters — the unified
observability surface ``PushDistribution.stats()`` returns.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Protocol, runtime_checkable

import jax

from ..obs import summary as _obs_summary
from .cache import ProgramCache, global_cache
from .program import ProgramSpec
from . import specs

BACKENDS = ("nel", "compiled")


@runtime_checkable
class Runtime(Protocol):
    """What a runtime backend must provide (DESIGN.md §8)."""
    name: str

    def infer(self, algo, dataloader, epochs: int, **kw): ...

    def predict(self, pd, batch): ...

    def run(self, spec: ProgramSpec, *args, placement=None,
            state_token=None): ...

    def stats(self) -> Dict[str, Any]: ...


class _BaseRuntime:
    def __init__(self, pd, cache: Optional[ProgramCache] = None):
        self.pd = pd
        # explicit None test: an *empty* ProgramCache is falsy (__len__)
        self.cache = cache if cache is not None else global_cache()

    def program(self, spec: ProgramSpec, *args, placement=None,
                state_token=None):
        """plan -> (cached) lower: the Program for this PD's placement
        and store generation. Epoch loops fetch it ONCE before the loop
        and call it per batch — the returned jit wrapper handles batch
        shape changes itself, so the per-step host cost is a plain call,
        not a cache-key construction over the whole state tree."""
        if placement is None:
            placement = self.pd.placement
        if state_token is None:
            state_token = self.pd.store.generation()
        return self.cache.program(spec, placement, args, state_token)

    def run(self, spec: ProgramSpec, *args, placement=None, state_token=None):
        """plan -> (cached) lower -> execute one fused program against
        this PD's placement and store generation."""
        return self.program(spec, *args, placement=placement,
                            state_token=state_token)(*args)

    def stats(self) -> Dict[str, Any]:
        ex = self.pd.nel.executor.stats()
        pl = self.pd.placement
        store_stats = self.pd.store.snapshot_stats()
        out = {
            "backend": self.name,
            "executor": ex,
            "dispatch": dict(self.pd.nel.stats),
            "store": store_stats,
            "program_cache": self.cache.snapshot_stats(),
            "lifecycle": {**self.pd.store.lifecycle_stats(),
                          **getattr(self.pd, "lifecycle", {})},
            # the 2D placement plan + its footprint: mesh shape, sharding
            # mode, per-device parameter bytes (drops by ~model-axis size
            # under tensor parallelism), and how often state was re-placed
            "placement": {
                "mesh_shape": (None if pl.mesh is None else
                               {a: int(pl.mesh.shape[a])
                                for a in pl.mesh.axis_names}),
                "mode": pl.mode,
                "particle_axis": pl.particle_axis,
                "model_axis": pl.model_axis,
                "model_axis_size": pl.model_axis_size(),
                "per_device_param_bytes":
                    self.pd.store.per_device_bytes("params"),
                "reshards": store_stats["device_puts"],
            },
            # tracer + metric-registry state (repro.obs): is tracing on,
            # how many spans recorded/buffered/dropped, ring capacity
            "obs": _obs_summary(),
        }
        # continuous-batching decode, when a DecodeScheduler serves this
        # store (lazy import: runtime must not depend on serve at module
        # scope — serve already imports runtime)
        from ..serve.batcher import decode_stats_for
        decode = decode_stats_for(self.pd.store)
        if decode is not None:
            out["decode"] = decode
        return out


class NelRuntime(_BaseRuntime):
    """Paper-faithful actor runtime (wraps the PR-1 Executor)."""

    name = "nel"

    def infer(self, algo, dataloader, epochs: int, **kw):
        return algo._nel_infer(dataloader, epochs, **kw)

    def predict(self, pd, batch):
        """n per-particle forwards on the event loops + host average."""
        futs = [pd.particles[pid].forward(batch)
                for pid in pd.particle_ids()]
        outs = [f.wait() for f in futs]
        return jax.tree.map(lambda *xs: sum(xs) / len(xs), *outs)


class CompiledRuntime(_BaseRuntime):
    """Fused stacked-axis runtime (wraps the store's checkout/commit
    protocol and the shared ProgramCache)."""

    name = "compiled"

    def infer(self, algo, dataloader, epochs: int, **kw):
        if algo._has_fused():
            return algo._fused_infer(dataloader, epochs, **kw)
        return algo._nel_infer(dataloader, epochs, **kw)

    def predict(self, pd, batch):
        pids = pd.particle_ids()
        if not pids:
            return NelRuntime.predict(self, pd, batch)
        # capacity-padded stacked params + active mask, read as ONE
        # atomic store snapshot (a mask bit never goes live before its
        # slot's data lands): the fused BMA averages live slots only,
        # and clone/kill churn within capacity reuses this exact
        # program (shapes and generation unchanged)
        _, mask, stacked = pd.store.snapshot("params")
        spec = specs.ensemble_predict(pd.module.forward)
        return self.run(spec, stacked, batch, mask)


def make_runtime(backend: str, pd,
                 cache: Optional[ProgramCache] = None) -> Runtime:
    if backend == "nel":
        return NelRuntime(pd, cache)
    if backend == "compiled":
        return CompiledRuntime(pd, cache)
    raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
