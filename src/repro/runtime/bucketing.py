"""Power-of-two batch bucketing — shared by the compile cache and the
serving layer. Request batches are padded up to the next bucket *before*
the cache lookup, so an engine serving mixed batch sizes holds one
program per (spec, placement, bucket) instead of one per distinct size.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bucket_size(m: int) -> int:
    """Next power of two >= m."""
    if m < 1:
        raise ValueError("batch must be non-empty")
    b = 1
    while b < m:
        b <<= 1
    return b


def pad_rows(tree, target: int):
    """Pad every leaf's leading axis to `target` by repeating the last
    row (repeat, not zeros: padding must stay in-distribution for
    normalization layers; padded rows are sliced off after the call)."""
    m = jax.tree.leaves(tree)[0].shape[0]
    if m == target:
        return tree
    return jax.tree.map(
        lambda x: jnp.concatenate(
            [x, jnp.broadcast_to(x[-1:], (target - m,) + x.shape[1:])]),
        tree)
