"""Dispatch-throughput microbenchmark: thread-per-dispatch vs event loops.

The seed runtime spawned a fresh Python thread for every dispatched
message; the executor keeps persistent per-device loops with per-particle
mailboxes. This benchmark measures raw messages/sec through both at a
fixed particle count — the number the ISSUE-1 acceptance bar (>= 5x at 8
particles) is checked against. The legacy dispatcher below is a faithful
inline copy of the seed's ``NodeEventLoop.dispatch`` threading strategy,
kept here (not in core) purely as the before-side of the comparison.

Rows: dispatch/<impl>/p<particles>,us_per_message,msgs_per_sec=<rate>
plus a final  dispatch/speedup/p<particles>,<ratio>,x_over_thread_per_msg
summary row (second column numeric, like every row in the suite).
"""
from __future__ import annotations

import argparse
import threading
import time

from repro.core import NodeEventLoop
from repro.core.messages import PFuture


class _LegacyThreadPerDispatch:
    """The seed's dispatch strategy: one Python thread per message."""

    def __init__(self):
        self._threads = []
        self._lock = threading.Lock()

    def dispatch(self, pid, fn, *args, **kwargs) -> PFuture:
        fut = PFuture()

        def run():
            try:
                fut._resolve(fn(*args, **kwargs))
            except BaseException as e:
                fut._reject(e)

        t = threading.Thread(target=run, daemon=True)
        with self._lock:
            self._threads = [th for th in self._threads if th.is_alive()]
            self._threads.append(t)
        t.start()
        return fut

    def shutdown(self):
        with self._lock:
            threads = list(self._threads)
        for t in threads:
            t.join(timeout=30)


def _noop():
    return None


def _drive(dispatch, particles: int, messages: int) -> float:
    """Round-robin `messages` no-op dispatches over `particles`; returns
    wall seconds from first dispatch to last completion."""
    t0 = time.perf_counter()
    futs = [dispatch(i % particles, _noop) for i in range(messages)]
    for f in futs:
        f.wait()
    return time.perf_counter() - t0


def run(particles: int = 8, messages: int = 4000, iters: int = 3):
    # --- legacy: thread per message ------------------------------------
    legacy_best = float("inf")
    for _ in range(iters):
        legacy = _LegacyThreadPerDispatch()
        legacy_best = min(legacy_best, _drive(legacy.dispatch, particles,
                                              messages))
        legacy.shutdown()
    legacy_rate = messages / legacy_best
    print(f"dispatch/thread-per-msg/p{particles},"
          f"{legacy_best / messages * 1e6:.2f},"
          f"msgs_per_sec={legacy_rate:.0f}", flush=True)

    # --- executor: persistent per-device loops -------------------------
    loop_best = float("inf")
    for _ in range(iters):
        nel = NodeEventLoop(num_devices=1)
        for pid in range(particles):
            nel.register(None)
        loop_best = min(loop_best, _drive(
            lambda pid, fn: nel.dispatch(pid, fn), particles, messages))
        nel.shutdown()
    loop_rate = messages / loop_best
    print(f"dispatch/event-loop/p{particles},"
          f"{loop_best / messages * 1e6:.2f},"
          f"msgs_per_sec={loop_rate:.0f}", flush=True)

    ratio = loop_rate / legacy_rate
    print(f"dispatch/speedup/p{particles},{ratio:.2f},"
          f"x_over_thread_per_msg", flush=True)
    return ratio


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--particles", type=int, default=8)
    ap.add_argument("--messages", type=int, default=4000)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--require", type=float, default=0.0,
                    help="fail (exit 1) if speedup is below this ratio")
    a = ap.parse_args()
    ratio = run(a.particles, a.messages, a.iters)
    if a.require and ratio < a.require:
        raise SystemExit(
            f"dispatch speedup {ratio:.2f}x below required {a.require}x")


if __name__ == "__main__":
    main()
