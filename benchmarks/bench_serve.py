"""Serving throughput + latency: micro-batched vs one-request-at-a-time.

The serving acceptance bar (ISSUE-3): coalescing concurrent requests
into fused BMA calls must deliver >= 3x the throughput of flushing every
request as its own fused call at 8 particles. Both sides run the full
service stack (engine + batcher + executor worker), differing only in
``max_batch`` — so the ratio isolates exactly what micro-batching buys.

Rows:
  serve/unbatched/p{P}     us_per_request, req_per_s   (max_batch=1)
  serve/batched/p{P}       us_per_request, req_per_s   (max_batch=32)
  serve/speedup/p{P}       ratio, x_over_unbatched
  serve/engine/p{P}_b{B}   us_per_fused_call across request batch sizes
  serve/latency/p{P}       p50 us, p95/p99 derived     (batched path)

``python -m benchmarks.run --only serve`` persists the rows to
BENCH_serve.json; ``python -m benchmarks.bench_serve --require 3.0``
enforces the speedup bar (CI).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import PushDistribution
from repro.data.synthetic import mnist_like
from repro.serve import serve

from .util import emit, timeit, tiny_module

PARTICLES = (2, 8)
REQUESTS = 32
ENGINE_BATCHES = (1, 8, 32)


def _requests(n):
    rng = np.random.default_rng(0)
    batch = mnist_like(rng, n)
    return [{"images": batch["images"][i]} for i in range(n)]


def _drive(svc, reqs) -> float:
    """Submit every request async, wait for all; returns seconds."""
    t0 = time.perf_counter()
    futs = [svc.predict_async(r) for r in reqs]
    for f in futs:
        f.result(120.0)
    return time.perf_counter() - t0


def _throughput(pd, reqs, *, max_batch: int, max_wait_ms: float):
    with serve(pd, kind="classify", max_batch=max_batch,
               max_wait_ms=max_wait_ms, max_queue=4 * REQUESTS) as svc:
        # compile every bucket this run can hit before timing (blocking:
        # lazy warmup results must not queue under the timed region)
        batch = {"images": np.stack([r["images"] for r in reqs])}
        b = 1
        while b <= max_batch:
            jax.block_until_ready(
                svc.predict_batch({"images": batch["images"][:b]}))
            b <<= 1
        _drive(svc, reqs[:4])                 # warm the batcher path
        dt = _drive(svc, reqs)
        return dt, svc.stats()


def run(require: float | None = None):
    module = tiny_module()
    reqs = _requests(REQUESTS)
    for P in PARTICLES:
        with PushDistribution(module, num_devices=1, seed=0) as pd:
            for _ in range(P):
                pd.p_create()

            dt_un, _ = _throughput(pd, reqs, max_batch=1, max_wait_ms=0.0)
            dt_b, stats = _throughput(pd, reqs, max_batch=REQUESTS,
                                      max_wait_ms=2.0)
            us_un = dt_un / REQUESTS * 1e6
            us_b = dt_b / REQUESTS * 1e6
            emit(f"serve/unbatched/p{P}", us_un,
                 f"req_per_s={REQUESTS / dt_un:.1f}")
            emit(f"serve/batched/p{P}", us_b,
                 f"req_per_s={REQUESTS / dt_b:.1f}")
            speedup = us_un / us_b
            emit(f"serve/speedup/p{P}", speedup, "x_over_unbatched")
            # the service's own percentile accounting (service.stats)
            emit(f"serve/latency/p{P}", stats["latency_p50_ms"] * 1e3,
                 f"p95_us={stats['latency_p95_ms'] * 1e3:.0f};"
                 f"p99_us={stats['latency_p99_ms'] * 1e3:.0f}")

            # raw fused-call cost across request batch sizes (no batcher)
            with serve(pd, kind="classify") as svc:
                for B in ENGINE_BATCHES:
                    batch = {"images": np.stack(
                        [r["images"] for r in reqs[:B]])}
                    us = timeit(lambda b=batch: svc.predict_batch(b))
                    emit(f"serve/engine/p{P}_b{B}", us,
                         f"us_per_req={us / B:.1f}")

            if require is not None and P == 8 and speedup < require:
                raise SystemExit(
                    f"serve speedup {speedup:.2f}x < required "
                    f"{require:.1f}x at {P} particles")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--require", type=float, default=None,
                    help="fail unless batched/unbatched >= this at 8 "
                         "particles (acceptance: 3.0)")
    a = ap.parse_args()
    print("name,us_per_call,derived")
    run(require=a.require)


if __name__ == "__main__":
    main()
