"""Paper Table 1: depth (D) vs number of particles (P) at fixed effective
parameter count, multi-SWAG on the ViT family.

Halve the depth <-> double the particles; report time per epoch. Ideal
scaling keeps the time constant along the diagonal (paper's 1x multiple).

Rows: depth_vs_particles/d<depth>_p<particles>,us_per_epoch,eff_params=<n>
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.bdl import MultiSWAG
from repro.core import ParticleModule
from repro.data.loader import DataLoader
from repro.models import api
from repro.optim import adam

from .util import emit, timeit


def _module(depth: int):
    cfg = configs.get("vit-mnist").smoke().replace(
        n_units=depth, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=192)
    return ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0], cfg=cfg)


def run(pairs=((8, 1), (4, 2), (2, 4), (1, 8)), num_batches: int = 3):
    for depth, n in pairs:
        mod = _module(depth)
        n_params = sum(x.size for x in jax.tree.leaves(
            mod.init(jax.random.PRNGKey(0))))
        data = [jax.tree.map(jnp.asarray, b) for b in
                DataLoader(mod.cfg, batch_size=8, num_batches=num_batches)]
        with MultiSWAG(mod, num_devices=1) as ms:
            ms.bayes_infer(data[:1], 1, optimizer=adam(1e-3),
                           num_particles=n, max_rank=4)
            pids = ms.push_dist.particle_ids()

            def epoch():
                for b in data:
                    ms.push_dist.p_wait(
                        [ms.push_dist.particles[p].step(b) for p in pids])
                ms.push_dist.p_wait(
                    [ms.push_dist.p_launch(p, "SWAG_COLLECT") for p in pids])
            us = timeit(lambda: epoch() or jnp.zeros(()))
        emit(f"depth_vs_particles/d{depth}_p{n}", us,
             f"eff_params={n_params * n}")


def main():
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
