"""Paper Table 2 / Appendix C.3 stress test: many small particles through
the NEL, with the particle cache oversubscribed (cache_size < particles).

Reports time per epoch, the NEL's swap statistics — the paper's
"swapping particles on and off the accelerator is even more costly"
story — and the executor's dispatch instrumentation (peak queue depth,
cumulative wait-vs-run time), which localizes whether oversubscription
cost is paid in swapping or in queueing.

Rows: stress/p<particles>_cache<size>,us_per_epoch,
      swaps=<in>/<out> qdepth=<max> wait_ms=<t> run_ms=<t>
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.bdl import DeepEnsemble
from repro.data.loader import DataLoader
from repro.optim import sgd

from .util import emit, timeit, tiny_module


def run(counts=(8, 16, 32), cache_sizes=(4, 32), num_batches: int = 2):
    mod = tiny_module("vit-mnist", n_units=1, d_model=32)
    data = [jax.tree.map(jnp.asarray, b) for b in
            DataLoader(mod.cfg, batch_size=4, num_batches=num_batches)]
    for n in counts:
        for cache in cache_sizes:
            with DeepEnsemble(mod, num_devices=1, cache_size=cache) as de:
                pids = [de.push_dist.p_create(sgd(1e-2)) for _ in range(n)]

                def epoch():
                    for b in data:
                        de.push_dist.p_wait(
                            [de.push_dist.particles[p].step(b) for p in pids])
                us = timeit(lambda: epoch() or jnp.zeros(()), iters=2)
                st = de.push_dist.nel.stats
                ex = de.push_dist.nel.executor.stats()
                emit(f"stress/p{n}_cache{cache}", us,
                     f"swaps={st['swaps_in']}/{st['swaps_out']} "
                     f"qdepth={ex['max_queue_depth']} "
                     f"wait_ms={ex['wait_time_s'] * 1e3:.0f} "
                     f"run_ms={ex['run_time_s'] * 1e3:.0f}")


def main():
    argparse.ArgumentParser().parse_args()
    run()


if __name__ == "__main__":
    main()
