"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Container-scale sizes (single CPU
core); EXPERIMENTS.md maps each section to the paper artifact and explains
which trends are wall-clock-faithful vs structurally validated.

  bench_scaling          Fig. 4 / Fig. 7  (particles x algorithms x devices)
  bench_depth_particles  Table 1          (depth vs particle tradeoff)
  bench_stress           Table 2 / C.3    (particle-cache oversubscription)
  bench_accuracy         Tables 3-4       (multi-SWAG vs standard accuracy)
  bench_kernels          (ours)           Pallas kernels + SVGD impls
  bench_dispatch         (ours)           event-loop vs thread-per-dispatch
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. kernels,stress")
    args = ap.parse_args()
    from . import (bench_accuracy, bench_depth_particles, bench_dispatch,
                   bench_kernels, bench_scaling, bench_stress)
    table = {
        "scaling": bench_scaling.run,
        "depth_particles": bench_depth_particles.run,
        "stress": bench_stress.run,
        "accuracy": bench_accuracy.run,
        "kernels": bench_kernels.run,
        "dispatch": bench_dispatch.run,
    }
    only = set(args.only.split(",")) if args.only else set(table)
    print("name,us_per_call,derived")
    for name, fn in table.items():
        if name in only:
            print(f"# --- {name} ---", flush=True)
            fn()


if __name__ == '__main__':
    main()
