"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Container-scale sizes (single CPU
core); EXPERIMENTS.md maps each section to the paper artifact and explains
which trends are wall-clock-faithful vs structurally validated.

When the scaling section runs, its rows (particles x emulated-device
throughput for every backend that ran) are also written to
``BENCH_scaling.json`` so the perf trajectory is tracked across PRs; CI's
sharded matrix job runs it under 4 forced host devices with
``--scaling-backend compiled-sharded``.

Serve rows land in ``BENCH_serve.json`` the same way (micro-batched vs
one-request-at-a-time throughput, fused-call latency across batch sizes
and particle counts); CI enforces the >= 3x micro-batching bar via
``bench_serve --require``.

Decode rows land in ``BENCH_decode.json`` (continuous-batching vs
flush-batched tokens/sec, retirement latency percentiles, page-pool
occupancy, plus speculative-vs-plain tok/s with acceptance rate and
cold-compile delta); CI enforces the >= 2x continuous-batching bar via
``bench_decode --require`` and the >= 1.3x speculative bar via
``--require-spec``.

Compile rows land in ``BENCH_runtime.json`` (cold-compile counts and
ProgramCache hit rate across the train -> serve lifecycle); CI enforces
a minimum hit rate via ``bench_compile --require-hit-rate``.

  bench_scaling          Fig. 4 / Fig. 7  (particles x algorithms x devices)
  bench_depth_particles  Table 1          (depth vs particle tradeoff)
  bench_stress           Table 2 / C.3    (particle-cache oversubscription)
  bench_accuracy         Tables 3-4       (multi-SWAG vs standard accuracy)
  bench_kernels          (ours)           Pallas kernels + SVGD impls
  bench_dispatch         (ours)           event-loop vs thread-per-dispatch
  bench_serve            (ours)           posterior-predictive serving layer
  bench_compile          (ours)           ProgramCache compile economics
  bench_lifecycle        (ours)           elastic churn: ops/sec, recompiles,
                                          serve latency under clone/kill
  bench_decode           (ours)           continuous-batching paged decode vs
                                          flush-batched (tok/s, p99, pages)
                                          + speculative BMA decode vs plain
                                          (tok/s, acceptance, cold compiles)
  bench_obs              (ours)           tracing overhead on the dispatch
                                          hot path (CI gates: disabled <=1%,
                                          enabled <=5%)
  bench_precision        (ours)           mixed-precision particles: HBM per
                                          particle, remat-policy temp bytes,
                                          serve latency (CI gate: fp32/bf16
                                          params+opt bytes >= 1.8x)

Obs rows land in ``BENCH_obs.json``; every BENCH_*.json additionally
carries an ``obs`` context block (tracer/registry state + per-program
FLOPs/bytes cost attribution from the global ProgramCache).
"""
import argparse
import functools
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. kernels,stress")
    ap.add_argument("--scaling-backend", default="nel",
                    choices=("nel", "compiled", "compiled-sharded"),
                    help="backend column set for the scaling section")
    ap.add_argument("--scaling-model", type=int, default=1,
                    help="model-axis size for the compiled-sharded scaling "
                         "rows (2D particle x model placement)")
    ap.add_argument("--scaling-json", default="BENCH_scaling.json",
                    help="where to persist the scaling rows")
    ap.add_argument("--serve-json", default="BENCH_serve.json",
                    help="where to persist the serving rows")
    ap.add_argument("--runtime-json", default="BENCH_runtime.json",
                    help="where to persist the compile/cache rows")
    ap.add_argument("--lifecycle-json", default="BENCH_lifecycle.json",
                    help="where to persist the churn rows")
    ap.add_argument("--decode-json", default="BENCH_decode.json",
                    help="where to persist the decode rows")
    ap.add_argument("--obs-json", default="BENCH_obs.json",
                    help="where to persist the tracing-overhead rows")
    ap.add_argument("--precision-json", default="BENCH_precision.json",
                    help="where to persist the mixed-precision rows")
    args = ap.parse_args()
    from . import (bench_accuracy, bench_compile, bench_decode,
                   bench_depth_particles, bench_dispatch, bench_kernels,
                   bench_lifecycle, bench_obs, bench_precision,
                   bench_scaling, bench_serve, bench_stress, util)
    table = {
        "scaling": functools.partial(bench_scaling.run,
                                     backend=args.scaling_backend,
                                     model=args.scaling_model),
        "depth_particles": bench_depth_particles.run,
        "stress": bench_stress.run,
        "accuracy": bench_accuracy.run,
        "kernels": bench_kernels.run,
        "dispatch": bench_dispatch.run,
        "serve": bench_serve.run,
        "compile": bench_compile.run,
        "lifecycle": bench_lifecycle.run,
        "decode": functools.partial(bench_decode.run, speculative=True),
        "obs": bench_obs.run,
        "precision": bench_precision.run,
    }
    only = set(args.only.split(",")) if args.only else set(table)
    print("name,us_per_call,derived")
    for name, fn in table.items():
        if name in only:
            print(f"# --- {name} ---", flush=True)
            fn()
    if "scaling" in only:
        import jax
        rows = [r for r in util.ROWS if r["name"].startswith("scaling/")]
        with open(args.scaling_json, "w") as f:
            json.dump({"devices": len(jax.devices()),
                       "backend": args.scaling_backend,
                       "model_axis": args.scaling_model,
                       "rows": rows, "obs": util.obs_context()}, f, indent=1)
        print(f"# wrote {len(rows)} scaling rows -> {args.scaling_json}",
              flush=True)
    if "serve" in only:
        import jax
        rows = [r for r in util.ROWS if r["name"].startswith("serve/")]
        with open(args.serve_json, "w") as f:
            json.dump({"devices": len(jax.devices()), "rows": rows,
                       "obs": util.obs_context()}, f, indent=1)
        print(f"# wrote {len(rows)} serve rows -> {args.serve_json}",
              flush=True)
    if "compile" in only:
        import jax
        from repro.runtime import global_cache
        rows = [r for r in util.ROWS if r["name"].startswith("compile/")]
        with open(args.runtime_json, "w") as f:
            json.dump({"devices": len(jax.devices()),
                       "cache": global_cache().snapshot_stats(),
                       "rows": rows, "obs": util.obs_context()}, f, indent=1)
        print(f"# wrote {len(rows)} compile rows -> {args.runtime_json}",
              flush=True)
    if "lifecycle" in only:
        import jax
        rows = [r for r in util.ROWS if r["name"].startswith("lifecycle/")]
        with open(args.lifecycle_json, "w") as f:
            json.dump({"devices": len(jax.devices()), "rows": rows,
                       "obs": util.obs_context()}, f, indent=1)
        print(f"# wrote {len(rows)} lifecycle rows -> {args.lifecycle_json}",
              flush=True)
    if "decode" in only:
        import jax
        rows = [r for r in util.ROWS if r["name"].startswith("decode/")]
        with open(args.decode_json, "w") as f:
            json.dump({"devices": len(jax.devices()), "rows": rows,
                       "obs": util.obs_context()}, f, indent=1)
        print(f"# wrote {len(rows)} decode rows -> {args.decode_json}",
              flush=True)
    if "obs" in only:
        import jax
        rows = [r for r in util.ROWS if r["name"].startswith("obs/")]
        with open(args.obs_json, "w") as f:
            json.dump({"devices": len(jax.devices()), "rows": rows,
                       "obs": util.obs_context()}, f, indent=1)
        print(f"# wrote {len(rows)} obs rows -> {args.obs_json}",
              flush=True)
    if "precision" in only:
        import jax
        rows = [r for r in util.ROWS if r["name"].startswith("precision/")]
        with open(args.precision_json, "w") as f:
            json.dump({"devices": len(jax.devices()), "rows": rows,
                       "obs": util.obs_context()}, f, indent=1)
        print(f"# wrote {len(rows)} precision rows -> {args.precision_json}",
              flush=True)


if __name__ == '__main__':
    main()
