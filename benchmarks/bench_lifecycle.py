"""Elastic particle lifecycle economics: churn throughput + recompiles.

What the capacity-padded store buys (DESIGN.md §9): before it, every
particle registration bumped the store generation and invalidated every
cached program — one clone mid-serving cost a full recompile of the BMA
program family. Now clone/kill within capacity are slot writes.

Rows (``lifecycle/...``) land in BENCH_lifecycle.json via ``run.py
--only lifecycle``; CI gates on ``--require-zero-recompile`` — if any of
the 100 churn operations cold-compiles, particle identity leaked back
into program shapes (a regression to the pre-elastic world).

  lifecycle/churn_ops          clone+kill pairs per second (no serving)
  lifecycle/churn_recompiles   cold compiles across 100 churn ops (gate: 0)
  lifecycle/serve_quiescent    BMA request p95 with a stable ensemble
  lifecycle/serve_under_churn  BMA request p95 with clone+kill between
                               requests (target <= 1.5x quiescent;
                               ``--max-latency-ratio 1.5`` gates it on
                               hardware with stable timing — this 1-core
                               container's p95 jitter exceeds the margin,
                               so CI gates zero-recompile only and
                               tracks the ratio in BENCH_lifecycle.json)
  lifecycle/resample           one SMC-style systematic resample round
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.bdl import DeepEnsemble, lifecycle
from repro.data.synthetic import mnist_like
from repro.optim import sgd
from repro.runtime import global_cache
from repro.serve import PredictiveEngine

from .util import emit, tiny_module

N_PARTICLES = 8
EPOCHS = 3
BATCH = 16
CHURN_OPS = 100
REQUESTS = 40


def _p95(xs):
    return float(np.percentile(np.asarray(xs), 95))


def _churn_once(pd):
    """One churn op: kill the oldest member, clone the (new) oldest into
    the freed slot — live count and capacity are invariant."""
    pd.p_kill(pd.particle_ids()[0])
    pd.p_clone(pd.particle_ids()[0], jitter=0.01)


def run(require_zero_recompile: bool = False,
        max_latency_ratio: float = 0.0) -> int:
    cache = global_cache()
    mod = tiny_module()
    batch = mnist_like(np.random.default_rng(0), BATCH)

    with DeepEnsemble(mod, backend="compiled", seed=0) as de:
        de.bayes_infer([batch], EPOCHS, optimizer=sgd(0.05),
                       num_particles=N_PARTICLES)
        pd = de.push_dist
        eng = PredictiveEngine(mod.forward, store=de.store, kind="classify")
        eng.predict(batch)                       # warm the serving program
        lifecycle.ensemble_weights(de, batch)    # warm the policy loss

        # -- churn throughput + the zero-recompile gate ------------------
        cold0 = cache.snapshot_stats()["cold_compiles"]
        gen0 = de.store.generation()
        t0 = time.perf_counter()
        for _ in range(CHURN_OPS):
            _churn_once(pd)
        dt = time.perf_counter() - t0
        recompiles = cache.snapshot_stats()["cold_compiles"] - cold0
        emit("lifecycle/churn_ops", dt / CHURN_OPS * 1e6,
             f"{CHURN_OPS / dt:.0f} clone+kill pairs/s")
        emit("lifecycle/churn_recompiles", 0.0,
             f"cold={recompiles} per {CHURN_OPS} ops "
             f"gen_drift={de.store.generation() - gen0}")

        # -- serving latency: quiescent vs under churn -------------------
        # interleaved sampling (quiet request, churn, churned request,
        # repeat) so machine drift hits both distributions equally; each
        # predict blocks until ready, so a churned request absorbs ALL
        # of its churn's async device work
        def timed_predict():
            t = time.perf_counter()
            jax.block_until_ready(eng.predict(batch)["mean"])
            return time.perf_counter() - t

        _churn_once(pd)
        eng.predict(batch)      # warm the churned path's one-off costs
        quiet, churned = [], []
        for _ in range(REQUESTS):
            quiet.append(timed_predict())
            _churn_once(pd)
            churned.append(timed_predict())
        p95_q, p95_c = _p95(quiet), _p95(churned)
        ratio = p95_c / max(p95_q, 1e-9)
        emit("lifecycle/serve_quiescent", p95_q * 1e6,
             f"p95 over {REQUESTS} requests")
        emit("lifecycle/serve_under_churn", p95_c * 1e6,
             f"p95 with clone+kill per request; {ratio:.2f}x quiescent")

        # -- one policy round: SMC-style resample ------------------------
        t0 = time.perf_counter()
        lifecycle.resample(de, batch=batch, jitter=0.01,
                           rng=np.random.default_rng(0))
        emit("lifecycle/resample", (time.perf_counter() - t0) * 1e6,
             f"{len(pd.particle_ids())} live after systematic resample")

        total_recompiles = cache.snapshot_stats()["cold_compiles"] - cold0

    if require_zero_recompile and total_recompiles != 0:
        print(f"# FAIL: lifecycle churn cold-compiled {total_recompiles} "
              "programs (expected 0 within capacity)", flush=True)
        return 1
    if max_latency_ratio and ratio > max_latency_ratio:
        print(f"# FAIL: p95 under churn {ratio:.2f}x quiescent > "
              f"allowed {max_latency_ratio:.2f}x", flush=True)
        return 1
    if require_zero_recompile:
        print(f"# PASS: {CHURN_OPS + REQUESTS} churn ops + resample, "
              f"0 recompiles, churn p95 {ratio:.2f}x quiescent", flush=True)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--require-zero-recompile", action="store_true",
                    help="exit nonzero if any churn op cold-compiles "
                         "(CI gate)")
    ap.add_argument("--max-latency-ratio", type=float, default=0.0,
                    help="exit nonzero if serve p95 under churn exceeds "
                         "this multiple of quiescent p95 (e.g. 1.5)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    return run(require_zero_recompile=args.require_zero_recompile,
               max_latency_ratio=args.max_latency_ratio)


if __name__ == "__main__":
    sys.exit(main())
