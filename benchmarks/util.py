"""Shared benchmark utilities: timing, CSV emission, tiny workloads.

All benchmarks print ``name,us_per_call,derived`` CSV rows (one per
measurement) so run.py can aggregate. Container-scale defaults: this box
has ONE physical CPU core — multi-"device" rows use forced host devices in
subprocesses, which exercises placement/communication code paths but NOT
real parallel speedup; EXPERIMENTS.md discusses how each paper trend is
validated structurally (collective bytes, dispatch counts) instead of by
wall clock where the wall clock cannot be faithful.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import ParticleModule
from repro.models import api


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds (blocks on jax results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


# Every emitted row is also collected here so run.py can persist sections
# as JSON artifacts (BENCH_scaling.json) for perf-trajectory tracking.
ROWS: list = []


def emit(name: str, us: float, derived: str = ""):
    ROWS.append({"name": name, "us_per_call": round(us, 1),
                 "derived": derived})
    print(f"{name},{us:.1f},{derived}", flush=True)


def obs_context() -> dict:
    """Observability context attached to every BENCH_*.json artifact:
    tracer/registry state plus per-program cost attribution for whatever
    the global ProgramCache compiled during the run (compute=True pays
    one analysis compile per entry — fine post-benchmark, off any timed
    path)."""
    from repro.obs import summary
    from repro.runtime import global_cache
    return {"obs": summary(),
            "program_costs": global_cache().program_costs(compute=True)}


def tiny_module(arch: str = "vit-mnist", n_units: int = 2,
                d_model: int = 64) -> ParticleModule:
    cfg = configs.get(arch).smoke().replace(n_units=n_units, d_model=d_model,
                                            n_heads=4, n_kv_heads=4,
                                            head_dim=16, d_ff=128)
    return ParticleModule(
        init=lambda rng: api.init_params(rng, cfg),
        loss=lambda p, b: api.loss_fn(p, b, cfg),
        forward=lambda p, b: api.forward(p, b, cfg)[0],
        cfg=cfg)
