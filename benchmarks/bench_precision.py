"""Mixed-precision particles: HBM per particle, particles-per-device
headroom, remat-policy activation footprint, serve throughput delta.

The PR acceptance bar: the "bf16" preset (bf16 masters, so params AND
adam moments store at half width) must show >= 1.8x lower resident
params+opt bytes per particle than fp32, measured off a REAL trained
store's leaf dtypes (``store.per_particle_bytes``), not an itemsize
guess. On top of that the bench reports the planning consequence — how
many live particles fit a fixed synthetic device budget for a deep
transformer config (``models.api.param_footprint``), which is exactly
the number ``Placement.auto(model="auto")`` sizes against — plus the
remat-policy menu's compiled temp-buffer footprint and the fp32 vs bf16
serve-path latency on a BMA predict engine.

Rows:
  precision/hbm/{fp32,bf16}          per-particle params+opt bytes
  precision/hbm/ratio                fp32/bf16 (gate: >= 1.8)
  precision/fit/{fp32,bf16}          live particles per synthetic 2 GiB
                                     device budget, deep config
  precision/remat/{policy}           compiled train-step temp bytes
  precision/serve/{fp32,mixed}       us per BMA predict (8-batch)

``python -m benchmarks.run --only precision`` persists the rows to
BENCH_precision.json; ``python -m benchmarks.bench_precision
--require-bytes-ratio 1.8`` enforces the memory bar (CI, both sharded
matrix jobs).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.bdl import DeepEnsemble
from repro.core.precision import get as get_precision
from repro.data.synthetic import mnist_like
from repro.models import api
from repro.optim import adam
from repro.serve import PredictiveEngine

from .util import emit, timeit, tiny_module

PARTICLES = 4
BUDGET_BYTES = 2 << 30          # synthetic per-device budget for /fit rows
DEEP_ARCH = "qwen1.5-0.5b"      # eval_shape only: never allocated


def _lm_batch(cfg, m=4, seed=0):
    tok = jax.random.randint(jax.random.PRNGKey(seed), (m, 16), 0,
                             cfg.vocab_size)
    return {"tokens": tok, "labels": tok}


def _store_bytes(precision):
    """Train a small ensemble one epoch so params AND opt_state are
    resident store keys, then read the per-particle bytes off the actual
    leaf dtypes."""
    mod = tiny_module()
    data = [mnist_like(np.random.default_rng(0), 8)]
    with DeepEnsemble(mod, num_devices=1, backend="compiled", seed=0,
                      precision=precision) as de:
        de.bayes_infer(data, 1, optimizer=adam(1e-3),
                       num_particles=PARTICLES)
        store = de.store
        return sum(store.per_particle_bytes(k)
                   for k in ("params", "opt_state"))


def _remat_temp_bytes(policy):
    """Temp-buffer bytes of one compiled train step for a deeper config
    under a named remat policy (AOT memory_analysis; None off CPU-less
    backends)."""
    cfg = configs.get(DEEP_ARCH).replace(
        n_units=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=256, max_seq_len=64, remat=False,
        remat_policy=policy)
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    batch = _lm_batch(cfg, m=2, seed=1)

    def step(p):
        return jax.value_and_grad(lambda q: api.loss_fn(q, batch, cfg)[0])(p)

    try:
        compiled = jax.jit(step).lower(params).compile()
        return int(compiled.memory_analysis().temp_size_in_bytes)
    except Exception:
        return None


def run(require_bytes_ratio=None):
    # -- headline: resident params+opt bytes per particle ------------------
    per = {}
    for name in ("fp32", "bf16"):
        per[name] = _store_bytes(name)
        emit(f"precision/hbm/{name}", float(per[name]),
             f"params+opt_bytes_per_particle;master="
             f"{get_precision(name).master}")
    ratio = per["fp32"] / per["bf16"]
    emit("precision/hbm/ratio", ratio, "fp32_over_bf16")

    # -- planning consequence: particles per device budget, deep config ----
    for name in ("fp32", "bf16"):
        fp = api.param_footprint(configs.get(DEEP_ARCH), name)
        fit = BUDGET_BYTES // fp
        emit(f"precision/fit/{name}", float(fit),
             f"particles_per_{BUDGET_BYTES >> 30}GiB;"
             f"param_bytes={fp}")

    # -- remat-policy menu: compiled temp footprint ------------------------
    base = None
    for policy in (None, "dots_saveable", "nothing_saveable"):
        tb = _remat_temp_bytes(policy)
        label = policy or "none"
        if tb is None:
            emit(f"precision/remat/{label}", 0.0, "memory_analysis_n/a")
            continue
        if base is None:
            base = tb
        emit(f"precision/remat/{label}", float(tb),
             f"train_step_temp_bytes;x_vs_none={base / max(tb, 1):.2f}")

    # -- serve path: fp32 vs bf16-compute BMA predict latency --------------
    probe = {"images": mnist_like(np.random.default_rng(3), 8)["images"]}
    for name in ("fp32", "mixed"):
        mod = tiny_module()
        with DeepEnsemble(mod, num_devices=1, backend="compiled", seed=0,
                          precision=name) as de:
            de.bayes_infer([mnist_like(np.random.default_rng(0), 8)], 1,
                           optimizer=adam(1e-3), num_particles=PARTICLES)
            eng = PredictiveEngine(mod.forward, store=de.store,
                                   kind="classify")
            us = timeit(lambda: eng.predict(probe)["mean"], iters=5)
            emit(f"precision/serve/{name}", us,
                 f"req_per_s={1e6 / us:.1f};serve={get_precision(name).serve}")

    if require_bytes_ratio is not None and ratio < require_bytes_ratio:
        raise SystemExit(
            f"per-particle params+opt bytes fp32/bf16 = {ratio:.2f}x "
            f"< required {require_bytes_ratio:.1f}x")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--require-bytes-ratio", type=float, default=None,
                    help="fail unless fp32/bf16 per-particle params+opt "
                         "bytes >= this (acceptance: 1.8)")
    a = ap.parse_args()
    print("name,us_per_call,derived")
    run(require_bytes_ratio=a.require_bytes_ratio)


if __name__ == "__main__":
    main()
